//! GP-bandit (paper Code Block 2) on Branin, comparing the two numeric
//! backends: the AOT-compiled JAX/Pallas artifact executed via PJRT, and
//! the pure-Rust reference — plus random search as the floor.
//!
//! Requires `make artifacts` for the PJRT backend (falls back with a
//! notice otherwise).
//!
//! ```text
//! cargo run --offline --release --example gp_bandit_demo
//! ```

use ossvizier::benchmarks::objectives::Objective;
use ossvizier::benchmarks::runner::run_study;
use ossvizier::pyvizier::Algorithm;
use ossvizier::runtime::ArtifactRegistry;

fn main() {
    match ArtifactRegistry::global() {
        Some(reg) => println!(
            "PJRT artifacts available: {:?}\n",
            reg.variant_keys()
                .iter()
                .map(|k| format!("n{}d{}m{}", k.n, k.d, k.m))
                .collect::<Vec<_>>()
        ),
        None => println!("NOTE: artifacts/ missing — GP_BANDIT falls back to the Rust backend\n"),
    }

    let budget = 40;
    let seeds = 3;
    println!("branin, {budget} trials, median over {seeds} seeds (optimum 0.3979):\n");
    println!("{:<28} {:>10} {:>14}", "algorithm", "best", "wall ms");
    for alg in [
        Algorithm::RandomSearch,
        Algorithm::Custom("GP_BANDIT_RUST".into()),
        Algorithm::GpBandit, // PJRT artifact when available
    ] {
        let mut outs: Vec<_> = (0..seeds)
            .map(|s| run_study(Objective::Branin, 2, alg.clone(), s, budget, 2))
            .collect();
        outs.sort_by(|a, b| a.best().partial_cmp(&b.best()).unwrap());
        let median = &outs[outs.len() / 2];
        println!("{:<28} {:>10.4} {:>14.1}", alg.as_str(), median.best(), median.wall_ms);
    }
    println!("\nGP-bandit variants should land well under random search.");
}
