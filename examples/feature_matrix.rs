//! Regenerates OSS Vizier's row of the paper's Table 1 by *demonstrating*
//! each claimed feature against this implementation (each check is the
//! minimal end-to-end scenario; the full versions live in rust/tests/).
//!
//! ```text
//! cargo run --offline --release --example feature_matrix
//! ```

use ossvizier::client::{LocalTransport, VizierClient};
use ossvizier::pyvizier::search_space::ParameterConfig;
use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, StudyConfig};
use ossvizier::service::in_memory_service;
use ossvizier::wire::messages::{ScaleType, StoppingConfig, StoppingKind};

fn check(name: &str, f: impl FnOnce() -> bool) {
    let ok = f();
    println!("  {:<22} {}", name, if ok { "yes ✓" } else { "NO ✗" });
    assert!(ok, "feature {name} failed");
}

fn main() {
    println!("Table 1, OSS Vizier row — regenerated against this implementation:");
    println!("  Type                   Service (client/server over a wire protocol)");
    println!("  Client languages       any (binary TLV wire format; Rust client included)");

    check("Parallel trials", || {
        let service = in_memory_service(4);
        let mut config = StudyConfig::new("par");
        config.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
        config.add_metric(MetricInformation::minimize("v"));
        let mk = |svc, id: &str| {
            VizierClient::load_or_create_study(
                Box::new(LocalTransport::new(svc)),
                "par",
                &config,
                id,
            )
            .unwrap()
        };
        let mut a = mk(service.clone(), "a");
        let mut b = mk(service, "b");
        let ta = a.get_suggestions(1).unwrap()[0].id;
        let tb = b.get_suggestions(1).unwrap()[0].id;
        ta != tb // two workers hold distinct active trials of one study
    });

    check("Multi-Objective", || {
        let service = in_memory_service(2);
        let mut config = StudyConfig::new("mo");
        config.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
        config.add_metric(MetricInformation::maximize("f1"));
        config.add_metric(MetricInformation::minimize("f2"));
        config.algorithm = Algorithm::Nsga2;
        let mut c = VizierClient::load_or_create_study(
            Box::new(LocalTransport::new(service)),
            "mo",
            &config,
            "w",
        )
        .unwrap();
        for _ in 0..10 {
            for t in c.get_suggestions(2).unwrap() {
                let x = t.parameters.get_f64("x").unwrap();
                let m = Measurement::new(1).with_metric("f1", x).with_metric("f2", 1.0 - x);
                c.complete_trial(t.id, Some(&m)).unwrap();
            }
        }
        c.list_optimal_trials().unwrap().len() > 1 // a frontier, not a point
    });

    check("Early Stopping", || {
        let service = in_memory_service(2);
        let mut config = StudyConfig::new("es");
        config.search_space.add_float("x", 0.0, 1.0, ScaleType::Linear);
        config.add_metric(MetricInformation::maximize("acc"));
        config.stopping = StoppingConfig { kind: StoppingKind::Median, min_trials: 2, confidence: 1.0 };
        let mut c = VizierClient::load_or_create_study(
            Box::new(LocalTransport::new(service)),
            "es",
            &config,
            "w",
        )
        .unwrap();
        for acc in [0.9, 0.8, 0.85] {
            let t = c.get_suggestions(1).unwrap()[0].clone();
            for s in 1..=5 {
                c.add_measurement(t.id, &Measurement::new(s).with_metric("acc", acc)).unwrap();
            }
            c.complete_trial(t.id, None).unwrap();
        }
        let bad = c.get_suggestions(1).unwrap()[0].clone();
        for s in 1..=3 {
            c.add_measurement(bad.id, &Measurement::new(s).with_metric("acc", 0.01)).unwrap();
        }
        c.should_trial_stop(bad.id).unwrap()
    });

    check("Transfer Learning", || {
        // PolicySupporter reads across studies (§6.2) — exercised via the
        // datastore-backed supporter.
        use ossvizier::datastore::memory::InMemoryDatastore;
        use ossvizier::datastore::Datastore;
        use ossvizier::pythia::supporter::{DatastoreSupporter, PolicySupporter};
        use std::sync::Arc;
        let ds = Arc::new(InMemoryDatastore::new());
        for name in ["prior-study", "new-study"] {
            ds.create_study(ossvizier::wire::messages::StudyProto {
                display_name: name.into(),
                ..Default::default()
            })
            .unwrap();
        }
        let sup = DatastoreSupporter::new(ds as Arc<dyn Datastore>);
        let names = sup.list_study_names().unwrap();
        names.len() == 2 && sup.study_config(&names[0]).is_ok()
    });

    check("Conditional Search", || {
        let mut config = StudyConfig::new("cond");
        config.search_space.add_categorical("model", vec!["a", "b"]);
        config
            .search_space
            .add_conditional("model", vec!["b".into()], ParameterConfig::integer("k", 1, 3))
            .unwrap();
        config.add_metric(MetricInformation::maximize("m"));
        let mut rng = ossvizier::util::rng::Pcg32::seeded(1);
        (0..50).all(|_| {
            let p = config.search_space.sample(&mut rng);
            config.search_space.validate(&p).is_ok()
                && (p.get_str("model") == Some("b")) == p.contains("k")
        })
    });

    println!("\nall Table-1 features demonstrated ✓");
}
