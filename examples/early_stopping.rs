//! Automated early stopping (paper Code Block 3 + Appendix B.1): tune the
//! learning-curve simulator with BOTH stopping rules and report the
//! evaluation budget each saves at equal final quality.
//!
//! ```text
//! cargo run --offline --release --example early_stopping
//! ```

use ossvizier::benchmarks::CurveSimulator;
use ossvizier::client::{LocalTransport, VizierClient};
use ossvizier::pyvizier::{Algorithm, StudyConfig};
use ossvizier::service::in_memory_service;
use ossvizier::util::rng::Pcg32;
use ossvizier::wire::messages::{StoppingConfig, StoppingKind};

struct Outcome {
    best: f64,
    steps: u64,
    stopped: u64,
    trials: u64,
}

fn run(kind: StoppingKind, label: &str) -> Outcome {
    let sim = CurveSimulator::default();
    let mut config: StudyConfig = sim.study_config();
    config.algorithm = Algorithm::QuasiRandomSearch;
    config.stopping = StoppingConfig { kind, min_trials: 4, confidence: 1.0 };
    config.seed = 5;

    let service = in_memory_service(4);
    let transport = Box::new(LocalTransport::new(service));
    let mut client =
        VizierClient::load_or_create_study(transport, &format!("es-{label}"), &config, "w").unwrap();
    let mut rng = Pcg32::seeded(8);
    let (mut steps, mut stopped, mut best) = (0u64, 0u64, 0.0f64);
    let trials = 40u64;
    for _ in 0..trials {
        let t = client.get_suggestions(1).unwrap().remove(0);
        let mut was_stopped = false;
        for step in 1..=sim.max_steps {
            client
                .add_measurement(t.id, &sim.measure(&t.parameters, step, &mut rng))
                .unwrap();
            steps += 1;
            if kind != StoppingKind::None && step % 4 == 0 && step < sim.max_steps {
                // Code Block 3: check_early_stopping + stop.
                if client.should_trial_stop(t.id).unwrap() {
                    was_stopped = true;
                    break;
                }
            }
        }
        let done = client.complete_trial(t.id, None).unwrap();
        if was_stopped {
            stopped += 1;
        }
        best = best.max(done.final_metric("accuracy").unwrap_or(0.0));
    }
    Outcome { best, steps, stopped, trials }
}

fn main() {
    println!(
        "{:<14} {:>8} {:>12} {:>14} {:>10}",
        "rule", "trials", "stopped", "steps run", "best acc"
    );
    let mut baseline_steps = 0;
    for (kind, label) in [
        (StoppingKind::None, "none"),
        (StoppingKind::Median, "median"),
        (StoppingKind::DecayCurve, "decay-curve"),
    ] {
        let o = run(kind, label);
        if kind == StoppingKind::None {
            baseline_steps = o.steps;
        }
        let saved = 100.0 * (baseline_steps.saturating_sub(o.steps)) as f64 / baseline_steps as f64;
        println!(
            "{label:<14} {:>8} {:>12} {:>9} (-{saved:>4.1}%) {:>10.4}",
            o.trials, o.stopped, o.steps, o.best
        );
        if kind != StoppingKind::None {
            assert!(o.stopped > 0, "{label} should stop some trials");
            assert!(o.steps < baseline_steps, "{label} should save steps");
            assert!(o.best > 0.8, "{label} must not hurt final quality: {}", o.best);
        }
    }
    println!("\nboth rules save budget without losing the best configuration ✓");
}
