//! Algorithm comparison sweep (experiment C-CONV): every built-in policy
//! on the BBOB-style suite, multiple seeds, run through the real service
//! stack. Prints a convergence table (median best value and trials-to-
//! target). The paper ships no algorithm benchmarks (§8); this regenerates
//! the *capability* its §6.3 algorithm surface claims.
//!
//! ```text
//! cargo run --offline --release --example algorithm_comparison [--budget 60] [--seeds 5]
//! ```

use ossvizier::benchmarks::objectives::SINGLE_OBJECTIVE;
use ossvizier::benchmarks::runner::run_study;
use ossvizier::pyvizier::Algorithm;
use ossvizier::util::cli::{Args, OptSpec};

fn main() {
    let specs = vec![
        OptSpec { name: "budget", takes_value: true, help: "trials per study" },
        OptSpec { name: "seeds", takes_value: true, help: "seeds per (alg, objective)" },
        OptSpec { name: "dim", takes_value: true, help: "dimension for scalable objectives" },
    ];
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &specs).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let budget = args.get_u64("budget", 60).unwrap() as usize;
    let seeds = args.get_u64("seeds", 5).unwrap();
    let dim = args.get_u64("dim", 4).unwrap() as usize;

    let algorithms = [
        Algorithm::RandomSearch,
        Algorithm::QuasiRandomSearch,
        Algorithm::GridSearch,
        Algorithm::HillClimb,
        Algorithm::RegularizedEvolution,
        Algorithm::HarmonySearch,
        Algorithm::Firefly,
        Algorithm::GpBandit,
    ];

    println!("budget={budget} trials, {seeds} seeds, dim={dim} (fixed dims for branin/hartmann6)\n");
    println!(
        "{:<22} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "algorithm", "sphere", "rosenbrock", "rastrigin", "branin", "hartmann6"
    );
    let mut ranking: Vec<(String, f64)> = Vec::new();
    for alg in &algorithms {
        let mut row = format!("{:<22}", alg.as_str());
        let mut score_sum = 0.0;
        for obj in SINGLE_OBJECTIVE {
            let mut bests: Vec<f64> = (0..seeds)
                .map(|s| run_study(obj, dim, alg.clone(), s, budget, 4).best())
                .collect();
            bests.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = bests[bests.len() / 2];
            row.push_str(&format!(" {median:>12.4}"));
            // Normalized regret for the cross-objective ranking.
            let opt = obj.optimum().unwrap();
            score_sum += (median - opt).max(0.0).ln_1p();
        }
        println!("{row}");
        ranking.push((alg.as_str().to_string(), score_sum));
    }
    ranking.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    println!("\noverall ranking (sum of log-regret; lower is better):");
    for (i, (name, score)) in ranking.iter().enumerate() {
        println!("  {}. {name:<22} {score:.3}", i + 1);
    }
}
