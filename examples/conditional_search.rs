//! Conditional search (paper §4.2's example): competitively tune
//! `model ∈ {linear, dnn, random_forest}`, each with its own child
//! parameters. Demonstrates that inactive branches never appear in
//! suggestions (the invariance the paper calls out).
//!
//! ```text
//! cargo run --offline --release --example conditional_search
//! ```

use ossvizier::client::{LocalTransport, VizierClient};
use ossvizier::pyvizier::search_space::ParameterConfig;
use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, StudyConfig};
use ossvizier::service::in_memory_service;
use ossvizier::wire::messages::ScaleType;

fn main() {
    let mut config = StudyConfig::new("model-select");
    config
        .search_space
        .add_categorical("model", vec!["linear", "dnn", "random_forest"]);
    config
        .search_space
        .add_conditional(
            "model",
            vec!["dnn".into(), "linear".into()],
            ParameterConfig::double("learning_rate", 1e-4, 1e-1).with_scale(ScaleType::Log),
        )
        .unwrap();
    config
        .search_space
        .add_conditional("model", vec!["dnn".into()], ParameterConfig::integer("num_layers", 1, 6))
        .unwrap();
    config
        .search_space
        .add_conditional(
            "model",
            vec!["random_forest".into()],
            ParameterConfig::integer("num_trees", 10, 500),
        )
        .unwrap();
    config.add_metric(MetricInformation::maximize("score"));
    config.algorithm = Algorithm::RegularizedEvolution;
    config.seed = 31;

    // Simulated per-model performance: DNN wins when tuned, RF is a solid
    // default, linear caps out.
    let evaluate = |t: &ossvizier::pyvizier::Trial| -> f64 {
        match t.parameters.get_str("model").unwrap() {
            "linear" => {
                let lr = t.parameters.get_f64("learning_rate").unwrap();
                0.70 - 0.05 * (lr.log10() + 2.5).powi(2)
            }
            "dnn" => {
                let lr = t.parameters.get_f64("learning_rate").unwrap();
                let layers = t.parameters.get_i64("num_layers").unwrap() as f64;
                0.92 - 0.08 * (lr.log10() + 2.0).powi(2) - 0.01 * (layers - 4.0).powi(2)
            }
            "random_forest" => {
                let trees = t.parameters.get_i64("num_trees").unwrap() as f64;
                0.80 + 0.02 * (trees / 500.0) - 0.04 * (trees / 500.0 - 0.6).powi(2)
            }
            other => panic!("unknown model {other}"),
        }
    };

    let service = in_memory_service(2);
    let transport = Box::new(LocalTransport::new(service));
    let mut client =
        VizierClient::load_or_create_study(transport, "model-select", &config, "w").unwrap();

    let mut counts = std::collections::BTreeMap::new();
    for _ in 0..40 {
        for trial in client.get_suggestions(2).unwrap() {
            // Invariance check (paper §4.2): inactive children never present.
            config.search_space.validate(&trial.parameters).unwrap();
            match trial.parameters.get_str("model").unwrap() {
                "random_forest" => assert!(!trial.parameters.contains("num_layers")),
                "linear" => {
                    assert!(!trial.parameters.contains("num_layers"));
                    assert!(!trial.parameters.contains("num_trees"));
                }
                _ => assert!(!trial.parameters.contains("num_trees")),
            }
            *counts.entry(trial.parameters.get_str("model").unwrap().to_string()).or_insert(0u32) += 1;
            let score = evaluate(&trial);
            client
                .complete_trial(trial.id, Some(&Measurement::new(1).with_metric("score", score)))
                .unwrap();
        }
    }

    let best = client.list_optimal_trials().unwrap()[0].clone();
    println!("suggestions per model arm: {counts:?}");
    println!(
        "best: model={} score={:.4} params={:?}",
        best.parameters.get_str("model").unwrap(),
        best.final_metric("score").unwrap(),
        best.parameters
    );
    assert_eq!(best.parameters.get_str("model"), Some("dnn"), "tuned DNN should win");
    println!("conditional-search invariances held for all 80 trials ✓");
}
