//! Multi-objective optimization (experiment C-MO): NSGA-II on ZDT1/ZDT2
//! through the service, reporting hypervolume growth and the Pareto
//! frontier from `ListOptimalTrials` (paper §4.1: "find Pareto frontiers
//! over multiple objectives").
//!
//! ```text
//! cargo run --offline --release --example multiobjective
//! ```

use ossvizier::benchmarks::objectives::Objective;
use ossvizier::benchmarks::runner::run_mo_study;
use ossvizier::client::{LocalTransport, VizierClient};
use ossvizier::pyvizier::{Algorithm, Measurement};
use ossvizier::service::in_memory_service;

fn main() {
    for obj in [Objective::Zdt1, Objective::Zdt2] {
        let (hv, _) = run_mo_study(obj, 6, 7, 120, 8);
        println!(
            "{}: hypervolume after 10/60/120 trials = {:.3} / {:.3} / {:.3}",
            obj.name(),
            hv[9],
            hv[59],
            hv[119]
        );
        assert!(hv[119] > hv[9], "hypervolume must grow");
    }

    // Show the frontier the service reports for a fresh ZDT1 study.
    let obj = Objective::Zdt1;
    let mut config = obj.study_config(6);
    config.algorithm = Algorithm::Nsga2;
    config.seed = 99;
    let service = in_memory_service(2);
    let transport = Box::new(LocalTransport::new(service));
    let mut client =
        VizierClient::load_or_create_study(transport, "zdt1-frontier", &config, "w").unwrap();
    for _ in 0..15 {
        for t in client.get_suggestions(8).unwrap() {
            let metrics = obj.evaluate(&t.parameters, 6);
            let mut m = Measurement::new(1);
            for (k, v) in metrics {
                m.metrics.insert(k, v);
            }
            client.complete_trial(t.id, Some(&m)).unwrap();
        }
    }
    let mut front: Vec<(f64, f64)> = client
        .list_optimal_trials()
        .unwrap()
        .iter()
        .map(|t| (t.final_metric("f1").unwrap(), t.final_metric("f2").unwrap()))
        .collect();
    front.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    println!("\nPareto frontier from ListOptimalTrials ({} points):", front.len());
    println!("{:>8} {:>8}", "f1", "f2");
    for (f1, f2) in &front {
        println!("{f1:>8.4} {f2:>8.4}");
    }
    // Frontier sanity: f2 strictly decreasing as f1 grows (both minimized).
    for w in front.windows(2) {
        assert!(w[1].1 <= w[0].1 + 1e-9, "frontier must trade off: {front:?}");
    }
    assert!(front.len() >= 5, "expect a spread frontier");
    println!("\nfrontier is mutually non-dominated ✓");
}
