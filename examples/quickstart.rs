//! Quickstart — the paper's Code Block 1, in Rust.
//!
//! Starts an in-process service, defines a study (search space, metric,
//! algorithm), and runs the suggest → evaluate → complete loop.
//!
//! ```text
//! cargo run --offline --release --example quickstart
//! ```

use ossvizier::client::{LocalTransport, SuggestionLoop, VizierClient};
use ossvizier::pyvizier::{Algorithm, Measurement, MetricInformation, StudyConfig};
use ossvizier::service::in_memory_service;
use ossvizier::wire::messages::ScaleType;

fn main() {
    // --- Code Block 1: study configuration -------------------------------
    let mut config = StudyConfig::new("cifar10");
    config
        .search_space
        .add_float("learning_rate", 1e-4, 1e-2, ScaleType::Log)
        .add_int("num_layers", 1, 5);
    config.add_metric(MetricInformation::maximize("accuracy").with_range(0.0, 1.0));
    config.algorithm = Algorithm::RandomSearch;

    // --- service + client -------------------------------------------------
    // The server "may be launched in the same local process as the client,
    // in cases where distributed computing is not needed" (§3.2).
    let service = in_memory_service(4);
    let transport = Box::new(LocalTransport::new(service));
    let client_id = std::env::args().nth(1).unwrap_or_else(|| "worker-0".into());
    let mut client =
        VizierClient::load_or_create_study(transport, "cifar10", &config, &client_id)
            .expect("create study");

    // --- tuning loop -------------------------------------------------------
    let evaluate = |lr: f64, layers: i64| -> f64 {
        // Stand-in for training a model: peak at lr=1e-3, 3 layers.
        let acc = 0.9 - 0.1 * (lr.log10() + 3.0).powi(2) - 0.02 * (layers - 3).pow(2) as f64;
        acc.clamp(0.0, 1.0)
    };
    let mut done = SuggestionLoop { client: &mut client, batch: 2 };
    let completed = done
        .run(30, |trial| {
            let lr = trial.parameters.get_f64("learning_rate").unwrap();
            let layers = trial.parameters.get_i64("num_layers").unwrap();
            let acc = evaluate(lr, layers);
            println!(
                "trial {:>2}: lr={lr:<10.6} layers={layers}  accuracy={acc:.4}",
                trial.id
            );
            Ok(Measurement::new(1).with_metric("accuracy", acc))
        })
        .expect("tuning loop");

    let best = client.list_optimal_trials().expect("optimal")[0].clone();
    println!(
        "\ncompleted {completed} trials; best accuracy {:.4} at lr={:.6}, layers={}",
        best.final_metric("accuracy").unwrap(),
        best.parameters.get_f64("learning_rate").unwrap(),
        best.parameters.get_i64("num_layers").unwrap(),
    );
}
