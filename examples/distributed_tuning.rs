//! END-TO-END DRIVER (Figure 2): the full distributed topology on a real
//! small workload.
//!
//! * API server over TCP with a **durable WAL datastore**;
//! * a **separate Pythia service** process-equivalent hosting the policies;
//! * **8 parallel workers**, each a TCP client with its own `client_id`,
//!   tuning a simulated deep-learning job (learning-curve simulator) with
//!   intermediate measurements and **median automated stopping**;
//! * fault injection: one worker is killed mid-trial and restarted with
//!   the same `client_id` (it must receive the same trial back), and the
//!   API server is **killed and restarted** mid-run (operations resume
//!   from the WAL).
//!
//! Prints trial/RPC throughput, suggestion latency, early-stopping
//! savings, and the best configuration found. Results recorded in
//! EXPERIMENTS.md §F2.
//!
//! ```text
//! cargo run --offline --release --example distributed_tuning
//! ```

use ossvizier::benchmarks::CurveSimulator;
use ossvizier::client::{TcpTransport, VizierClient};
use ossvizier::datastore::wal::WalDatastore;
use ossvizier::datastore::Datastore;
use ossvizier::pythia::runner::default_registry;
use ossvizier::pyvizier::{Algorithm, Measurement};
use ossvizier::service::remote_pythia::{PythiaServer, RemotePythia};
use ossvizier::service::{VizierServer, VizierService};
use ossvizier::util::rng::Pcg32;
use ossvizier::util::time::Stopwatch;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

const WORKERS: usize = 8;
const TRIALS_PER_WORKER: usize = 25;

fn start_api(
    ds: Arc<dyn Datastore>,
    pythia_addr: &str,
    bind: &str,
) -> (VizierServer, Arc<VizierService>) {
    let service = VizierService::new(ds, Arc::new(RemotePythia::new(pythia_addr)), 16);
    let resumed = service.resume_pending_operations().expect("resume");
    if resumed > 0 {
        println!("[api] resumed {resumed} interrupted operation(s) from the WAL");
    }
    let svc = Arc::clone(&service);
    (VizierServer::start(service, bind).expect("bind api"), svc)
}

fn main() {
    let dir = std::env::temp_dir().join(format!("ossvizier-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let wal_path = dir.join("store.wal");

    // --- topology ----------------------------------------------------------
    let ds: Arc<dyn Datastore> = Arc::new(WalDatastore::open(&wal_path).expect("wal"));
    let (api, service) = start_api(Arc::clone(&ds), "127.0.0.1:1", "127.0.0.1:0");
    let api_addr = api.local_addr().to_string();
    let pythia = PythiaServer::start(default_registry(), &api_addr, "127.0.0.1:0").expect("pythia");
    let pythia_addr = pythia.local_addr().to_string();
    // Re-point the API server at the live Pythia address.
    api.shutdown();
    service.shutdown();
    let (api, service) = start_api(Arc::clone(&ds), &pythia_addr, &api_addr);
    println!("[topology] api={api_addr} pythia={pythia_addr} wal={}", wal_path.display());

    // --- study --------------------------------------------------------------
    let sim = CurveSimulator {
        max_steps: 20,
        noise_std: 0.01,
        infeasible_p: 0.03,
        ..Default::default()
    };
    let mut config = sim.study_config();
    config.algorithm = Algorithm::RegularizedEvolution;
    config.seed = 2022;

    let completed = Arc::new(AtomicU64::new(0));
    let stopped_early = Arc::new(AtomicU64::new(0));
    let steps_run = Arc::new(AtomicU64::new(0));
    let suggest_lat_us = Arc::new(AtomicU64::new(0));
    let suggest_count = Arc::new(AtomicU64::new(0));

    let run_worker = {
        let sim = sim.clone();
        let config = config.clone();
        let api_addr = api_addr.clone();
        let completed = Arc::clone(&completed);
        let stopped = Arc::clone(&stopped_early);
        let steps = Arc::clone(&steps_run);
        let lat = Arc::clone(&suggest_lat_us);
        let cnt = Arc::clone(&suggest_count);
        move |worker_id: usize, budget: usize| {
            let transport = Box::new(TcpTransport::connect(&api_addr).expect("connect"));
            let mut client = VizierClient::load_or_create_study(
                transport,
                "curve-sim",
                &config,
                &format!("worker-{worker_id}"),
            )
            .expect("load_or_create");
            let mut rng = Pcg32::seeded(1000 + worker_id as u64);
            let mut done = 0;
            while done < budget {
                let sw = Stopwatch::start();
                let suggestions = client.get_suggestions(1).expect("suggest");
                lat.fetch_add(sw.elapsed_micros(), Ordering::Relaxed);
                cnt.fetch_add(1, Ordering::Relaxed);
                for trial in suggestions {
                    if sim.is_infeasible(&trial.parameters, &mut rng) {
                        client.report_infeasible(trial.id, "diverged at init").unwrap();
                        done += 1;
                        continue;
                    }
                    let mut was_stopped = false;
                    for step in 1..=sim.max_steps {
                        client
                            .add_measurement(trial.id, &sim.measure(&trial.parameters, step, &mut rng))
                            .expect("measurement");
                        steps.fetch_add(1, Ordering::Relaxed);
                        // Ask for an early-stopping verdict every 5 steps.
                        if step % 5 == 0 && step < sim.max_steps {
                            if client.should_trial_stop(trial.id).unwrap_or(false) {
                                was_stopped = true;
                                break;
                            }
                        }
                    }
                    client.complete_trial(trial.id, None).expect("complete");
                    if was_stopped {
                        stopped.fetch_add(1, Ordering::Relaxed);
                    }
                    completed.fetch_add(1, Ordering::Relaxed);
                    done += 1;
                }
            }
        }
    };

    // --- run: phase 1, then crash the API server, then phase 2 --------------
    let wall = Stopwatch::start();
    let phase = |n: usize| {
        let handles: Vec<_> = (0..WORKERS)
            .map(|w| {
                let f = run_worker.clone();
                std::thread::spawn(move || f(w, n))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    };

    println!("[phase 1] {WORKERS} workers x {} trials", TRIALS_PER_WORKER / 2);
    phase(TRIALS_PER_WORKER / 2);

    // Client-side fault tolerance demo: start a trial, "crash", restart.
    {
        let transport = Box::new(TcpTransport::connect(&api_addr).expect("connect"));
        let mut victim =
            VizierClient::load_or_create_study(transport, "curve-sim", &config, "victim").unwrap();
        let t1 = victim.get_suggestions(1).unwrap()[0].clone();
        drop(victim); // worker dies mid-trial
        let transport = Box::new(TcpTransport::connect(&api_addr).expect("connect"));
        let mut revived =
            VizierClient::load_or_create_study(transport, "curve-sim", &config, "victim").unwrap();
        let t2 = revived.get_suggestions(1).unwrap()[0].clone();
        assert_eq!(t1.id, t2.id, "restarted client must get the same trial");
        println!("[fault] client restart with same client_id -> same trial {} ✓", t2.id);
        revived.complete_trial(t2.id, Some(&Measurement::new(1).with_metric("accuracy", 0.1))).unwrap();
    }

    // Server-side fault tolerance: hard-stop the API server and restart on
    // the same WAL. In-flight state (studies, trials, ops) must survive.
    println!("[fault] killing API server mid-run…");
    api.shutdown();
    service.shutdown();
    let (api, service) = start_api(Arc::clone(&ds), &pythia_addr, &api_addr);
    println!("[fault] API server restarted on the same WAL ✓");

    println!("[phase 2] {WORKERS} workers x {} trials", TRIALS_PER_WORKER / 2);
    phase(TRIALS_PER_WORKER / 2);
    let wall_s = wall.elapsed().as_secs_f64();

    // --- report --------------------------------------------------------------
    let transport = Box::new(TcpTransport::connect(&api_addr).expect("connect"));
    let mut observer = VizierClient::load_or_create_study(transport, "curve-sim", &config, "obs").unwrap();
    let trials = observer.list_trials().unwrap();
    let best = observer.list_optimal_trials().unwrap().first().cloned().expect("best");
    let n_completed = completed.load(Ordering::Relaxed);
    let n_stopped = stopped_early.load(Ordering::Relaxed);
    let n_steps = steps_run.load(Ordering::Relaxed);
    let full_steps = n_completed * sim.max_steps as u64;
    let avg_suggest_ms =
        suggest_lat_us.load(Ordering::Relaxed) as f64 / suggest_count.load(Ordering::Relaxed).max(1) as f64 / 1e3;

    println!("\n================ distributed_tuning report ================");
    println!("workers                  {WORKERS} (+1 victim, +1 observer)");
    println!("trials in datastore      {}", trials.len());
    println!("trials completed         {n_completed}");
    println!(
        "infeasible trials        {}",
        trials.iter().filter(|t| t.infeasibility_reason.is_some()).count()
    );
    println!("trials stopped early     {n_stopped}");
    println!(
        "training steps saved     {} of {} ({:.1}%)",
        full_steps - n_steps,
        full_steps,
        100.0 * (full_steps - n_steps) as f64 / full_steps.max(1) as f64
    );
    println!("wall time                {wall_s:.2} s");
    println!("trial throughput         {:.1} trials/s", n_completed as f64 / wall_s);
    println!("mean suggest op latency  {avg_suggest_ms:.2} ms (incl. polling)");
    println!(
        "best accuracy            {:.4} (lr={:.5}, layers={}, opt={})",
        best.final_metric("accuracy").unwrap(),
        best.parameters.get_f64("learning_rate").unwrap(),
        best.parameters.get_i64("num_layers").unwrap(),
        best.parameters.get_str("optimizer").unwrap(),
    );
    println!("noise-free plateau @best {:.4}", sim.plateau(&best.parameters));
    println!("\n[service metrics]\n{}", service.metrics.report());

    api.shutdown();
    service.shutdown();
    pythia.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
