//! Pythia: the developer API for implementing optimization algorithms
//! (paper §6). A [`policy::Policy`] executes one suggestion or
//! early-stopping operation; a [`supporter::PolicySupporter`] is the
//! mini-client it uses to read trials and persist state; and
//! [`designer::SerializableDesigner`] + [`designer::DesignerPolicy`] give
//! evolutionary-style algorithms O(1)-per-operation state management via
//! study metadata (§6.3, Code Block 7).

pub mod designer;
pub mod policy;
pub mod runner;
pub mod supporter;

pub use designer::{Designer, DesignerPolicy, SerializableDesigner};
pub use policy::{
    EarlyStopDecision, EarlyStopRequest, MetadataDelta, Policy, PolicyError, SuggestDecision,
    SuggestRequest, SuggestWant, SuggestionGroup,
};
pub use supporter::{DatastoreSupporter, PolicySupporter};
