//! PolicySupporter: the mini-client policies use to read and filter trials
//! and to persist algorithm state (paper §6.2).
//!
//! Policies can meta-learn from *any* study in the database — the
//! transfer-learning capability in Table 1 — via `study_config` /
//! `trials` on arbitrary study names and `list_study_names`.

use super::policy::PolicyError;
use crate::datastore::query::TrialFilter;
use crate::datastore::Datastore;
use crate::pyvizier::converters;
use crate::pyvizier::{Metadata, StudyConfig, Trial};
use crate::wire::messages::{MetadataItem, UnitMetadataUpdate};
use std::sync::Arc;

/// Read/metadata access for policies.
///
/// Implementations are not required to be cheap to construct:
/// `PythiaServer` keeps a pool of `RemoteSupporter`s (each owning one
/// connection to the API server) and checks one out per policy run on
/// its compute pool, so a supporter must tolerate being used from a
/// different thread on every run — `Send + Sync` is load-bearing, not
/// boilerplate.
pub trait PolicySupporter: Send + Sync {
    /// Load any study's configuration (cross-study reads enable transfer
    /// learning).
    fn study_config(&self, study_name: &str) -> Result<StudyConfig, PolicyError>;

    /// Load trials from a study, filtered server-side.
    fn trials(&self, study_name: &str, filter: &TrialFilter) -> Result<Vec<Trial>, PolicyError>;

    /// All study names in the datastore.
    fn list_study_names(&self) -> Result<Vec<String>, PolicyError>;

    /// Persist study-level metadata (upsert per (namespace, key)).
    fn update_study_metadata(&self, study_name: &str, md: &Metadata) -> Result<(), PolicyError>;

    /// Persist trial-level metadata.
    fn update_trial_metadata(
        &self,
        study_name: &str,
        trial_id: u64,
        md: &Metadata,
    ) -> Result<(), PolicyError>;

    /// Number of trials in the study (any state).
    fn trial_count(&self, study_name: &str) -> Result<usize, PolicyError>;
}

/// The standard supporter: reads straight from the datastore (used when the
/// Pythia service runs in the same process as the API service; the
/// remote-Pythia runner wraps RPCs behind this same trait).
pub struct DatastoreSupporter {
    ds: Arc<dyn Datastore>,
}

impl DatastoreSupporter {
    pub fn new(ds: Arc<dyn Datastore>) -> Self {
        Self { ds }
    }
}

fn ds_err(e: crate::datastore::DsError) -> PolicyError {
    PolicyError::Datastore(e.to_string())
}

impl PolicySupporter for DatastoreSupporter {
    fn study_config(&self, study_name: &str) -> Result<StudyConfig, PolicyError> {
        let study = self.ds.get_study(study_name).map_err(ds_err)?;
        Ok(converters::study_config_from_proto(&study.display_name, &study.spec))
    }

    fn trials(&self, study_name: &str, filter: &TrialFilter) -> Result<Vec<Trial>, PolicyError> {
        // Filtered at the datastore (§6.2): only matching trials are
        // cloned/converted, so incremental designer reads are O(new).
        let protos = self.ds.query_trials(study_name, filter).map_err(ds_err)?;
        Ok(protos.iter().map(converters::trial_from_proto).collect())
    }

    fn list_study_names(&self) -> Result<Vec<String>, PolicyError> {
        Ok(self
            .ds
            .list_studies()
            .map_err(ds_err)?
            .into_iter()
            .map(|s| s.name)
            .collect())
    }

    fn update_study_metadata(&self, study_name: &str, md: &Metadata) -> Result<(), PolicyError> {
        let updates: Vec<UnitMetadataUpdate> = md
            .iter()
            .map(|(ns, k, v)| UnitMetadataUpdate {
                trial_id: 0,
                new_trial_index: 0,
                item: Some(MetadataItem {
                    namespace: ns.to_string(),
                    key: k.to_string(),
                    value: v.to_vec(),
                }),
            })
            .collect();
        self.ds.update_metadata(study_name, &updates).map_err(ds_err)
    }

    fn update_trial_metadata(
        &self,
        study_name: &str,
        trial_id: u64,
        md: &Metadata,
    ) -> Result<(), PolicyError> {
        let updates: Vec<UnitMetadataUpdate> = md
            .iter()
            .map(|(ns, k, v)| UnitMetadataUpdate {
                trial_id,
                new_trial_index: 0,
                item: Some(MetadataItem {
                    namespace: ns.to_string(),
                    key: k.to_string(),
                    value: v.to_vec(),
                }),
            })
            .collect();
        self.ds.update_metadata(study_name, &updates).map_err(ds_err)
    }

    fn trial_count(&self, study_name: &str) -> Result<usize, PolicyError> {
        self.ds.trial_count(study_name).map_err(ds_err)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::memory::InMemoryDatastore;
    use crate::pyvizier::{MetricInformation, StudyConfig};
    use crate::wire::messages::{StudyProto, TrialProto, TrialState};

    fn setup() -> (Arc<InMemoryDatastore>, String) {
        let ds = Arc::new(InMemoryDatastore::new());
        let mut config = StudyConfig::new("exp");
        config.add_metric(MetricInformation::maximize("m"));
        let study = ds
            .create_study(StudyProto {
                display_name: "exp".into(),
                spec: crate::pyvizier::converters::study_config_to_proto(&config),
                ..Default::default()
            })
            .unwrap();
        for i in 0..5 {
            let t = ds.create_trial(&study.name, TrialProto::default()).unwrap();
            if i % 2 == 0 {
                ds.mutate_trial(&study.name, t.id, &mut |t| {
                    t.state = TrialState::Completed;
                    Ok(())
                })
                .unwrap();
            }
        }
        (ds, study.name)
    }

    #[test]
    fn reads_config_and_filtered_trials() {
        let (ds, name) = setup();
        let sup = DatastoreSupporter::new(ds);
        let config = sup.study_config(&name).unwrap();
        assert_eq!(config.display_name, "exp");
        let done = sup.trials(&name, &TrialFilter::completed()).unwrap();
        assert_eq!(done.len(), 3);
        let newer = sup.trials(&name, &TrialFilter::completed().newer_than(1)).unwrap();
        assert_eq!(newer.len(), 2);
        assert_eq!(sup.trial_count(&name).unwrap(), 5);
        assert_eq!(sup.list_study_names().unwrap(), vec![name]);
    }

    #[test]
    fn metadata_writes_visible() {
        let (ds, name) = setup();
        let sup = DatastoreSupporter::new(Arc::clone(&ds) as Arc<dyn Datastore>);
        let mut md = Metadata::new();
        md.put_str("evo", "pop", "xyz");
        sup.update_study_metadata(&name, &md).unwrap();
        sup.update_trial_metadata(&name, 1, &md).unwrap();
        let study = ds.get_study(&name).unwrap();
        assert_eq!(study.spec.metadata[0].value, b"xyz");
        assert_eq!(ds.get_trial(&name, 1).unwrap().metadata[0].value, b"xyz");
    }
}
