//! Policy registry and the Pythia execution endpoint (paper §6.1).
//!
//! The API service hands operations to a [`PythiaEndpoint`]. The default
//! [`LocalPythia`] runs policies in-process ("which can be the same binary
//! as the API service"); `service::remote_pythia` provides the
//! separate-service deployment of Figure 2 on top of the same trait.

use super::policy::{
    EarlyStopDecision, EarlyStopRequest, Policy, PolicyError, SuggestDecision, SuggestRequest,
};
use super::supporter::PolicySupporter;
use crate::pyvizier::{Algorithm, StudyConfig};
use std::collections::HashMap;
use std::sync::Arc;

/// Creates a fresh policy object per operation.
pub type Factory = Arc<dyn Fn(&StudyConfig) -> Box<dyn Policy> + Send + Sync>;

/// Maps algorithm names to policy factories. Researchers register custom
/// policies here (the "developer API" entry point).
#[derive(Default, Clone)]
pub struct PolicyRegistry {
    factories: HashMap<String, Factory>,
}

impl PolicyRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) a factory under an algorithm name.
    pub fn register(&mut self, name: &str, factory: Factory) {
        self.factories.insert(name.to_string(), factory);
    }

    pub fn contains(&self, name: &str) -> bool {
        self.factories.contains_key(name)
    }

    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.factories.keys().cloned().collect();
        names.sort();
        names
    }

    /// Instantiate the policy for a study's configured algorithm.
    pub fn create(&self, config: &StudyConfig) -> Result<Box<dyn Policy>, PolicyError> {
        let name = config.algorithm.as_str();
        let factory = self.factories.get(name).ok_or_else(|| {
            PolicyError::Unsupported(format!(
                "no policy registered for algorithm {name:?} (known: {:?})",
                self.names()
            ))
        })?;
        Ok(factory(config))
    }
}

/// Where the service sends suggestion / early-stopping work (batched,
/// Pythia v2): one call serves every want / trial id in the request.
pub trait PythiaEndpoint: Send + Sync {
    fn run_suggest(&self, req: &SuggestRequest) -> Result<SuggestDecision, PolicyError>;
    fn run_early_stop(
        &self,
        req: &EarlyStopRequest,
    ) -> Result<Vec<EarlyStopDecision>, PolicyError>;
}

/// In-process Pythia: create policy, run, drop (one policy object per
/// operation, §6.3).
pub struct LocalPythia {
    registry: PolicyRegistry,
    supporter: Arc<dyn PolicySupporter>,
}

impl LocalPythia {
    pub fn new(registry: PolicyRegistry, supporter: Arc<dyn PolicySupporter>) -> Self {
        Self {
            registry,
            supporter,
        }
    }

    pub fn registry(&self) -> &PolicyRegistry {
        &self.registry
    }
}

impl PythiaEndpoint for LocalPythia {
    fn run_suggest(&self, req: &SuggestRequest) -> Result<SuggestDecision, PolicyError> {
        let mut policy = self.registry.create(&req.study_config)?;
        policy.suggest(req, self.supporter.as_ref())
    }

    fn run_early_stop(
        &self,
        req: &EarlyStopRequest,
    ) -> Result<Vec<EarlyStopDecision>, PolicyError> {
        let mut policy = self.registry.create(&req.study_config)?;
        policy.early_stop(req, self.supporter.as_ref())
    }
}

/// Convenience: a registry pre-populated with every built-in policy.
pub fn default_registry() -> PolicyRegistry {
    let mut registry = PolicyRegistry::new();
    crate::policies::register_builtins(&mut registry);
    registry
}

/// Helper for registering custom algorithms by name.
pub fn algorithm_name(a: &Algorithm) -> &str {
    a.as_str()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pyvizier::{Metadata, MetricInformation, TrialSuggestion};

    struct FixedPolicy;
    impl Policy for FixedPolicy {
        fn suggest(
            &mut self,
            req: &SuggestRequest,
            _s: &dyn PolicySupporter,
        ) -> Result<SuggestDecision, PolicyError> {
            Ok(SuggestDecision::from_flat(
                req,
                vec![TrialSuggestion::default(); req.total_count()],
            ))
        }
    }

    struct NullSupporter;
    impl PolicySupporter for NullSupporter {
        fn study_config(&self, _: &str) -> Result<StudyConfig, PolicyError> {
            Ok(StudyConfig::default())
        }
        fn trials(
            &self,
            _: &str,
            _: &crate::datastore::query::TrialFilter,
        ) -> Result<Vec<crate::pyvizier::Trial>, PolicyError> {
            Ok(vec![])
        }
        fn list_study_names(&self) -> Result<Vec<String>, PolicyError> {
            Ok(vec![])
        }
        fn update_study_metadata(&self, _: &str, _: &Metadata) -> Result<(), PolicyError> {
            Ok(())
        }
        fn update_trial_metadata(&self, _: &str, _: u64, _: &Metadata) -> Result<(), PolicyError> {
            Ok(())
        }
        fn trial_count(&self, _: &str) -> Result<usize, PolicyError> {
            Ok(0)
        }
    }

    #[test]
    fn registry_dispatch() {
        let mut reg = PolicyRegistry::new();
        reg.register("MY_ALGO", Arc::new(|_| Box::new(FixedPolicy)));
        assert!(reg.contains("MY_ALGO"));
        let mut config = StudyConfig::new("t");
        config.add_metric(MetricInformation::maximize("m"));
        config.algorithm = Algorithm::Custom("MY_ALGO".into());
        let pythia = LocalPythia::new(reg, Arc::new(NullSupporter));
        let req = SuggestRequest::single("studies/1", config.clone(), "c", 3);
        let d = pythia.run_suggest(&req).unwrap();
        assert_eq!(d.total(), 3);
        assert_eq!(d.groups.len(), 1);
        assert_eq!(d.groups[0].client_id, "c");

        // Unknown algorithm -> Unsupported.
        config.algorithm = Algorithm::Custom("NOPE".into());
        let req = SuggestRequest {
            study_config: config,
            ..req
        };
        assert!(matches!(
            pythia.run_suggest(&req),
            Err(PolicyError::Unsupported(_))
        ));
    }

    #[test]
    fn default_early_stop_is_never() {
        let mut reg = PolicyRegistry::new();
        reg.register("MY_ALGO", Arc::new(|_| Box::new(FixedPolicy)));
        let pythia = LocalPythia::new(reg, Arc::new(NullSupporter));
        let mut config = StudyConfig::new("t");
        config.algorithm = Algorithm::Custom("MY_ALGO".into());
        let d = pythia
            .run_early_stop(&EarlyStopRequest {
                study_name: "studies/1".into(),
                study_config: config,
                trial_ids: vec![1, 4],
            })
            .unwrap();
        assert_eq!(d.len(), 2);
        assert!(d.iter().all(|x| !x.should_stop));
        assert_eq!(d[0].trial_id, 1);
        assert_eq!(d[1].trial_id, 4);
    }
}
