//! The Policy interface (paper §6.1, Code Block 2).
//!
//! A `Policy` object's lifespan is one suggestion or early-stopping
//! operation (§6.3) — the service constructs a policy, calls it once, and
//! drops it. Long-lived algorithm state must go through metadata (see
//! [`super::designer`]).

use super::supporter::PolicySupporter;
use crate::pyvizier::{Metadata, StudyConfig, TrialSuggestion};

/// Errors a policy can raise; mapped to failed operations by the service.
#[derive(Debug)]
pub enum PolicyError {
    Unsupported(String),
    Datastore(String),
    CorruptState(String),
    Internal(String),
}

impl std::fmt::Display for PolicyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PolicyError::Unsupported(msg) => {
                write!(f, "policy got an unsupported study config: {msg}")
            }
            PolicyError::Datastore(msg) => write!(f, "datastore access failed: {msg}"),
            PolicyError::CorruptState(msg) => write!(f, "policy state corrupt: {msg}"),
            PolicyError::Internal(msg) => write!(f, "internal policy failure: {msg}"),
        }
    }
}

impl std::error::Error for PolicyError {}

/// Request for new suggestions.
#[derive(Debug, Clone)]
pub struct SuggestRequest {
    pub study_name: String,
    pub study_config: StudyConfig,
    pub count: usize,
    /// The requesting worker (paper §5: trials are assigned per client id).
    pub client_id: String,
}

/// A policy's answer to a suggest request.
#[derive(Debug, Clone, Default)]
pub struct SuggestDecision {
    pub suggestions: Vec<TrialSuggestion>,
    /// Study-level metadata writes to persist atomically with the
    /// suggestions (designer state, §6.3).
    pub study_metadata: Option<Metadata>,
}

/// Request for an early-stopping decision on one trial.
#[derive(Debug, Clone)]
pub struct EarlyStopRequest {
    pub study_name: String,
    pub study_config: StudyConfig,
    pub trial_id: u64,
}

/// A policy's early-stopping verdict (paper Appendix B.1).
#[derive(Debug, Clone, Default)]
pub struct EarlyStopDecision {
    pub should_stop: bool,
    pub reason: String,
}

/// A blackbox-optimization algorithm, as seen by the service.
pub trait Policy: Send {
    /// Produce `req.count` suggestions.
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        supporter: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError>;

    /// Decide whether `req.trial_id` should stop early. Default: never.
    fn early_stop(
        &mut self,
        _req: &EarlyStopRequest,
        _supporter: &dyn PolicySupporter,
    ) -> Result<EarlyStopDecision, PolicyError> {
        Ok(EarlyStopDecision::default())
    }

    /// Human-readable policy name (for logs and metrics).
    fn name(&self) -> &str {
        "unnamed-policy"
    }
}

/// A policy factory: constructs a fresh policy per operation (the service
/// never reuses policy objects across operations, matching the paper).
pub type PolicyFactory = Box<dyn Fn(&StudyConfig) -> Box<dyn Policy> + Send + Sync>;
