//! The Policy interface, v2 (paper §6.1, Code Block 2) — batched.
//!
//! A `Policy` object's lifespan is one *batch* of suggestion or
//! early-stopping work (§6.3) — the service constructs a policy, calls it
//! once, and drops it. Long-lived algorithm state must go through metadata
//! (see [`super::designer`]).
//!
//! # What changed from v1 (and why)
//!
//! The v1 surface forced one policy construction + invocation per suggest
//! operation and one RPC per early-stopping check, so K parallel workers
//! on one study paid K policy runs (K GP fits for `GP_BANDIT`) per wave.
//! v2 makes batches first-class so the service can coalesce queued
//! operations of one study into a single invocation:
//!
//! * [`SuggestRequest`] carries a list of [`SuggestWant`]s — one
//!   `(client_id, count)` per waiting operation — instead of a single
//!   `(client_id, count)` pair.
//! * [`SuggestDecision`] returns one [`SuggestionGroup`] per want plus a
//!   unified [`MetadataDelta`] covering study-level **and** trial-level
//!   writes, applied atomically by the service (the v1 field was an
//!   `Option<Metadata>` limited to study metadata).
//! * [`EarlyStopRequest`] names many trials (`trial_ids`; empty = "all
//!   ACTIVE trials"), and `Policy::early_stop` returns one
//!   [`EarlyStopDecision`] per trial.
//!
//! # Migrating a Policy from v1 to v2
//!
//! Most v1 policies generated `req.count` suggestions from shared state
//! and did not care which client asked. Such policies migrate in two
//! lines: generate [`SuggestRequest::total_count`] suggestions, then let
//! [`SuggestDecision::from_flat`] split them across the wants in order:
//!
//! ```ignore
//! // v1
//! fn suggest(&mut self, req: &SuggestRequest, s: &dyn PolicySupporter)
//!     -> Result<SuggestDecision, PolicyError> {
//!     let suggestions = (0..req.count).map(|_| self.draw()).collect();
//!     Ok(SuggestDecision { suggestions, study_metadata: None })
//! }
//!
//! // v2
//! fn suggest(&mut self, req: &SuggestRequest, s: &dyn PolicySupporter)
//!     -> Result<SuggestDecision, PolicyError> {
//!     let suggestions = (0..req.total_count()).map(|_| self.draw()).collect();
//!     Ok(SuggestDecision::from_flat(req, suggestions))
//! }
//! ```
//!
//! Policies that want per-client behaviour (e.g. per-worker arms) can
//! build the groups themselves; the service assigns group *i* to want
//! *i*. Metadata writes move from `study_metadata: Some(md)` to
//! `decision.metadata_delta.on_study = md`, and trial-level state (which
//! v1 could only write through the supporter, outside the operation's
//! atomic commit) goes in `metadata_delta.on_trials`.
//!
//! For early stopping, a v1 `early_stop` looked at `req.trial_id`; a v2
//! implementation loops over `req.trial_ids` (resolving an empty list to
//! the study's ACTIVE trials via the supporter if it cares) and returns a
//! decision per trial. The default still never stops anything.

use super::supporter::PolicySupporter;
use crate::pyvizier::{Metadata, StudyConfig, TrialSuggestion};
use crate::wire::messages::{MetadataItem, TrialStopDecision, UnitMetadataUpdate};
use std::collections::BTreeMap;

/// Errors a policy can raise; mapped to failed operations by the service.
#[derive(Debug)]
pub enum PolicyError {
    Unsupported(String),
    Datastore(String),
    CorruptState(String),
    Internal(String),
}

impl std::fmt::Display for PolicyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PolicyError::Unsupported(msg) => {
                write!(f, "policy got an unsupported study config: {msg}")
            }
            PolicyError::Datastore(msg) => write!(f, "datastore access failed: {msg}"),
            PolicyError::CorruptState(msg) => write!(f, "policy state corrupt: {msg}"),
            PolicyError::Internal(msg) => write!(f, "internal policy failure: {msg}"),
        }
    }
}

impl std::error::Error for PolicyError {}

/// One waiting operation's ask: `count` suggestions for `client_id`
/// (paper §5: trials are assigned per client id).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SuggestWant {
    pub client_id: String,
    pub count: usize,
}

/// Request for new suggestions on behalf of one or more clients.
#[derive(Debug, Clone)]
pub struct SuggestRequest {
    pub study_name: String,
    pub study_config: StudyConfig,
    /// One entry per coalesced operation. Never empty in service calls.
    pub wants: Vec<SuggestWant>,
}

impl SuggestRequest {
    /// The common single-client request (v1 shape).
    pub fn single(
        study_name: impl Into<String>,
        study_config: StudyConfig,
        client_id: impl Into<String>,
        count: usize,
    ) -> Self {
        Self {
            study_name: study_name.into(),
            study_config,
            wants: vec![SuggestWant {
                client_id: client_id.into(),
                count,
            }],
        }
    }

    /// Total number of suggestions requested across all wants.
    pub fn total_count(&self) -> usize {
        self.wants.iter().map(|w| w.count).sum()
    }
}

/// Study-level and trial-level metadata writes the service applies as one
/// atomic datastore batch when the operation(s) complete (§6.3: the two
/// metadata tables).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetadataDelta {
    /// Writes to the study's metadata table (designer state lives here).
    pub on_study: Metadata,
    /// Writes to individual trials' metadata, keyed by trial id. Trial
    /// ids must refer to *existing* trials — suggestions returned in the
    /// same decision are addressed through `on_new_trials` instead.
    pub on_trials: BTreeMap<u64, Metadata>,
    /// Writes to the trials *being suggested in this decision*, keyed by
    /// the suggestion's position in the decision (flattened across
    /// groups, in want order). The suggestions have no trial ids yet;
    /// the service resolves each index to the id the datastore assigned
    /// at registration and persists these atomically with the batch's
    /// delta — before any operation completes.
    pub on_new_trials: BTreeMap<usize, Metadata>,
}

impl MetadataDelta {
    /// A delta with only study-level writes (the v1 `study_metadata`).
    pub fn for_study(md: Metadata) -> Self {
        Self {
            on_study: md,
            ..Default::default()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.on_study.is_empty()
            && self.on_trials.values().all(|m| m.is_empty())
            && self.on_new_trials.values().all(|m| m.is_empty())
    }

    /// Flatten to the wire representation (`trial_id == 0` targets the
    /// study table).
    pub fn to_updates(&self) -> Vec<UnitMetadataUpdate> {
        let mut out = Vec::new();
        for (ns, k, v) in self.on_study.iter() {
            out.push(UnitMetadataUpdate {
                trial_id: 0,
                new_trial_index: 0,
                item: Some(MetadataItem {
                    namespace: ns.to_string(),
                    key: k.to_string(),
                    value: v.to_vec(),
                }),
            });
        }
        for (trial_id, md) in &self.on_trials {
            for (ns, k, v) in md.iter() {
                out.push(UnitMetadataUpdate {
                    trial_id: *trial_id,
                    new_trial_index: 0,
                    item: Some(MetadataItem {
                        namespace: ns.to_string(),
                        key: k.to_string(),
                        value: v.to_vec(),
                    }),
                });
            }
        }
        // Placeholder writes: `new_trial_index` is the 1-based flattened
        // suggestion position (0 = unset), resolved service-side.
        for (idx, md) in &self.on_new_trials {
            for (ns, k, v) in md.iter() {
                out.push(UnitMetadataUpdate {
                    trial_id: 0,
                    new_trial_index: (*idx as u64) + 1,
                    item: Some(MetadataItem {
                        namespace: ns.to_string(),
                        key: k.to_string(),
                        value: v.to_vec(),
                    }),
                });
            }
        }
        out
    }

    /// Rebuild from the wire representation.
    pub fn from_updates(updates: &[UnitMetadataUpdate]) -> Self {
        let mut delta = Self::default();
        for u in updates {
            let Some(item) = &u.item else { continue };
            let target = if u.new_trial_index > 0 {
                delta
                    .on_new_trials
                    .entry((u.new_trial_index - 1) as usize)
                    .or_default()
            } else if u.trial_id == 0 {
                &mut delta.on_study
            } else {
                delta.on_trials.entry(u.trial_id).or_default()
            };
            target.put(&item.namespace, &item.key, item.value.clone());
        }
        delta
    }
}

/// The suggestions produced for one want (one coalesced operation).
#[derive(Debug, Clone, Default)]
pub struct SuggestionGroup {
    pub client_id: String,
    pub suggestions: Vec<TrialSuggestion>,
}

/// A policy's answer to a (possibly coalesced) suggest request. Group *i*
/// answers want *i* of the request.
#[derive(Debug, Clone, Default)]
pub struct SuggestDecision {
    pub groups: Vec<SuggestionGroup>,
    pub metadata_delta: MetadataDelta,
}

impl SuggestDecision {
    /// Partition a flat batch of suggestions across `req.wants` in order.
    /// This is the standard migration path for policies that draw from
    /// shared state and don't differentiate clients. If `suggestions`
    /// runs short (e.g. an exhausted grid), later groups come up short;
    /// any surplus goes to the last group.
    pub fn from_flat(req: &SuggestRequest, suggestions: Vec<TrialSuggestion>) -> Self {
        let mut groups: Vec<SuggestionGroup> = req
            .wants
            .iter()
            .map(|w| SuggestionGroup {
                client_id: w.client_id.clone(),
                suggestions: Vec::with_capacity(w.count),
            })
            .collect();
        let mut it = suggestions.into_iter();
        for (group, want) in groups.iter_mut().zip(&req.wants) {
            for _ in 0..want.count {
                match it.next() {
                    Some(s) => group.suggestions.push(s),
                    None => break,
                }
            }
        }
        if let Some(last) = groups.last_mut() {
            last.suggestions.extend(it);
        }
        Self {
            groups,
            metadata_delta: MetadataDelta::default(),
        }
    }

    /// Attach a metadata delta (builder style).
    pub fn with_delta(mut self, delta: MetadataDelta) -> Self {
        self.metadata_delta = delta;
        self
    }

    /// Total suggestions across all groups.
    pub fn total(&self) -> usize {
        self.groups.iter().map(|g| g.suggestions.len()).sum()
    }

    /// Collapse the groups back into one flat list (tests, benches, and
    /// single-want callers).
    pub fn flatten(self) -> Vec<TrialSuggestion> {
        self.groups.into_iter().flat_map(|g| g.suggestions).collect()
    }
}

/// Request for early-stopping decisions on a batch of trials.
#[derive(Debug, Clone)]
pub struct EarlyStopRequest {
    pub study_name: String,
    pub study_config: StudyConfig,
    /// Trials to judge. Empty = "every ACTIVE trial of the study" (the
    /// service resolves the list before invoking a policy, so policies
    /// normally see explicit ids).
    pub trial_ids: Vec<u64>,
}

/// A policy's early-stopping verdict for one trial (paper Appendix B.1).
#[derive(Debug, Clone, Default)]
pub struct EarlyStopDecision {
    pub trial_id: u64,
    pub should_stop: bool,
    pub reason: String,
}

impl EarlyStopDecision {
    pub fn keep(trial_id: u64) -> Self {
        Self {
            trial_id,
            ..Default::default()
        }
    }

    pub fn stop(trial_id: u64, reason: impl Into<String>) -> Self {
        Self {
            trial_id,
            should_stop: true,
            reason: reason.into(),
        }
    }
}

// EarlyStopDecision <-> wire::TrialStopDecision: same shape, one place to
// keep them in sync (the service and both remote-Pythia ends convert
// through these).
impl From<EarlyStopDecision> for TrialStopDecision {
    fn from(d: EarlyStopDecision) -> Self {
        Self {
            trial_id: d.trial_id,
            should_stop: d.should_stop,
            reason: d.reason,
        }
    }
}

impl From<&EarlyStopDecision> for TrialStopDecision {
    fn from(d: &EarlyStopDecision) -> Self {
        Self {
            trial_id: d.trial_id,
            should_stop: d.should_stop,
            reason: d.reason.clone(),
        }
    }
}

impl From<TrialStopDecision> for EarlyStopDecision {
    fn from(d: TrialStopDecision) -> Self {
        Self {
            trial_id: d.trial_id,
            should_stop: d.should_stop,
            reason: d.reason,
        }
    }
}

/// A blackbox-optimization algorithm, as seen by the service.
pub trait Policy: Send {
    /// Produce suggestions for every want in `req` (group *i* answers
    /// want *i*); [`SuggestDecision::from_flat`] implements the common
    /// "draw `total_count`, split in order" shape.
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        supporter: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError>;

    /// Decide, per trial in `req.trial_ids`, whether it should stop
    /// early. Default: never stop anything.
    fn early_stop(
        &mut self,
        req: &EarlyStopRequest,
        _supporter: &dyn PolicySupporter,
    ) -> Result<Vec<EarlyStopDecision>, PolicyError> {
        Ok(req.trial_ids.iter().map(|&id| EarlyStopDecision::keep(id)).collect())
    }

    /// Human-readable policy name (for logs and metrics).
    fn name(&self) -> &str {
        "unnamed-policy"
    }
}

/// A policy factory: constructs a fresh policy per batch (the service
/// never reuses policy objects across operations, matching the paper).
pub type PolicyFactory = Box<dyn Fn(&StudyConfig) -> Box<dyn Policy> + Send + Sync>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pyvizier::ParameterDict;

    fn req(counts: &[usize]) -> SuggestRequest {
        SuggestRequest {
            study_name: "studies/1".into(),
            study_config: StudyConfig::default(),
            wants: counts
                .iter()
                .enumerate()
                .map(|(i, &count)| SuggestWant {
                    client_id: format!("c{i}"),
                    count,
                })
                .collect(),
        }
    }

    fn tagged(n: usize) -> Vec<TrialSuggestion> {
        (0..n)
            .map(|i| {
                let mut p = ParameterDict::new();
                p.set("i", i as i64);
                TrialSuggestion::new(p)
            })
            .collect()
    }

    #[test]
    fn from_flat_partitions_in_want_order() {
        let r = req(&[2, 1, 3]);
        assert_eq!(r.total_count(), 6);
        let d = SuggestDecision::from_flat(&r, tagged(6));
        assert_eq!(d.groups.len(), 3);
        assert_eq!(d.groups[0].client_id, "c0");
        assert_eq!(d.groups[0].suggestions.len(), 2);
        assert_eq!(d.groups[1].suggestions.len(), 1);
        assert_eq!(d.groups[2].suggestions.len(), 3);
        // Order preserved: want 1 gets the third draw.
        assert_eq!(d.groups[1].suggestions[0].parameters.get_i64("i"), Some(2));
        assert_eq!(d.total(), 6);
        assert_eq!(d.flatten().len(), 6);
    }

    #[test]
    fn from_flat_short_and_surplus() {
        // Short: later wants come up empty-handed.
        let d = SuggestDecision::from_flat(&req(&[2, 2]), tagged(3));
        assert_eq!(d.groups[0].suggestions.len(), 2);
        assert_eq!(d.groups[1].suggestions.len(), 1);
        // Surplus: extras land in the last group.
        let d = SuggestDecision::from_flat(&req(&[1, 1]), tagged(4));
        assert_eq!(d.groups[0].suggestions.len(), 1);
        assert_eq!(d.groups[1].suggestions.len(), 3);
    }

    #[test]
    fn metadata_delta_roundtrips_through_updates() {
        let mut delta = MetadataDelta::default();
        delta.on_study.put_str("designer.x", "state", "s");
        delta.on_trials.entry(7).or_default().put_str("ns", "k", "v");
        delta.on_trials.entry(9).or_default().put("ns", "b", vec![1u8, 2]);
        delta.on_new_trials.entry(0).or_default().put_str("ns", "seed", "a");
        delta.on_new_trials.entry(2).or_default().put_str("ns", "seed", "c");
        assert!(!delta.is_empty());
        let updates = delta.to_updates();
        assert_eq!(updates.len(), 5);
        assert!(updates.iter().any(|u| u.trial_id == 0 && u.new_trial_index == 0));
        // Placeholder entries carry the 1-based index, never a trial id.
        assert!(updates.iter().any(|u| u.new_trial_index == 1));
        assert!(updates.iter().any(|u| u.new_trial_index == 3));
        let back = MetadataDelta::from_updates(&updates);
        assert_eq!(back, delta);
    }

    #[test]
    fn empty_delta_is_empty() {
        assert!(MetadataDelta::default().is_empty());
        assert!(MetadataDelta::for_study(Metadata::new()).is_empty());
        let mut md = Metadata::new();
        md.put_str("a", "b", "c");
        assert!(!MetadataDelta::for_study(md).is_empty());
    }
}
