//! Designers and metadata-backed state management (paper §6.3, Code
//! Block 7).
//!
//! A [`Designer`] is the natural shape of evolutionary/local-search
//! algorithms: it sequentially `update`s internal state with newly
//! completed trials and `suggest`s mutations. Because a Pythia policy
//! object lives for exactly one operation, a naive wrapper would rebuild
//! designer state from *all* trials on every operation — O(n) per
//! suggestion. [`DesignerPolicy`] instead persists the designer's state
//! into study metadata ([`SerializableDesigner::dump`]) and restores it
//! with [`SerializableDesigner::recover`], reading only trials newer than
//! the last one seen — O(new trials) per operation, the paper's
//! "orders of magnitude" database-work reduction.
//!
//! [`StatelessDesignerPolicy`] is the deliberately-naive wrapper, kept as
//! the baseline for the §6.3 benchmark (`benches/bench_state_recovery.rs`).

use super::policy::{MetadataDelta, Policy, PolicyError, SuggestDecision, SuggestRequest};
use super::supporter::PolicySupporter;
use crate::datastore::query::TrialFilter;
use crate::pyvizier::{Metadata, StudyConfig, Trial, TrialSuggestion};

/// An algorithm that incrementally updates internal state.
pub trait Designer: Send {
    /// Incorporate newly completed trials.
    fn update(&mut self, completed: &[Trial]);

    /// Produce `count` new suggestions.
    fn suggest(&mut self, count: usize) -> Result<Vec<TrialSuggestion>, PolicyError>;
}

/// A designer whose state can be dumped to / recovered from metadata.
pub trait SerializableDesigner: Designer {
    /// Stable name; used as the metadata namespace.
    fn designer_name() -> &'static str
    where
        Self: Sized;

    /// Construct a fresh designer for a study.
    fn from_config(config: &StudyConfig) -> Result<Self, PolicyError>
    where
        Self: Sized;

    /// Serialize internal state (e.g. the population pool) to metadata.
    fn dump(&self) -> Metadata;

    /// Restore from metadata. Returning an error is *harmless*: the
    /// wrapper falls back to a fresh designer + full replay.
    fn recover(config: &StudyConfig, md: &Metadata) -> Result<Self, PolicyError>
    where
        Self: Sized;
}

const LAST_SEEN_KEY: &str = "last_seen_trial_id";

fn namespace<D: SerializableDesigner>() -> String {
    format!("designer.{}", D::designer_name())
}

/// Policy wrapper with metadata state saving (the paper's
/// `SerializableDesignerPolicy`).
pub struct DesignerPolicy<D: SerializableDesigner> {
    _marker: std::marker::PhantomData<fn() -> D>,
}

impl<D: SerializableDesigner> Default for DesignerPolicy<D> {
    fn default() -> Self {
        Self::new()
    }
}

impl<D: SerializableDesigner> DesignerPolicy<D> {
    pub fn new() -> Self {
        Self {
            _marker: std::marker::PhantomData,
        }
    }
}

impl<D: SerializableDesigner + 'static> Policy for DesignerPolicy<D> {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        supporter: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        let ns = namespace::<D>();
        // Re-read the config so we see the latest stored metadata. The
        // wrapper always writes LAST_SEEN_KEY, so its presence marks a
        // stored state regardless of which keys the designer dumps.
        let config = supporter.study_config(&req.study_name)?;
        let stored = config.metadata.get_str(&ns, LAST_SEEN_KEY);
        let last_seen: u64 = stored.and_then(|s| s.parse().ok()).unwrap_or(0);

        // Try to restore; a recovery error is harmless and triggers a full
        // rebuild (paper: HarmlessDecodeError).
        let (mut designer, mut seen) = match stored {
            Some(_) => {
                let mut md = Metadata::new();
                // Copy the designer's namespace into a bare view for recover().
                for (k, v) in config.metadata.ns(&ns) {
                    md.put("", k, v.to_vec());
                }
                match D::recover(&config, &md) {
                    Ok(d) => (d, last_seen),
                    Err(_) => (D::from_config(&config)?, 0),
                }
            }
            None => (D::from_config(&config)?, 0),
        };

        // Reflect only trials the stored state has not seen (O(new)).
        let fresh = supporter.trials(&req.study_name, &TrialFilter::completed().newer_than(seen))?;
        if !fresh.is_empty() {
            seen = fresh.iter().map(|t| t.id).max().unwrap().max(seen);
            designer.update(&fresh);
        }

        // One designer pass serves every coalesced want (the batching win:
        // state is restored and updated once, not once per operation).
        let suggestions = designer.suggest(req.total_count())?;

        // Persist state under the designer's namespace.
        let mut out = Metadata::new();
        for (_, k, v) in designer.dump().iter() {
            out.put(&ns, k, v.to_vec());
        }
        out.put_str(&ns, LAST_SEEN_KEY, &seen.to_string());
        Ok(SuggestDecision::from_flat(req, suggestions).with_delta(MetadataDelta::for_study(out)))
    }

    fn name(&self) -> &str {
        "designer-policy"
    }
}

/// The naive wrapper: rebuilds the designer from scratch on every
/// operation (no metadata). Baseline for the §6.3 benchmark.
pub struct StatelessDesignerPolicy<D: SerializableDesigner> {
    _marker: std::marker::PhantomData<fn() -> D>,
}

impl<D: SerializableDesigner> Default for StatelessDesignerPolicy<D> {
    fn default() -> Self {
        Self {
            _marker: std::marker::PhantomData,
        }
    }
}

impl<D: SerializableDesigner + 'static> Policy for StatelessDesignerPolicy<D> {
    fn suggest(
        &mut self,
        req: &SuggestRequest,
        supporter: &dyn PolicySupporter,
    ) -> Result<SuggestDecision, PolicyError> {
        let config = supporter.study_config(&req.study_name)?;
        let mut designer = D::from_config(&config)?;
        // Full O(n) replay of every completed trial.
        let all = supporter.trials(&req.study_name, &TrialFilter::completed())?;
        designer.update(&all);
        let suggestions = designer.suggest(req.total_count())?;
        Ok(SuggestDecision::from_flat(req, suggestions))
    }

    fn name(&self) -> &str {
        "stateless-designer-policy"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::memory::InMemoryDatastore;
    use crate::datastore::Datastore;
    use crate::pythia::supporter::DatastoreSupporter;
    use crate::pyvizier::{converters, MetricInformation, ParameterDict};
    use crate::wire::messages::{StudyProto, TrialProto, TrialState};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    static REBUILDS: AtomicUsize = AtomicUsize::new(0);

    /// A designer that counts how many trials it has absorbed; its state is
    /// that single number, so recovery is trivially checkable.
    struct CountingDesigner {
        absorbed: usize,
    }

    impl Designer for CountingDesigner {
        fn update(&mut self, completed: &[Trial]) {
            self.absorbed += completed.len();
        }
        fn suggest(&mut self, count: usize) -> Result<Vec<TrialSuggestion>, PolicyError> {
            Ok((0..count)
                .map(|_| {
                    let mut p = ParameterDict::new();
                    p.set("absorbed", self.absorbed as i64);
                    TrialSuggestion::new(p)
                })
                .collect())
        }
    }

    impl SerializableDesigner for CountingDesigner {
        fn designer_name() -> &'static str {
            "counting"
        }
        fn from_config(_config: &StudyConfig) -> Result<Self, PolicyError> {
            REBUILDS.fetch_add(1, Ordering::SeqCst);
            Ok(Self { absorbed: 0 })
        }
        fn dump(&self) -> Metadata {
            let mut md = Metadata::new();
            md.put_str("", "state", &self.absorbed.to_string());
            md
        }
        fn recover(_config: &StudyConfig, md: &Metadata) -> Result<Self, PolicyError> {
            let absorbed = md
                .get_str("", "state")
                .ok_or_else(|| PolicyError::CorruptState("missing".into()))?
                .parse()
                .map_err(|_| PolicyError::CorruptState("not a number".into()))?;
            Ok(Self { absorbed })
        }
    }

    fn setup() -> (Arc<InMemoryDatastore>, String, StudyConfig) {
        let ds = Arc::new(InMemoryDatastore::new());
        let mut config = StudyConfig::new("exp");
        config.add_metric(MetricInformation::maximize("m"));
        let study = ds
            .create_study(StudyProto {
                display_name: "exp".into(),
                spec: converters::study_config_to_proto(&config),
                ..Default::default()
            })
            .unwrap();
        (ds, study.name, config)
    }

    fn add_completed(ds: &InMemoryDatastore, study: &str, n: usize) {
        for _ in 0..n {
            let t = ds.create_trial(study, TrialProto::default()).unwrap();
            ds.mutate_trial(study, t.id, &mut |t| {
                t.state = TrialState::Completed;
                Ok(())
            })
            .unwrap();
        }
    }

    /// Run one suggest op and persist the returned metadata the way the
    /// service does.
    fn run_op(
        policy: &mut dyn Policy,
        sup: &DatastoreSupporter,
        study: &str,
        config: &StudyConfig,
    ) -> Vec<TrialSuggestion> {
        let req = SuggestRequest::single(study, config.clone(), "c", 1);
        let decision = policy.suggest(&req, sup).unwrap();
        if !decision.metadata_delta.on_study.is_empty() {
            sup.update_study_metadata(study, &decision.metadata_delta.on_study)
                .unwrap();
        }
        decision.flatten()
    }

    #[test]
    fn designer_state_persists_across_operations() {
        let (ds, study, config) = setup();
        let sup = DatastoreSupporter::new(Arc::clone(&ds) as Arc<dyn Datastore>);
        REBUILDS.store(0, Ordering::SeqCst);

        add_completed(&ds, &study, 3);
        let mut policy = DesignerPolicy::<CountingDesigner>::new();
        let d1 = run_op(&mut policy, &sup, &study, &config);
        assert_eq!(d1[0].parameters.get_i64("absorbed"), Some(3));
        assert_eq!(REBUILDS.load(Ordering::SeqCst), 1, "first op builds fresh");

        // Second operation: 2 new trials; state restored, only new absorbed.
        add_completed(&ds, &study, 2);
        let mut policy = DesignerPolicy::<CountingDesigner>::new();
        let d2 = run_op(&mut policy, &sup, &study, &config);
        assert_eq!(d2[0].parameters.get_i64("absorbed"), Some(5));
        assert_eq!(REBUILDS.load(Ordering::SeqCst), 1, "no rebuild on second op");
    }

    #[test]
    fn corrupt_state_triggers_harmless_rebuild() {
        let (ds, study, config) = setup();
        let sup = DatastoreSupporter::new(Arc::clone(&ds) as Arc<dyn Datastore>);
        add_completed(&ds, &study, 4);
        let mut policy = DesignerPolicy::<CountingDesigner>::new();
        run_op(&mut policy, &sup, &study, &config);

        // Corrupt the stored state.
        let mut bad = Metadata::new();
        bad.put_str("designer.counting", "state", "not-a-number");
        sup.update_study_metadata(&study, &bad).unwrap();

        REBUILDS.store(0, Ordering::SeqCst);
        let mut policy = DesignerPolicy::<CountingDesigner>::new();
        let d = run_op(&mut policy, &sup, &study, &config);
        assert_eq!(REBUILDS.load(Ordering::SeqCst), 1, "rebuild after corrupt state");
        // Rebuild replays all 4 trials.
        assert_eq!(d[0].parameters.get_i64("absorbed"), Some(4));
    }

    #[test]
    fn stateless_policy_always_rebuilds() {
        let (ds, study, config) = setup();
        let sup = DatastoreSupporter::new(Arc::clone(&ds) as Arc<dyn Datastore>);
        add_completed(&ds, &study, 3);
        REBUILDS.store(0, Ordering::SeqCst);
        let mut policy = StatelessDesignerPolicy::<CountingDesigner>::default();
        run_op(&mut policy, &sup, &study, &config);
        run_op(&mut policy, &sup, &study, &config);
        assert_eq!(REBUILDS.load(Ordering::SeqCst), 2);
    }
}
