//! The Vizier service implementation: every RPC method of §3.2 over a
//! pluggable datastore and Pythia endpoint.
//!
//! The suggestion workflow reproduces the paper, plus per-study operation
//! coalescing (Pythia v2):
//! 1. `suggest_trials` persists an [`OperationProto`], pushes it onto the
//!    study's pending-suggest queue, and kicks a batch runner on a worker
//!    thread, returning the operation immediately.
//! 2. Clients poll `get_operation` until `done`.
//! 3. The batch runner drains *every* queued suggest operation for the
//!    study, runs **one** Pythia policy invocation for the combined wants,
//!    partitions the returned suggestion groups back onto the operations
//!    (trials registered ACTIVE, assigned to each op's `client_id`),
//!    persists the unified metadata delta atomically, and completes each
//!    operation individually. K queued operations on one study therefore
//!    cost one policy run (one GP fit) instead of K.
//! 4. On startup, [`VizierService::resume_pending_operations`] re-queues
//!    operations that were interrupted by a crash (server-side fault
//!    tolerance) — re-coalescing them without double-serving anything
//!    already queued or in flight.
//! 5. ACTIVE trials already assigned to a client are returned *before* new
//!    suggestions are computed (client-side fault tolerance, §5).
//!
//! Locks here are registered with [`crate::util::sync::classes`]
//! (`service.coalesce`, then `service.op_waiters`, then
//! `service.worker_pool`, all below the datastore ranks) and checked
//! under lockdep; the full hierarchy lives in `rust/docs/INVARIANTS.md`.

use crate::datastore::{Datastore, DsError};
use crate::pythia::policy::{EarlyStopRequest, SuggestRequest, SuggestWant};
use crate::pythia::runner::PythiaEndpoint;
use crate::pyvizier::{converters, StudyConfig, TrialSuggestion};
use crate::service::metrics::ServiceMetrics;
use crate::util::sync::{classes, Mutex};
use crate::util::threadpool::ThreadPool;
use crate::util::time::epoch_millis;
use crate::util::trace;
use crate::wire::framing::Status;
use crate::wire::messages::*;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Service-level error: an RPC status plus message.
#[derive(Debug, Clone)]
pub struct ApiError {
    pub status: Status,
    pub message: String,
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}: {}", self.status, self.message)
    }
}

impl std::error::Error for ApiError {}

impl ApiError {
    pub fn invalid(msg: impl Into<String>) -> Self {
        Self {
            status: Status::InvalidArgument,
            message: msg.into(),
        }
    }

    pub fn failed_precondition(msg: impl Into<String>) -> Self {
        Self {
            status: Status::FailedPrecondition,
            message: msg.into(),
        }
    }
}

impl From<DsError> for ApiError {
    fn from(e: DsError) -> Self {
        let status = match &e {
            DsError::StudyNotFound(_) | DsError::TrialNotFound(..) | DsError::OperationNotFound(_) => {
                Status::NotFound
            }
            DsError::StudyExists(_) => Status::FailedPrecondition,
            DsError::Invalid(_) => Status::InvalidArgument,
            DsError::Storage(_) => Status::Internal,
        };
        Self {
            status,
            message: e.to_string(),
        }
    }
}

pub type ApiResult<T> = Result<T, ApiError>;

/// Pending-suggest bookkeeping for per-study operation coalescing.
///
/// `queued` holds persisted-but-unclaimed suggest operation names per
/// study; `claimed` holds operation names currently being served by a
/// batch runner. A name lives in at most one of the two, which is what
/// lets [`VizierService::resume_pending_operations`] re-queue
/// crash-interrupted work without double-serving an operation that is
/// already queued or in flight.
#[derive(Default)]
struct CoalesceState {
    queued: HashMap<String, Vec<String>>,
    claimed: HashSet<String>,
    /// Trace context of the request that queued each operation (absent
    /// for unsampled requests and crash-resumed ops). Claimed along
    /// with the name so the one policy span a coalesced batch produces
    /// can fan into every waiting request's trace.
    ctxs: HashMap<String, trace::TraceCtx>,
}

/// Releases a batch's claims even if the policy panics (the worker pool
/// catches unwinds): leaked claims would leave the batch's ops
/// permanently unservable — queue admission and resume both refuse
/// claimed names.
struct ClaimGuard<'a> {
    coalesce: &'a Mutex<CoalesceState>,
    names: &'a [String],
}

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        let mut state = self.coalesce.lock();
        for name in self.names {
            state.claimed.remove(name);
        }
    }
}

/// Claim a study's whole pending queue (or only its oldest entry when
/// `coalescing` is off). Returns the claimed names with the trace
/// context each op was queued under, empty when the study had nothing
/// queued.
fn claim_batch(
    coalesce: &Mutex<CoalesceState>,
    study_name: &str,
    coalescing: bool,
) -> (Vec<String>, HashMap<String, trace::TraceCtx>) {
    let state = &mut *coalesce.lock();
    let Some(q) = state.queued.get_mut(study_name) else {
        return (Vec::new(), HashMap::new()); // another worker already drained this study
    };
    let batch = if coalescing {
        std::mem::take(q)
    } else if q.is_empty() {
        Vec::new()
    } else {
        vec![q.remove(0)]
    };
    if q.is_empty() {
        state.queued.remove(study_name);
    }
    state.claimed.extend(batch.iter().cloned());
    let ctxs = batch.iter().filter_map(|n| state.ctxs.remove(n).map(|c| (n.clone(), c))).collect();
    (batch, ctxs)
}

/// A parked completion callback: fired exactly once, with the final
/// operation, when it completes.
pub type OpWaiter = Box<dyn FnOnce(&OperationProto) + Send>;

/// A streaming watcher (wire v2 `WaitOperation`): invoked with every
/// observed operation state — the registration snapshot, each
/// intermediate change, and the final `done` state. Returning `false`
/// unregisters it. Callbacks run *under* the registry lock (rank
/// `service.op_waiters` → `frontend.mux_corrs` → `frontend.mux_out` is
/// ascending, and v2 stream sends never block — they buffer and park),
/// which is what makes the watch/complete interleaving race-free without
/// a second handshake.
pub type OpStream = Box<dyn FnMut(&OperationProto) -> bool + Send>;

/// Registry of operation watchers (op name -> parked waiters), the
/// server half of `WaitOperation`: instead of clients busy-polling
/// `GetOperation`, a waiter parks here and [`fire`](Self::fire) wakes it
/// the instant the policy result lands. Waiters for operations that
/// complete through crash-resume are fired by the same path — the
/// resume batch runner completes operations exactly like a live one, so
/// re-arming after a restart is just watching again.
///
/// Waiters are keyed by id so a long-poll that times out can disarm
/// itself ([`VizierService::unwatch_operation`]) instead of leaving a
/// stale closure to fire at completion. Deferred front-end waiters
/// cannot be disarmed by the event-loop sweep (it is service-agnostic);
/// those fire into a dead ticket as a no-op and are bounded by the
/// operation's lifetime.
struct WaiterMap {
    /// One-shot long-poll waiters (v1 `WaitOperation`).
    once: HashMap<String, Vec<(u64, OpWaiter)>>,
    /// Streaming watchers (v2 `WaitOperation`): op name -> stream id ->
    /// callback, fed every state change until `done` or deregistration.
    streams: HashMap<String, HashMap<u64, OpStream>>,
}

struct OpWaiters {
    map: Mutex<WaiterMap>,
    next_id: AtomicU64,
}

impl Default for OpWaiters {
    fn default() -> Self {
        Self {
            map: Mutex::new(
                &classes::SVC_WAITERS,
                WaiterMap {
                    once: HashMap::new(),
                    streams: HashMap::new(),
                },
            ),
            next_id: AtomicU64::new(0),
        }
    }
}

impl OpWaiters {
    /// Fire-and-remove every watcher parked on `op.name`. Stream
    /// callbacks get the final state under the registry lock (see
    /// [`OpStream`]); one-shot waiters run outside it (they enqueue
    /// front-end write jobs or send on channels; neither may deadlock
    /// against a concurrent [`VizierService::watch_operation`]).
    fn fire(&self, op: &OperationProto, metrics: &ServiceMetrics) {
        let once = {
            let mut map = self.map.lock();
            if let Some(streams) = map.streams.remove(&op.name) {
                for (_, mut cb) in streams {
                    let _ = cb(op);
                    metrics.dec_watch_streams();
                }
            }
            map.once.remove(&op.name)
        };
        if let Some(ws) = once {
            for (_, w) in ws {
                w(op);
            }
        }
    }
}

/// Outcome of [`VizierService::watch_operation`].
pub enum WatchResult {
    /// Already done — the waiter was dropped unused.
    Done(OperationProto),
    /// Armed; the id disarms it via
    /// [`VizierService::unwatch_operation`] if the caller stops
    /// listening before completion.
    Parked(u64),
}

/// Server-side cap on one `WaitOperation` long-poll; clients chunk
/// longer waits into successive calls.
pub const MAX_WAIT_MS: u64 = 60_000;
/// Long-poll duration when the request leaves `timeout_ms` zero.
pub const DEFAULT_WAIT_MS: u64 = 20_000;

/// Clamp a requested `WaitOperation` timeout to the server policy.
pub fn effective_wait_ms(requested_ms: u64) -> u64 {
    if requested_ms == 0 {
        DEFAULT_WAIT_MS
    } else {
        requested_ms.min(MAX_WAIT_MS)
    }
}

/// The OSS Vizier API service.
pub struct VizierService {
    ds: Arc<dyn Datastore>,
    pythia: Arc<dyn PythiaEndpoint>,
    workers: Mutex<Option<ThreadPool>>,
    coalesce: Mutex<CoalesceState>,
    /// Early-stopping twin of `coalesce`: concurrent `CheckEarlyStopping`
    /// operations on one study are served by a single policy invocation
    /// over the union of their trial sets. A distinct instance of the
    /// same lock class — the two are never held together.
    es_coalesce: Mutex<CoalesceState>,
    waiters: OpWaiters,
    /// When false every suggest operation gets its own policy invocation
    /// (the v1 behaviour, kept as a benchmark baseline).
    coalescing: AtomicBool,
    /// Set by [`begin_drain`](Self::begin_drain): blocking
    /// `wait_operation` calls return promptly so front-end threads can
    /// be joined.
    draining: AtomicBool,
    pub metrics: Arc<ServiceMetrics>,
}

impl VizierService {
    /// Create a service over a datastore and Pythia endpoint with
    /// `workers` threads for policy computations.
    pub fn new(ds: Arc<dyn Datastore>, pythia: Arc<dyn PythiaEndpoint>, workers: usize) -> Arc<Self> {
        Arc::new(Self {
            ds,
            pythia,
            workers: Mutex::new(&classes::SVC_WORKERS, Some(ThreadPool::new(workers.max(1)))),
            coalesce: Mutex::new(&classes::SVC_COALESCE, CoalesceState::default()),
            es_coalesce: Mutex::new(&classes::SVC_COALESCE, CoalesceState::default()),
            waiters: OpWaiters::default(),
            coalescing: AtomicBool::new(true),
            draining: AtomicBool::new(false),
            metrics: Arc::new(ServiceMetrics::new()),
        })
    }

    /// Toggle per-study suggest coalescing (on by default). Off = one
    /// policy invocation per operation, the pre-v2 baseline used by the
    /// `C-PYTHIA-COAL` bench.
    pub fn set_suggest_coalescing(&self, on: bool) {
        self.coalescing.store(on, Ordering::SeqCst);
    }

    pub fn datastore(&self) -> &Arc<dyn Datastore> {
        &self.ds
    }

    /// Unblock threads parked in the blocking [`wait_operation`]
    /// (legacy / in-process transports) so a front-end teardown can join
    /// them promptly. Parked pool-mode waits are dropped by the
    /// front-end itself; deferred completions firing later are no-ops.
    ///
    /// [`wait_operation`]: Self::wait_operation
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    /// Drain in-flight operations and stop the worker pool.
    pub fn shutdown(&self) {
        self.begin_drain();
        if let Some(pool) = self.workers.lock().take() {
            pool.shutdown();
        }
    }

    fn enqueue(self: &Arc<Self>, job: impl FnOnce(&VizierService) + Send + 'static) {
        let me = Arc::clone(self);
        let guard = self.workers.lock();
        if let Some(pool) = guard.as_ref() {
            pool.execute(move || job(&me));
        }
    }

    // ------------------------------------------------------------------
    // Studies
    // ------------------------------------------------------------------

    pub fn create_study(&self, req: CreateStudyRequest) -> ApiResult<StudyResponse> {
        let mut study = req.study;
        // Validate through the PyVizier layer before storing.
        let config = converters::study_config_from_proto(&study.display_name, &study.spec);
        config
            .validate()
            .map_err(|e| ApiError::invalid(format!("invalid study config: {e}")))?;
        study.created_ms = epoch_millis();
        study.state = StudyState::Active;
        let stored = self.ds.create_study(study)?;
        Ok(StudyResponse { study: stored })
    }

    pub fn get_study(&self, req: GetStudyRequest) -> ApiResult<StudyResponse> {
        Ok(StudyResponse {
            study: self.ds.get_study(&req.name)?,
        })
    }

    pub fn lookup_study(&self, req: LookupStudyRequest) -> ApiResult<StudyResponse> {
        Ok(StudyResponse {
            study: self.ds.lookup_study(&req.display_name)?,
        })
    }

    pub fn list_studies(&self, req: ListStudiesRequest) -> ApiResult<ListStudiesResponse> {
        if req.page_size == 0 && req.page_token.is_empty() {
            // v1 behaviour: the full listing in one response.
            return Ok(ListStudiesResponse {
                studies: self.ds.list_studies()?,
                next_page_token: String::new(),
            });
        }
        let page = self
            .ds
            .list_studies_page(req.page_size as usize, &req.page_token)?;
        Ok(ListStudiesResponse {
            studies: page.studies,
            next_page_token: page.next_page_token,
        })
    }

    pub fn delete_study(&self, req: DeleteStudyRequest) -> ApiResult<EmptyResponse> {
        self.ds.delete_study(&req.name)?;
        Ok(EmptyResponse::default())
    }

    // ------------------------------------------------------------------
    // Suggestions (long-running operations)
    // ------------------------------------------------------------------

    pub fn suggest_trials(self: &Arc<Self>, req: SuggestTrialsRequest) -> ApiResult<OperationResponse> {
        if req.count == 0 {
            return Err(ApiError::invalid("count must be >= 1"));
        }
        let study = self.ds.get_study(&req.study_name)?;

        // Client-side fault tolerance (§5): if this client already has
        // ACTIVE trials, hand them back instead of generating new ones.
        // Server-side filtered read (§6.2): the datastore clones only the
        // matching trials instead of the whole study — and in the default
        // copy-on-write mode the scan runs against an atomically loaded
        // shard image with zero locks held, so a burst of suggest calls
        // never stalls behind (or stalls) trial writers.
        let filter = crate::datastore::query::TrialFilter::active().for_client(&req.client_id);
        let mut assigned: Vec<TrialProto> = self.ds.query_trials(&req.study_name, &filter)?;
        assigned.truncate(req.count as usize);
        if !assigned.is_empty() {
            let op = self.ds.create_operation(OperationProto {
                kind: OperationKind::SuggestTrials,
                study_name: req.study_name.clone(),
                client_id: req.client_id.clone(),
                done: true,
                trials: assigned,
                count: req.count,
                created_ms: epoch_millis(),
                ..Default::default()
            })?;
            return Ok(OperationResponse { operation: op });
        }

        // Persist the operation first (durability), then queue it for the
        // study's coalescing batch runner.
        let op = self.ds.create_operation(OperationProto {
            kind: OperationKind::SuggestTrials,
            study_name: req.study_name.clone(),
            client_id: req.client_id.clone(),
            done: false,
            count: req.count,
            created_ms: epoch_millis(),
            ..Default::default()
        })?;
        let config = converters::study_config_from_proto(&study.display_name, &study.spec);
        self.queue_suggest(&op.name, &req.study_name);
        let study_name = req.study_name.clone();
        self.enqueue(move |svc| svc.run_suggest_batch(&study_name, &config));
        Ok(OperationResponse { operation: op })
    }

    /// Add a persisted operation to a coalescing queue, unless it is
    /// already queued or in flight. Every queue admission counts once on
    /// the `in_flight_policy_jobs` gauge; the matching decrement happens
    /// at completion (or at the claim-skip for an operation a racing run
    /// already finished).
    fn queue_into(&self, coalesce: &Mutex<CoalesceState>, op_name: &str, study_name: &str) -> bool {
        let state = &mut *coalesce.lock();
        if state.claimed.contains(op_name) {
            return false;
        }
        let q = state.queued.entry(study_name.to_string()).or_default();
        if q.iter().any(|n| n == op_name) {
            return false;
        }
        q.push(op_name.to_string());
        // Remember the requesting trace (if sampled) so the batch runner
        // can fan its one policy span into this op's tree.
        if let Some(ctx) = trace::current() {
            state.ctxs.insert(op_name.to_string(), ctx);
        }
        self.metrics.inc_in_flight_policy_jobs();
        true
    }

    fn queue_suggest(&self, op_name: &str, study_name: &str) -> bool {
        self.queue_into(&self.coalesce, op_name, study_name)
    }

    fn queue_early_stop(&self, op_name: &str, study_name: &str) -> bool {
        self.queue_into(&self.es_coalesce, op_name, study_name)
    }

    /// Persist a finished operation, release its slot on the in-flight
    /// gauge, and wake every parked `WaitOperation` watcher — the single
    /// exit point of the operation lifecycle (see `service/mod.rs`).
    fn complete_operation(&self, op: &OperationProto) {
        debug_assert!(op.done, "complete_operation on a non-done operation");
        let _ = self.ds.update_operation(op.clone());
        self.metrics.dec_in_flight_policy_jobs();
        self.waiters.fire(op, &self.metrics);
    }

    /// Push an intermediate (non-done) operation state to its streaming
    /// watchers. Completion goes through
    /// [`complete_operation`](Self::complete_operation), which also
    /// closes the streams.
    pub fn notify_operation(&self, op: &OperationProto) {
        if op.done {
            return;
        }
        let mut map = self.waiters.map.lock();
        if let Some(streams) = map.streams.get_mut(&op.name) {
            let dead: Vec<u64> = streams
                .iter_mut()
                .filter_map(|(&id, cb)| if cb(op) { None } else { Some(id) })
                .collect();
            for id in dead {
                streams.remove(&id);
                self.metrics.dec_watch_streams();
            }
            if streams.is_empty() {
                map.streams.remove(&op.name);
            }
        }
    }

    /// Serve queued SuggestTrials operations for one study (worker
    /// thread). Repeatedly claims the study's whole queue and runs **one**
    /// policy invocation per claim for the combined wants; each operation
    /// is then completed individually with its own suggestion group. The
    /// loop also picks up operations queued *while* a policy was running
    /// (and, with coalescing off, serves the queue one op at a time), so
    /// a single kicked job never strands queued work.
    fn run_suggest_batch(&self, study_name: &str, config: &StudyConfig) {
        loop {
            if !self.serve_one_suggest_batch(study_name, config) {
                return;
            }
        }
    }

    /// One claim-serve cycle; returns false once the queue was empty.
    fn serve_one_suggest_batch(&self, study_name: &str, config: &StudyConfig) -> bool {
        // Claim the queue (or only its oldest entry with coalescing off).
        let (batch, ctxs) = claim_batch(
            &self.coalesce,
            study_name,
            self.coalescing.load(Ordering::SeqCst),
        );
        if batch.is_empty() {
            return false;
        }
        let _guard = ClaimGuard {
            coalesce: &self.coalesce,
            names: &batch,
        };

        // Load the claimed operations, skipping any already completed
        // (e.g. a duplicate resume that raced a live run). A skipped
        // entry still consumed a queue admission, so its gauge slot is
        // released here.
        let mut ops: Vec<OperationProto> = Vec::with_capacity(batch.len());
        for name in &batch {
            match self.ds.get_operation(name) {
                Ok(op) if !op.done => ops.push(op),
                _ => self.metrics.dec_in_flight_policy_jobs(),
            }
        }
        if !ops.is_empty() {
            // The batch runs under the first traced op's context: the
            // one policy invocation (its Pythia hop, shared metadata
            // persist) lands in that *primary* trace, and the linked
            // copies below fan the policy interval into every other
            // waiting request's tree. Per-op work (trial registration,
            // completion WAL commits) re-targets each op's own context.
            let primary = ops.iter().find_map(|op| ctxs.get(&op.name).copied());
            let _batch_ctx = trace::set_current(primary);
            let request = SuggestRequest {
                study_name: study_name.to_string(),
                study_config: config.clone(),
                wants: ops
                    .iter()
                    .map(|op| SuggestWant {
                        client_id: op.client_id.clone(),
                        count: op.count as usize,
                    })
                    .collect(),
            };
            // A run is a run even if it fails; "served" ops are counted
            // only once their batch got past the policy + delta persist,
            // so the coalescing ratio stays honest during incidents.
            self.metrics.record_policy_run();
            let policy_start = trace::now_us();
            let policy_result = self.pythia.run_suggest(&request);
            let policy_dur = trace::now_us().saturating_sub(policy_start);
            for op in &ops {
                if let Some(&ctx) = ctxs.get(&op.name) {
                    trace::record_linked(ctx, trace::POLICY_COMPUTE, policy_start, policy_dur);
                }
            }
            match policy_result {
                Ok(decision) => {
                    // The unified delta (study- and trial-level writes) is
                    // one atomic datastore batch, persisted before any
                    // operation completes so policy state is never behind
                    // a visible completion. Placeholder writes addressed
                    // at this decision's own suggestions
                    // (`new_trial_index > 0`) cannot be applied yet — the
                    // trials have no ids — so the delta is split: the
                    // resolvable part persists now, the placeholder part
                    // after registration assigns ids (still before any
                    // completion).
                    let (deferred, immediate): (Vec<_>, Vec<_>) = decision
                        .metadata_delta
                        .to_updates()
                        .into_iter()
                        .partition(|u| u.new_trial_index > 0);
                    let mut delta_err = String::new();
                    if !immediate.is_empty() {
                        if let Err(e) = self.ds.update_metadata(study_name, &immediate) {
                            delta_err = format!("failed to persist policy state: {e}");
                            self.metrics.record_error();
                        }
                    }
                    if !delta_err.is_empty() {
                        // Fail the batch *without* registering trials:
                        // completing ops whose policy state could not be
                        // persisted would orphan ACTIVE trials behind a
                        // failed operation (the client never sees them).
                        for op in &mut ops {
                            let _ctx = trace::set_current(ctxs.get(&op.name).copied());
                            op.error = delta_err.clone();
                            op.done = true;
                            self.complete_operation(op);
                        }
                        return true;
                    }
                    self.metrics.record_suggest_ops(ops.len() as u64);
                    // Group i answers want i; a misbehaving policy that
                    // returns fewer groups leaves the tail ops empty.
                    // `slots` maps each flattened suggestion position to
                    // the trial id registration assigned it (None when
                    // that op's registration failed and rolled back).
                    let mut groups = decision.groups.into_iter();
                    let mut slots: Vec<Option<u64>> = Vec::new();
                    for op in &mut ops {
                        let _ctx = trace::set_current(ctxs.get(&op.name).copied());
                        let suggestions =
                            groups.next().map(|g| g.suggestions).unwrap_or_default();
                        let n = suggestions.len();
                        self.register_suggestions(op, suggestions);
                        if op.trials.len() == n {
                            slots.extend(op.trials.iter().map(|t| Some(t.id)));
                        } else {
                            slots.extend(std::iter::repeat(None).take(n));
                        }
                    }
                    let delta_err = self.persist_new_trial_delta(study_name, deferred, &slots);
                    for op in &mut ops {
                        let _ctx = trace::set_current(ctxs.get(&op.name).copied());
                        if let Some(err) = &delta_err {
                            // Trials are already registered and listed on
                            // the op; surface the metadata failure without
                            // hiding them.
                            op.error = err.clone();
                        }
                        op.done = true;
                        self.complete_operation(op);
                    }
                }
                Err(e) => {
                    let msg = format!("policy failed: {e}");
                    self.metrics.record_error();
                    for op in &mut ops {
                        let _ctx = trace::set_current(ctxs.get(&op.name).copied());
                        op.error = msg.clone();
                        op.done = true;
                        self.complete_operation(op);
                    }
                }
            }
        }

        true
    }

    /// Register one operation's suggestions as ACTIVE trials assigned to
    /// its client. If the datastore rejects a trial mid-batch, the
    /// already-registered trials are rolled back to INFEASIBLE — no
    /// orphaned ACTIVE work is silently left assigned to the client — and
    /// the operation completes with an error and no trials. A trial the
    /// client already grabbed through the §5 fast path *and* reported a
    /// measurement on is left alone: the client is demonstrably working
    /// on it, so killing it would be worse than the orphan it prevents.
    fn register_suggestions(&self, op: &mut OperationProto, suggestions: Vec<TrialSuggestion>) {
        let mut registered: Vec<TrialProto> = Vec::with_capacity(suggestions.len());
        for s in suggestions {
            let mut trial = TrialProto {
                state: TrialState::Active,
                client_id: op.client_id.clone(),
                created_ms: epoch_millis(),
                ..Default::default()
            };
            trial.parameters = s
                .parameters
                .iter()
                .map(|(k, v)| TrialParameter {
                    parameter_id: k.clone(),
                    value: converters::value_to_proto(v),
                })
                .collect();
            trial.metadata = converters::metadata_to_proto(&s.metadata);
            match self.ds.create_trial(&op.study_name, trial) {
                Ok(t) => registered.push(t),
                Err(e) => {
                    op.error = format!("failed to register trial: {e}");
                    self.metrics.record_error();
                    let reason = format!("rolled back: {}", op.error);
                    for t in &registered {
                        let _ = self.ds.mutate_trial(&op.study_name, t.id, &mut |t| {
                            let untouched = matches!(
                                t.state,
                                TrialState::Active | TrialState::Requested
                            ) && t.measurements.is_empty();
                            if untouched {
                                t.state = TrialState::Infeasible;
                                t.infeasibility_reason = reason.clone();
                                t.completed_ms = epoch_millis();
                            }
                            Ok(())
                        });
                    }
                    op.trials = Vec::new();
                    return;
                }
            }
        }
        op.trials = registered;
    }

    /// Resolve a decision's placeholder metadata (`new_trial[i]`, carried
    /// as 1-based `new_trial_index`) against the trial ids registration
    /// just assigned and persist the result as one atomic batch. Indices
    /// pointing past the suggestion count or at a rolled-back
    /// registration are dropped (counted as errors); returns the message
    /// to surface on the batch's operations when the persist itself
    /// fails.
    fn persist_new_trial_delta(
        &self,
        study_name: &str,
        deferred: Vec<UnitMetadataUpdate>,
        slots: &[Option<u64>],
    ) -> Option<String> {
        if deferred.is_empty() {
            return None;
        }
        let mut resolved = Vec::with_capacity(deferred.len());
        let mut dropped = 0usize;
        for mut u in deferred {
            let idx = (u.new_trial_index - 1) as usize;
            match slots.get(idx).copied().flatten() {
                Some(id) => {
                    u.trial_id = id;
                    u.new_trial_index = 0;
                    resolved.push(u);
                }
                None => dropped += 1,
            }
        }
        if dropped > 0 {
            self.metrics.record_error();
        }
        if resolved.is_empty() {
            return None;
        }
        match self.ds.update_metadata(study_name, &resolved) {
            Ok(()) => None,
            Err(e) => {
                self.metrics.record_error();
                Some(format!("failed to persist suggestion metadata: {e}"))
            }
        }
    }

    pub fn get_operation(&self, req: GetOperationRequest) -> ApiResult<OperationResponse> {
        Ok(OperationResponse {
            operation: self.ds.get_operation(&req.name)?,
        })
    }

    /// Arm `waiter` to fire when the operation completes. Returns
    /// [`WatchResult::Done`] — dropping the waiter unused — when the
    /// operation is already done, so callers can answer synchronously.
    ///
    /// Race-free against completion: the datastore read happens under
    /// the waiter-registry lock, and the completion path persists `done`
    /// *before* taking that lock to fire. Whichever order the two
    /// interleave, the waiter either observes `done` here or is in the
    /// registry when `fire` runs — a completion can never slip between
    /// the check and the arm.
    pub fn watch_operation(&self, name: &str, waiter: OpWaiter) -> ApiResult<WatchResult> {
        let id = self.waiters.next_id.fetch_add(1, Ordering::Relaxed);
        let mut map = self.waiters.map.lock();
        let op = self.ds.get_operation(name)?;
        if op.done {
            return Ok(WatchResult::Done(op));
        }
        map.once.entry(name.to_string()).or_default().push((id, waiter));
        Ok(WatchResult::Parked(id))
    }

    /// Arm a streaming watcher (wire v2 `WaitOperation`): `cb` is invoked
    /// immediately with the operation's current state, then once per
    /// subsequent state change, and a final time with the `done` state.
    /// Returns `Ok(None)` when no registration happened — the operation
    /// was already done (the callback saw the final state) or the
    /// callback declined by returning `false`; otherwise the id disarms
    /// it via [`unwatch_stream`](Self::unwatch_stream).
    ///
    /// Race-free by the same argument as
    /// [`watch_operation`](Self::watch_operation): the snapshot read and
    /// the registration happen under the registry lock, and completion
    /// persists `done` before taking that lock to fire.
    pub fn watch_operation_stream(&self, name: &str, mut cb: OpStream) -> ApiResult<Option<u64>> {
        let id = self.waiters.next_id.fetch_add(1, Ordering::Relaxed);
        let mut map = self.waiters.map.lock();
        let op = self.ds.get_operation(name)?;
        let keep = cb(&op);
        if op.done || !keep {
            return Ok(None);
        }
        map.streams.entry(name.to_string()).or_default().insert(id, cb);
        self.metrics.inc_watch_streams();
        Ok(Some(id))
    }

    /// Disarm a streaming watcher whose consumer went away (client
    /// `CANCEL` or connection teardown). A no-op if the stream already
    /// closed at completion.
    pub fn unwatch_stream(&self, name: &str, id: u64) {
        let mut map = self.waiters.map.lock();
        if let Some(streams) = map.streams.get_mut(name) {
            if streams.remove(&id).is_some() {
                self.metrics.dec_watch_streams();
            }
            if streams.is_empty() {
                map.streams.remove(name);
            }
        }
    }

    /// Disarm a parked waiter whose recipient stopped listening (its
    /// long-poll timed out), so slow operations do not accumulate stale
    /// closures that would fire — and skew `wait_wakeup` — at
    /// completion. A no-op if the waiter already fired.
    pub fn unwatch_operation(&self, name: &str, id: u64) {
        let mut map = self.waiters.map.lock();
        if let Some(ws) = map.once.get_mut(name) {
            ws.retain(|(wid, _)| *wid != id);
            if ws.is_empty() {
                map.once.remove(name);
            }
        }
    }

    /// Blocking `WaitOperation` (paper §3.2 long-running operations,
    /// server-side long-poll): park until the operation completes or
    /// ~`timeout_ms` passes, then return its state either way. Used by
    /// the in-process transport and the legacy thread-per-connection
    /// front-end, where a blocked thread is fine; the worker-pool
    /// front-end serves the same RPC without blocking via
    /// [`watch_operation`](Self::watch_operation) + deferred responses.
    pub fn wait_operation(&self, req: WaitOperationRequest) -> ApiResult<OperationResponse> {
        let (tx, rx) = mpsc::channel::<OperationProto>();
        let armed = Instant::now();
        let metrics = Arc::clone(&self.metrics);
        let waiter: OpWaiter = Box::new(move |op: &OperationProto| {
            metrics.record_wait_wakeup(armed.elapsed().as_micros() as u64);
            let _ = tx.send(op.clone());
        });
        let waiter_id = match self.watch_operation(&req.name, waiter)? {
            WatchResult::Done(op) => return Ok(OperationResponse { operation: op }),
            WatchResult::Parked(id) => id,
        };
        // Short recv slices so begin_drain() can reclaim this thread
        // promptly during shutdown.
        let deadline = Instant::now() + Duration::from_millis(effective_wait_ms(req.timeout_ms));
        loop {
            let now = Instant::now();
            if now >= deadline || self.draining.load(Ordering::SeqCst) {
                // Timeout is not an error: report the current state.
                // Disarm first so the abandoned waiter cannot fire at
                // completion and skew the wakeup metrics.
                self.unwatch_operation(&req.name, waiter_id);
                return Ok(OperationResponse { operation: self.ds.get_operation(&req.name)? });
            }
            let slice = (deadline - now).min(Duration::from_millis(250));
            if let Ok(op) = rx.recv_timeout(slice) {
                return Ok(OperationResponse { operation: op });
            }
        }
    }

    /// Re-enqueue every non-done operation (call at startup; paper §3.2
    /// server-side fault tolerance). Interrupted suggest operations are
    /// pushed back onto their study's queue and re-coalesced — one batch
    /// runner per affected study — and anything already queued or in
    /// flight is skipped, so a resume racing live traffic (or a second
    /// resume) cannot double-serve an operation.
    pub fn resume_pending_operations(self: &Arc<Self>) -> ApiResult<usize> {
        let pending = self.ds.pending_operations()?;
        let n = pending.len();
        // Queue everything first, then kick one batch job per study, so a
        // fast worker cannot drain a study's queue while later pending
        // operations of the same study are still being pushed.
        let mut kick: Vec<(String, StudyConfig)> = Vec::new();
        let mut es_kick: Vec<(String, StudyConfig)> = Vec::new();
        for op in pending {
            let study = self.ds.get_study(&op.study_name)?;
            let config = converters::study_config_from_proto(&study.display_name, &study.spec);
            match op.kind {
                OperationKind::SuggestTrials => {
                    let fresh = self.queue_suggest(&op.name, &op.study_name);
                    if fresh && !kick.iter().any(|(s, _)| s == &op.study_name) {
                        kick.push((op.study_name.clone(), config));
                    }
                }
                OperationKind::EarlyStopping => {
                    let fresh = self.queue_early_stop(&op.name, &op.study_name);
                    if fresh && !es_kick.iter().any(|(s, _)| s == &op.study_name) {
                        es_kick.push((op.study_name.clone(), config));
                    }
                }
            }
        }
        for (study_name, config) in kick {
            self.enqueue(move |svc| svc.run_suggest_batch(&study_name, &config));
        }
        for (study_name, config) in es_kick {
            self.enqueue(move |svc| svc.run_early_stop_batch(&study_name, &config));
        }
        Ok(n)
    }

    // ------------------------------------------------------------------
    // Measurements / completion
    // ------------------------------------------------------------------

    pub fn add_measurement(&self, req: AddMeasurementRequest) -> ApiResult<TrialResponse> {
        let m = req.measurement;
        let trial = self
            .ds
            .mutate_trial(&req.study_name, req.trial_id, &mut |t| {
                if matches!(t.state, TrialState::Completed | TrialState::Infeasible) {
                    return Err(DsError::Invalid(format!(
                        "trial {} is already completed",
                        t.id
                    )));
                }
                t.measurements.push(m.clone());
                Ok(())
            })?;
        Ok(TrialResponse { trial })
    }

    pub fn complete_trial(&self, req: CompleteTrialRequest) -> ApiResult<TrialResponse> {
        let trial = self
            .ds
            .mutate_trial(&req.study_name, req.trial_id, &mut |t| {
                if matches!(t.state, TrialState::Completed | TrialState::Infeasible) {
                    return Err(DsError::Invalid(format!(
                        "trial {} is already completed",
                        t.id
                    )));
                }
                if req.infeasible {
                    t.state = TrialState::Infeasible;
                    t.infeasibility_reason = if req.infeasibility_reason.is_empty() {
                        "infeasible".to_string()
                    } else {
                        req.infeasibility_reason.clone()
                    };
                } else {
                    t.state = TrialState::Completed;
                    if let Some(fm) = &req.final_measurement {
                        t.final_measurement = Some(fm.clone());
                    } else if let Some(last) = t.measurements.last() {
                        // Paper semantics: completing without an explicit
                        // final measurement promotes the last intermediate.
                        t.final_measurement = Some(last.clone());
                    } else {
                        return Err(DsError::Invalid(
                            "cannot complete a trial with no measurements; \
                             mark it infeasible instead"
                                .into(),
                        ));
                    }
                }
                t.completed_ms = epoch_millis();
                Ok(())
            })?;
        Ok(TrialResponse { trial })
    }

    // ------------------------------------------------------------------
    // Trials
    // ------------------------------------------------------------------

    pub fn list_trials(&self, req: ListTrialsRequest) -> ApiResult<ListTrialsResponse> {
        if req.page_size == 0 && req.page_token.is_empty() {
            // v1 behaviour: every trial in one response.
            return Ok(ListTrialsResponse {
                trials: self.ds.list_trials(&req.study_name)?,
                next_page_token: String::new(),
            });
        }
        let page = self.ds.list_trials_page(
            &req.study_name,
            req.page_size as usize,
            &req.page_token,
        )?;
        Ok(ListTrialsResponse {
            trials: page.trials,
            next_page_token: page.next_page_token,
        })
    }

    pub fn get_trial(&self, req: GetTrialRequest) -> ApiResult<TrialResponse> {
        Ok(TrialResponse {
            trial: self.ds.get_trial(&req.study_name, req.trial_id)?,
        })
    }

    pub fn delete_trial(&self, req: DeleteTrialRequest) -> ApiResult<EmptyResponse> {
        self.ds.delete_trial(&req.study_name, req.trial_id)?;
        Ok(EmptyResponse::default())
    }

    pub fn stop_trial(&self, req: StopTrialRequest) -> ApiResult<TrialResponse> {
        let trial = self
            .ds
            .mutate_trial(&req.study_name, req.trial_id, &mut |t| {
                if matches!(t.state, TrialState::Active | TrialState::Requested) {
                    t.state = TrialState::Stopping;
                }
                Ok(())
            })?;
        Ok(TrialResponse { trial })
    }

    pub fn list_optimal_trials(
        &self,
        req: ListOptimalTrialsRequest,
    ) -> ApiResult<ListTrialsResponse> {
        let study = self.ds.get_study(&req.study_name)?;
        let config = converters::study_config_from_proto(&study.display_name, &study.spec);
        let trials: Vec<crate::pyvizier::Trial> = self
            .ds
            .list_trials(&req.study_name)?
            .iter()
            .map(converters::trial_from_proto)
            .collect();
        let optimal = crate::pyvizier::pareto::optimal_trials(&trials, &config.metrics);
        Ok(ListTrialsResponse {
            trials: optimal.iter().map(|t| converters::trial_to_proto(t)).collect(),
            next_page_token: String::new(),
        })
    }

    /// Counter snapshot over an RPC (Pythia v2 follow-up (c)): the
    /// coalescing ratio, async-dispatch gauges, and front-end occupancy
    /// without shelling into the server for `ServiceMetrics::report`.
    ///
    /// The response is fully structured — every counter, gauge, and
    /// latency histogram the server tracks, by name (`frontend.*` /
    /// `wal.*` / `datastore.*` entries appear only when those
    /// subsystems are linked).
    /// Text rendering lives client-side in
    /// [`crate::client::VizierClient::service_metrics`]; the retired
    /// server-rendered `report` field is left empty.
    pub fn get_service_metrics(
        &self,
        _req: GetServiceMetricsRequest,
    ) -> ApiResult<ServiceMetricsResponse> {
        use crate::service::metrics::Histogram;
        let m = &self.metrics;
        let fe = m.frontend();
        let wal = m.wal();

        fn point(name: &str, value: u64) -> MetricPointProto {
            MetricPointProto {
                name: name.to_string(),
                value,
            }
        }
        fn histo(name: &str, h: &Histogram) -> MetricHistogramProto {
            MetricHistogramProto {
                name: name.to_string(),
                count: h.count(),
                sum_us: h.sum_micros(),
                p50_us: h.quantile_micros(0.5),
                p99_us: h.quantile_micros(0.99),
                buckets: h.bucket_counts(),
            }
        }

        let mut counters = vec![
            point("errors", m.errors.load(Ordering::Relaxed)),
            point("policy_runs", m.policy_runs()),
            point("suggest_ops_served", m.suggest_ops_served()),
        ];
        let mut gauges = vec![
            point("in_flight_policy_jobs", m.in_flight_policy_jobs()),
            point("watch_streams", m.watch_streams()),
        ];
        let mut histograms = vec![histo("wait_wakeup", &m.wait_wakeup)];
        for (name, h) in m.method_histograms() {
            histograms.push(histo(&format!("method.{name}"), &h));
        }
        if let Some(f) = &fe {
            counters.push(point("frontend.connections_total", f.connections_total()));
            counters.push(point("frontend.requests", f.requests()));
            counters.push(point("frontend.idle_evictions", f.idle_evictions()));
            counters.push(point("frontend.connections_refused", f.connections_refused()));
            counters.push(point("frontend.loop_wakeups", f.loop_wakeups()));
            counters.push(point("frontend.loop_scan_cost", f.loop_scan_cost()));
            gauges.push(point("frontend.active_connections", f.active_connections()));
            gauges.push(point("frontend.queue_depth", f.queue_depth()));
            gauges.push(point("frontend.parked_responses", f.parked_responses()));
            histograms.push(histo("frontend.queue_wait", &f.queue_wait));
        }
        if let Some(w) = &wal {
            counters.push(point("wal.rotations", w.rotations()));
            counters.push(point("wal.compactions", w.compactions()));
            counters.push(point("wal.reclaimed_bytes", w.reclaimed_bytes()));
            gauges.push(point("wal.segments", w.segments()));
            gauges.push(point("wal.commit_stall_max_us", w.commit_stall_max_micros()));
            histograms.push(histo("wal.compaction", &w.compaction_micros));
            histograms.push(histo("wal.commit_wait", &w.commit_wait));
        }
        if let Some(d) = &m.datastore() {
            counters.push(point("datastore.snapshot_publishes", d.snapshot_publishes()));
            counters.push(point("datastore.snapshot_loads", d.snapshot_loads()));
            counters.push(point("datastore.locked_reads", d.locked_reads()));
            counters.push(point("datastore.shard_writes", d.shard_writes()));
            gauges.push(point("datastore.retired_images", d.retired_images()));
            gauges.push(point("datastore.pinned_readers", d.pinned_readers()));
        }

        Ok(ServiceMetricsResponse {
            policy_runs: m.policy_runs(),
            suggest_ops_served: m.suggest_ops_served(),
            in_flight_policy_jobs: m.in_flight_policy_jobs(),
            errors: m.errors.load(Ordering::Relaxed),
            wait_wakeups: m.wait_wakeup.count(),
            wait_wakeup_mean_us: m.wait_wakeup.mean_micros() as u64,
            active_connections: fe.as_ref().map_or(0, |f| f.active_connections()),
            parked_responses: fe.as_ref().map_or(0, |f| f.parked_responses()),
            connections_total: fe.as_ref().map_or(0, |f| f.connections_total()),
            requests: fe.as_ref().map_or(0, |f| f.requests()),
            report: String::new(),
            counters,
            gauges,
            histograms,
        })
    }

    pub fn update_metadata(&self, req: UpdateMetadataRequest) -> ApiResult<EmptyResponse> {
        self.ds.update_metadata(&req.study_name, &req.updates)?;
        Ok(EmptyResponse::default())
    }

    /// The slowest-N recent request traces (span trees) from the
    /// in-process trace rings — the per-request counterpart to
    /// [`get_service_metrics`](Self::get_service_metrics)'s aggregates.
    /// Empty when tracing is disabled. `limit` 0 means 10; with
    /// `include_infra` the background spans (fsync batches, rotations)
    /// are appended as pseudo-trace 0 regardless of the limit. Spans
    /// are grouped and named server-side
    /// ([`super::server::span_label`]) so any client version renders
    /// new span kinds without decoding numeric codes.
    pub fn get_traces(&self, req: GetTracesRequest) -> ApiResult<GetTracesResponse> {
        let spans = trace::snapshot();
        let mut by_trace: HashMap<u64, Vec<&trace::SpanRecord>> = HashMap::new();
        for s in &spans {
            if s.trace_id == 0 && !req.include_infra {
                continue;
            }
            by_trace.entry(s.trace_id).or_default().push(s);
        }
        let to_proto = |(id, ss): (u64, Vec<&trace::SpanRecord>)| {
            let start = ss.iter().map(|s| s.start_us).min().unwrap_or(0);
            let end = ss.iter().map(|s| s.start_us + s.dur_us).max().unwrap_or(0);
            TraceProto {
                trace_id: id,
                duration_us: end.saturating_sub(start),
                spans: ss
                    .iter()
                    .map(|s| SpanProto {
                        span_id: s.span_id,
                        parent_id: s.parent_id,
                        name: super::server::span_label(s.name_code),
                        start_us: s.start_us,
                        duration_us: s.dur_us,
                    })
                    .collect(),
            }
        };
        let infra = by_trace.remove(&0).map(|ss| to_proto((0, ss)));
        let mut traces: Vec<TraceProto> = by_trace.into_iter().map(to_proto).collect();
        traces.sort_by(|a, b| {
            b.duration_us.cmp(&a.duration_us).then(a.trace_id.cmp(&b.trace_id))
        });
        let limit = if req.limit == 0 { 10 } else { req.limit as usize };
        traces.truncate(limit);
        traces.extend(infra);
        Ok(GetTracesResponse { traces })
    }

    // ------------------------------------------------------------------
    // Early stopping (long-running operation, §3.2)
    // ------------------------------------------------------------------

    /// Batched (Pythia v2): one operation judges many trials. An empty
    /// `trial_ids` means "every ACTIVE trial", resolved when the
    /// operation runs.
    pub fn check_early_stopping(
        self: &Arc<Self>,
        req: CheckEarlyStoppingRequest,
    ) -> ApiResult<OperationResponse> {
        let study = self.ds.get_study(&req.study_name)?;
        // Explicitly named trials must exist and be running. Small
        // batches (the should_trial_stop hot path) use keyed reads; big
        // batches are validated with one filtered scan instead of one
        // lock + full-trial clone per id.
        let is_running = |state: TrialState| {
            matches!(
                state,
                TrialState::Active | TrialState::Requested | TrialState::Stopping
            )
        };
        if req.trial_ids.len() <= 2 {
            for &trial_id in &req.trial_ids {
                let trial = self.ds.get_trial(&req.study_name, trial_id)?;
                if !is_running(trial.state) {
                    return Err(ApiError::failed_precondition(format!(
                        "trial {trial_id} is not running"
                    )));
                }
            }
        } else {
            // Early-stop read set: like the suggest path, this filtered
            // scan runs lock-free against the shard's published image in
            // copy-on-write mode, so batch stop requests don't contend
            // with evaluators reporting measurements.
            let running_filter = crate::datastore::query::TrialFilter {
                states: vec![TrialState::Active, TrialState::Requested, TrialState::Stopping],
                ..Default::default()
            };
            let running: HashSet<u64> = self
                .ds
                .query_trials(&req.study_name, &running_filter)?
                .iter()
                .map(|t| t.id)
                .collect();
            for &trial_id in &req.trial_ids {
                if !running.contains(&trial_id) {
                    // NotFound if the trial doesn't exist at all.
                    self.ds.get_trial(&req.study_name, trial_id)?;
                    return Err(ApiError::failed_precondition(format!(
                        "trial {trial_id} is not running"
                    )));
                }
            }
        }
        let op = self.ds.create_operation(OperationProto {
            kind: OperationKind::EarlyStopping,
            study_name: req.study_name.clone(),
            trial_ids: req.trial_ids.clone(),
            done: false,
            created_ms: epoch_millis(),
            ..Default::default()
        })?;
        let config = converters::study_config_from_proto(&study.display_name, &study.spec);
        self.queue_early_stop(&op.name, &req.study_name);
        let study_name = req.study_name.clone();
        self.enqueue(move |svc| svc.run_early_stop_batch(&study_name, &config));
        Ok(OperationResponse { operation: op })
    }

    /// Serve queued EarlyStopping operations for one study (worker
    /// thread), the early-stop twin of
    /// [`run_suggest_batch`](Self::run_suggest_batch): each claim takes
    /// the study's whole queue, unions the claimed operations' trial
    /// sets, and runs **one** policy invocation (or one built-in-rule
    /// pass) for the union. Each operation then completes with the
    /// verdicts for its own requested subset.
    fn run_early_stop_batch(&self, study_name: &str, config: &StudyConfig) {
        loop {
            if !self.serve_one_early_stop_batch(study_name, config) {
                return;
            }
        }
    }

    /// One claim-serve cycle; returns false once the queue was empty.
    fn serve_one_early_stop_batch(&self, study_name: &str, config: &StudyConfig) -> bool {
        let (batch, ctxs) = claim_batch(
            &self.es_coalesce,
            study_name,
            self.coalescing.load(Ordering::SeqCst),
        );
        if batch.is_empty() {
            return false;
        }
        let _guard = ClaimGuard {
            coalesce: &self.es_coalesce,
            names: &batch,
        };

        // Load the claimed operations, skipping any already completed
        // (e.g. a duplicate resume that raced a live run) — a skipped
        // entry still consumed a queue admission, so its gauge slot is
        // released here, which is what keeps crash-resume re-coalescing
        // without double-serving.
        let mut ops: Vec<OperationProto> = Vec::with_capacity(batch.len());
        for name in &batch {
            match self.ds.get_operation(name) {
                Ok(op) if !op.done => ops.push(op),
                _ => self.metrics.dec_in_flight_policy_jobs(),
            }
        }
        if ops.is_empty() {
            return true;
        }

        // Union the batch's trial sets. An operation with an empty
        // `trial_ids` means "every trial ACTIVE right now"; resolve that
        // once for the whole batch and remember the resolution so the
        // operation's own verdict subset matches it.
        let wants_all = ops.iter().any(|op| op.trial_ids.is_empty());
        let all_active: Vec<u64> = if wants_all {
            match self
                .ds
                .query_trials(study_name, &crate::datastore::query::TrialFilter::active())
            {
                Ok(trials) => trials.iter().map(|t| t.id).collect(),
                Err(e) => {
                    let msg = e.to_string();
                    self.metrics.record_error();
                    for op in &mut ops {
                        op.error = msg.clone();
                        op.done = true;
                        self.complete_operation(op);
                    }
                    return true;
                }
            }
        } else {
            Vec::new()
        };
        let mut seen: HashSet<u64> = HashSet::new();
        let mut union_ids: Vec<u64> = Vec::new();
        for &id in all_active.iter().chain(ops.iter().flat_map(|op| op.trial_ids.iter())) {
            if seen.insert(id) {
                union_ids.push(id);
            }
        }

        // Same fan-in as the suggest batch: the one computation runs
        // under the first traced op's context, and a linked copy lands
        // in every waiting trace.
        let primary = ops.iter().find_map(|op| ctxs.get(&op.name).copied());
        let _batch_ctx = trace::set_current(primary);
        let es_start = trace::now_us();
        let es_result = self.early_stop_decisions(study_name, config, union_ids);
        let es_dur = trace::now_us().saturating_sub(es_start);
        for op in &ops {
            if let Some(&ctx) = ctxs.get(&op.name) {
                trace::record_linked(ctx, trace::POLICY_COMPUTE, es_start, es_dur);
            }
        }
        match es_result {
            Ok(decisions) => {
                for d in &decisions {
                    if d.should_stop {
                        // Move the trial to STOPPING so the worker sees it
                        // (once per batch, not once per operation).
                        let _ = self.ds.mutate_trial(study_name, d.trial_id, &mut |t| {
                            if matches!(t.state, TrialState::Active | TrialState::Requested) {
                                t.state = TrialState::Stopping;
                            }
                            Ok(())
                        });
                    }
                }
                let by_id: HashMap<u64, &crate::pythia::policy::EarlyStopDecision> =
                    decisions.iter().map(|d| (d.trial_id, d)).collect();
                for op in &mut ops {
                    let subset: &[u64] = if op.trial_ids.is_empty() {
                        &all_active
                    } else {
                        &op.trial_ids
                    };
                    op.stop_decisions = subset
                        .iter()
                        .filter_map(|id| by_id.get(id))
                        .map(|d| TrialStopDecision::from(*d))
                        .collect();
                    op.done = true;
                    self.complete_operation(op);
                }
            }
            Err(e) => {
                self.metrics.record_error();
                for op in &mut ops {
                    op.error = e.clone();
                    op.done = true;
                    self.complete_operation(op);
                }
            }
        }
        true
    }

    /// Compute stop verdicts for `trial_ids` — via the built-in automated
    /// stopping rule when configured (Appendix B.1; the completed pool is
    /// read once for the whole batch), otherwise via one Pythia policy
    /// invocation.
    fn early_stop_decisions(
        &self,
        study_name: &str,
        config: &StudyConfig,
        trial_ids: Vec<u64>,
    ) -> Result<Vec<crate::pythia::policy::EarlyStopDecision>, String> {
        use crate::pythia::policy::EarlyStopDecision;
        if config.stopping.kind != StoppingKind::None {
            let completed: Vec<crate::pyvizier::Trial> = self
                .ds
                .query_trials(
                    study_name,
                    &crate::datastore::query::TrialFilter::completed(),
                )
                .map_err(|e| e.to_string())?
                .iter()
                .map(converters::trial_from_proto)
                .collect();
            let mut out = Vec::with_capacity(trial_ids.len());
            for id in trial_ids {
                // A trial deleted while the operation was queued gets no
                // verdict; it must not fail the rest of the batch.
                let Ok(proto) = self.ds.get_trial(study_name, id) else {
                    continue;
                };
                let trial = converters::trial_from_proto(&proto);
                let d = crate::stopping::decide(config, &trial, &completed);
                out.push(EarlyStopDecision {
                    trial_id: id,
                    should_stop: d.should_stop,
                    reason: d.reason,
                });
            }
            Ok(out)
        } else {
            self.pythia
                .run_early_stop(&EarlyStopRequest {
                    study_name: study_name.to_string(),
                    study_config: config.clone(),
                    trial_ids,
                })
                .map_err(|e| e.to_string())
        }
    }
}
