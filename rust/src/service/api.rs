//! The Vizier service implementation: every RPC method of §3.2 over a
//! pluggable datastore and Pythia endpoint.
//!
//! The suggestion workflow reproduces the paper exactly:
//! 1. `suggest_trials` persists an [`OperationProto`] and enqueues the
//!    policy run on a worker thread, returning the operation immediately.
//! 2. Clients poll `get_operation` until `done`.
//! 3. The worker runs the Pythia policy, registers the suggested trials
//!    (state ACTIVE, assigned to the requesting `client_id`), persists any
//!    designer metadata, and marks the operation done.
//! 4. On startup, [`VizierService::resume_pending_operations`] re-enqueues
//!    operations that were interrupted by a crash (server-side fault
//!    tolerance).
//! 5. ACTIVE trials already assigned to a client are returned *before* new
//!    suggestions are computed (client-side fault tolerance, §5).

use crate::datastore::{Datastore, DsError};
use crate::pythia::policy::{EarlyStopRequest, SuggestRequest};
use crate::pythia::runner::PythiaEndpoint;
use crate::pyvizier::{converters, StudyConfig};
use crate::service::metrics::ServiceMetrics;
use crate::util::threadpool::ThreadPool;
use crate::util::time::epoch_millis;
use crate::wire::framing::Status;
use crate::wire::messages::*;
use std::sync::{Arc, Mutex};

/// Service-level error: an RPC status plus message.
#[derive(Debug, Clone)]
pub struct ApiError {
    pub status: Status,
    pub message: String,
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}: {}", self.status, self.message)
    }
}

impl std::error::Error for ApiError {}

impl ApiError {
    pub fn invalid(msg: impl Into<String>) -> Self {
        Self {
            status: Status::InvalidArgument,
            message: msg.into(),
        }
    }

    pub fn failed_precondition(msg: impl Into<String>) -> Self {
        Self {
            status: Status::FailedPrecondition,
            message: msg.into(),
        }
    }
}

impl From<DsError> for ApiError {
    fn from(e: DsError) -> Self {
        let status = match &e {
            DsError::StudyNotFound(_) | DsError::TrialNotFound(..) | DsError::OperationNotFound(_) => {
                Status::NotFound
            }
            DsError::StudyExists(_) => Status::FailedPrecondition,
            DsError::Invalid(_) => Status::InvalidArgument,
            DsError::Storage(_) => Status::Internal,
        };
        Self {
            status,
            message: e.to_string(),
        }
    }
}

pub type ApiResult<T> = Result<T, ApiError>;

/// The OSS Vizier API service.
pub struct VizierService {
    ds: Arc<dyn Datastore>,
    pythia: Arc<dyn PythiaEndpoint>,
    workers: Mutex<Option<ThreadPool>>,
    pub metrics: Arc<ServiceMetrics>,
}

impl VizierService {
    /// Create a service over a datastore and Pythia endpoint with
    /// `workers` threads for policy computations.
    pub fn new(ds: Arc<dyn Datastore>, pythia: Arc<dyn PythiaEndpoint>, workers: usize) -> Arc<Self> {
        Arc::new(Self {
            ds,
            pythia,
            workers: Mutex::new(Some(ThreadPool::new(workers.max(1)))),
            metrics: Arc::new(ServiceMetrics::new()),
        })
    }

    pub fn datastore(&self) -> &Arc<dyn Datastore> {
        &self.ds
    }

    /// Drain in-flight operations and stop the worker pool.
    pub fn shutdown(&self) {
        if let Some(pool) = self.workers.lock().unwrap().take() {
            pool.shutdown();
        }
    }

    fn enqueue(self: &Arc<Self>, job: impl FnOnce(&VizierService) + Send + 'static) {
        let me = Arc::clone(self);
        let guard = self.workers.lock().unwrap();
        if let Some(pool) = guard.as_ref() {
            pool.execute(move || job(&me));
        }
    }

    // ------------------------------------------------------------------
    // Studies
    // ------------------------------------------------------------------

    pub fn create_study(&self, req: CreateStudyRequest) -> ApiResult<StudyResponse> {
        let mut study = req.study;
        // Validate through the PyVizier layer before storing.
        let config = converters::study_config_from_proto(&study.display_name, &study.spec);
        config
            .validate()
            .map_err(|e| ApiError::invalid(format!("invalid study config: {e}")))?;
        study.created_ms = epoch_millis();
        study.state = StudyState::Active;
        let stored = self.ds.create_study(study)?;
        Ok(StudyResponse { study: stored })
    }

    pub fn get_study(&self, req: GetStudyRequest) -> ApiResult<StudyResponse> {
        Ok(StudyResponse {
            study: self.ds.get_study(&req.name)?,
        })
    }

    pub fn lookup_study(&self, req: LookupStudyRequest) -> ApiResult<StudyResponse> {
        Ok(StudyResponse {
            study: self.ds.lookup_study(&req.display_name)?,
        })
    }

    pub fn list_studies(&self, _req: ListStudiesRequest) -> ApiResult<ListStudiesResponse> {
        Ok(ListStudiesResponse {
            studies: self.ds.list_studies()?,
        })
    }

    pub fn delete_study(&self, req: DeleteStudyRequest) -> ApiResult<EmptyResponse> {
        self.ds.delete_study(&req.name)?;
        Ok(EmptyResponse::default())
    }

    // ------------------------------------------------------------------
    // Suggestions (long-running operations)
    // ------------------------------------------------------------------

    pub fn suggest_trials(self: &Arc<Self>, req: SuggestTrialsRequest) -> ApiResult<OperationResponse> {
        if req.count == 0 {
            return Err(ApiError::invalid("count must be >= 1"));
        }
        let study = self.ds.get_study(&req.study_name)?;

        // Client-side fault tolerance (§5): if this client already has
        // ACTIVE trials, hand them back instead of generating new ones.
        // Server-side filtered read (§6.2): the datastore clones only the
        // matching trials instead of the whole study.
        let filter = crate::datastore::query::TrialFilter::active().for_client(&req.client_id);
        let mut assigned: Vec<TrialProto> = self.ds.query_trials(&req.study_name, &filter)?;
        assigned.truncate(req.count as usize);
        if !assigned.is_empty() {
            let op = self.ds.create_operation(OperationProto {
                kind: OperationKind::SuggestTrials,
                study_name: req.study_name.clone(),
                client_id: req.client_id.clone(),
                done: true,
                trials: assigned,
                count: req.count,
                created_ms: epoch_millis(),
                ..Default::default()
            })?;
            return Ok(OperationResponse { operation: op });
        }

        // Persist the operation first (durability), then enqueue.
        let op = self.ds.create_operation(OperationProto {
            kind: OperationKind::SuggestTrials,
            study_name: req.study_name.clone(),
            client_id: req.client_id.clone(),
            done: false,
            count: req.count,
            created_ms: epoch_millis(),
            ..Default::default()
        })?;
        let op_name = op.name.clone();
        let config = converters::study_config_from_proto(&study.display_name, &study.spec);
        self.enqueue(move |svc| svc.run_suggest_operation(&op_name, &config));
        Ok(OperationResponse { operation: op })
    }

    /// Execute one persisted SuggestTrials operation (worker thread).
    fn run_suggest_operation(&self, op_name: &str, config: &StudyConfig) {
        let Ok(mut op) = self.ds.get_operation(op_name) else {
            return;
        };
        if op.done {
            return; // raced with a duplicate resume
        }
        let request = SuggestRequest {
            study_name: op.study_name.clone(),
            study_config: config.clone(),
            count: op.count as usize,
            client_id: op.client_id.clone(),
        };
        match self.pythia.run_suggest(&request) {
            Ok(decision) => {
                // Register suggestions as ACTIVE trials assigned to the client.
                let mut registered = Vec::with_capacity(decision.suggestions.len());
                for s in decision.suggestions {
                    let mut trial = TrialProto {
                        state: TrialState::Active,
                        client_id: op.client_id.clone(),
                        created_ms: epoch_millis(),
                        ..Default::default()
                    };
                    trial.parameters = s
                        .parameters
                        .iter()
                        .map(|(k, v)| TrialParameter {
                            parameter_id: k.clone(),
                            value: converters::value_to_proto(v),
                        })
                        .collect();
                    trial.metadata = converters::metadata_to_proto(&s.metadata);
                    match self.ds.create_trial(&op.study_name, trial) {
                        Ok(t) => registered.push(t),
                        Err(e) => {
                            op.error = format!("failed to register trial: {e}");
                            break;
                        }
                    }
                }
                // Persist designer state atomically with completion.
                if let Some(md) = decision.study_metadata {
                    let updates: Vec<UnitMetadataUpdate> = md
                        .iter()
                        .map(|(ns, k, v)| UnitMetadataUpdate {
                            trial_id: 0,
                            item: Some(MetadataItem {
                                namespace: ns.to_string(),
                                key: k.to_string(),
                                value: v.to_vec(),
                            }),
                        })
                        .collect();
                    if let Err(e) = self.ds.update_metadata(&op.study_name, &updates) {
                        op.error = format!("failed to persist designer state: {e}");
                    }
                }
                op.trials = registered;
            }
            Err(e) => {
                op.error = format!("policy failed: {e}");
                self.metrics.record_error();
            }
        }
        op.done = true;
        let _ = self.ds.update_operation(op);
    }

    pub fn get_operation(&self, req: GetOperationRequest) -> ApiResult<OperationResponse> {
        Ok(OperationResponse {
            operation: self.ds.get_operation(&req.name)?,
        })
    }

    /// Re-enqueue every non-done operation (call at startup; paper §3.2
    /// server-side fault tolerance).
    pub fn resume_pending_operations(self: &Arc<Self>) -> ApiResult<usize> {
        let pending = self.ds.pending_operations()?;
        let n = pending.len();
        for op in pending {
            let study = self.ds.get_study(&op.study_name)?;
            let config = converters::study_config_from_proto(&study.display_name, &study.spec);
            let name = op.name.clone();
            match op.kind {
                OperationKind::SuggestTrials => {
                    self.enqueue(move |svc| svc.run_suggest_operation(&name, &config));
                }
                OperationKind::EarlyStopping => {
                    self.enqueue(move |svc| svc.run_early_stopping_operation(&name, &config));
                }
            }
        }
        Ok(n)
    }

    // ------------------------------------------------------------------
    // Measurements / completion
    // ------------------------------------------------------------------

    pub fn add_measurement(&self, req: AddMeasurementRequest) -> ApiResult<TrialResponse> {
        let m = req.measurement;
        let trial = self
            .ds
            .mutate_trial(&req.study_name, req.trial_id, &mut |t| {
                if matches!(t.state, TrialState::Completed | TrialState::Infeasible) {
                    return Err(DsError::Invalid(format!(
                        "trial {} is already completed",
                        t.id
                    )));
                }
                t.measurements.push(m.clone());
                Ok(())
            })?;
        Ok(TrialResponse { trial })
    }

    pub fn complete_trial(&self, req: CompleteTrialRequest) -> ApiResult<TrialResponse> {
        let trial = self
            .ds
            .mutate_trial(&req.study_name, req.trial_id, &mut |t| {
                if matches!(t.state, TrialState::Completed | TrialState::Infeasible) {
                    return Err(DsError::Invalid(format!(
                        "trial {} is already completed",
                        t.id
                    )));
                }
                if req.infeasible {
                    t.state = TrialState::Infeasible;
                    t.infeasibility_reason = if req.infeasibility_reason.is_empty() {
                        "infeasible".to_string()
                    } else {
                        req.infeasibility_reason.clone()
                    };
                } else {
                    t.state = TrialState::Completed;
                    if let Some(fm) = &req.final_measurement {
                        t.final_measurement = Some(fm.clone());
                    } else if let Some(last) = t.measurements.last() {
                        // Paper semantics: completing without an explicit
                        // final measurement promotes the last intermediate.
                        t.final_measurement = Some(last.clone());
                    } else {
                        return Err(DsError::Invalid(
                            "cannot complete a trial with no measurements; \
                             mark it infeasible instead"
                                .into(),
                        ));
                    }
                }
                t.completed_ms = epoch_millis();
                Ok(())
            })?;
        Ok(TrialResponse { trial })
    }

    // ------------------------------------------------------------------
    // Trials
    // ------------------------------------------------------------------

    pub fn list_trials(&self, req: ListTrialsRequest) -> ApiResult<ListTrialsResponse> {
        Ok(ListTrialsResponse {
            trials: self.ds.list_trials(&req.study_name)?,
        })
    }

    pub fn get_trial(&self, req: GetTrialRequest) -> ApiResult<TrialResponse> {
        Ok(TrialResponse {
            trial: self.ds.get_trial(&req.study_name, req.trial_id)?,
        })
    }

    pub fn delete_trial(&self, req: DeleteTrialRequest) -> ApiResult<EmptyResponse> {
        self.ds.delete_trial(&req.study_name, req.trial_id)?;
        Ok(EmptyResponse::default())
    }

    pub fn stop_trial(&self, req: StopTrialRequest) -> ApiResult<TrialResponse> {
        let trial = self
            .ds
            .mutate_trial(&req.study_name, req.trial_id, &mut |t| {
                if matches!(t.state, TrialState::Active | TrialState::Requested) {
                    t.state = TrialState::Stopping;
                }
                Ok(())
            })?;
        Ok(TrialResponse { trial })
    }

    pub fn list_optimal_trials(
        &self,
        req: ListOptimalTrialsRequest,
    ) -> ApiResult<ListTrialsResponse> {
        let study = self.ds.get_study(&req.study_name)?;
        let config = converters::study_config_from_proto(&study.display_name, &study.spec);
        let trials: Vec<crate::pyvizier::Trial> = self
            .ds
            .list_trials(&req.study_name)?
            .iter()
            .map(converters::trial_from_proto)
            .collect();
        let optimal = crate::pyvizier::pareto::optimal_trials(&trials, &config.metrics);
        Ok(ListTrialsResponse {
            trials: optimal.iter().map(|t| converters::trial_to_proto(t)).collect(),
        })
    }

    pub fn update_metadata(&self, req: UpdateMetadataRequest) -> ApiResult<EmptyResponse> {
        self.ds.update_metadata(&req.study_name, &req.updates)?;
        Ok(EmptyResponse::default())
    }

    // ------------------------------------------------------------------
    // Early stopping (long-running operation, §3.2)
    // ------------------------------------------------------------------

    pub fn check_early_stopping(
        self: &Arc<Self>,
        req: CheckEarlyStoppingRequest,
    ) -> ApiResult<OperationResponse> {
        let study = self.ds.get_study(&req.study_name)?;
        // Trial must exist and be running.
        let trial = self.ds.get_trial(&req.study_name, req.trial_id)?;
        if !matches!(trial.state, TrialState::Active | TrialState::Requested | TrialState::Stopping) {
            return Err(ApiError::failed_precondition(format!(
                "trial {} is not running",
                req.trial_id
            )));
        }
        let op = self.ds.create_operation(OperationProto {
            kind: OperationKind::EarlyStopping,
            study_name: req.study_name.clone(),
            trial_id: req.trial_id,
            done: false,
            created_ms: epoch_millis(),
            ..Default::default()
        })?;
        let name = op.name.clone();
        let config = converters::study_config_from_proto(&study.display_name, &study.spec);
        self.enqueue(move |svc| svc.run_early_stopping_operation(&name, &config));
        Ok(OperationResponse { operation: op })
    }

    fn run_early_stopping_operation(&self, op_name: &str, config: &StudyConfig) {
        let Ok(mut op) = self.ds.get_operation(op_name) else {
            return;
        };
        if op.done {
            return;
        }
        let decision = (|| {
            // Built-in automated stopping rule, if configured (Appendix B.1).
            if config.stopping.kind != StoppingKind::None {
                let trial = self
                    .ds
                    .get_trial(&op.study_name, op.trial_id)
                    .map(|t| converters::trial_from_proto(&t))
                    .map_err(|e| e.to_string())?;
                let completed: Vec<crate::pyvizier::Trial> = self
                    .ds
                    .query_trials(
                        &op.study_name,
                        &crate::datastore::query::TrialFilter::completed(),
                    )
                    .map_err(|e| e.to_string())?
                    .iter()
                    .map(converters::trial_from_proto)
                    .collect();
                Ok(crate::stopping::decide(config, &trial, &completed))
            } else {
                // Otherwise delegate to the study's policy.
                self.pythia
                    .run_early_stop(&EarlyStopRequest {
                        study_name: op.study_name.clone(),
                        study_config: config.clone(),
                        trial_id: op.trial_id,
                    })
                    .map_err(|e| e.to_string())
            }
        })();
        match decision {
            Ok(d) => {
                op.should_stop = d.should_stop;
                if d.should_stop {
                    // Move the trial to STOPPING so the worker sees it.
                    let _ = self.ds.mutate_trial(&op.study_name, op.trial_id, &mut |t| {
                        if matches!(t.state, TrialState::Active | TrialState::Requested) {
                            t.state = TrialState::Stopping;
                        }
                        Ok(())
                    });
                }
            }
            Err(e) => {
                op.error = e;
                self.metrics.record_error();
            }
        }
        op.done = true;
        let _ = self.ds.update_operation(op);
    }
}
