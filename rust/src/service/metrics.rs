//! Service instrumentation: per-method request counters and latency
//! histograms (paper §2: "the service architecture ... can collect data
//! and metrics over time").

use crate::util::sync::{classes, Mutex};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency histogram (microseconds).
#[derive(Debug, Default)]
pub struct Histogram {
    /// Bucket upper bounds: 1us * 2^i, 32 buckets (~= up to 1 hour).
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_micros: AtomicU64,
}

impl Histogram {
    pub fn record(&self, micros: u64) {
        let idx = (64 - micros.max(1).leading_zeros() as usize).min(31);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add(micros, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_micros(&self) -> u64 {
        self.sum_micros.load(Ordering::Relaxed)
    }

    /// Snapshot of the raw log2 bucket counts (bucket *i* covers
    /// `[2^i, 2^(i+1))` µs), for the structured `GetServiceMetrics`
    /// export.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn mean_micros(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_micros.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile_micros(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((n as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << i;
            }
        }
        1u64 << 31
    }
}

/// Front-end (TCP event loop + worker pool) instrumentation, shared
/// between [`crate::service::frontend::FrontendServer`] and the legacy
/// thread-per-connection path so benchmarks can compare like for like.
#[derive(Debug, Default)]
pub struct FrontendMetrics {
    /// Connections accepted over the server's lifetime (monotonic; the
    /// pre-pool server only had this counter).
    pub connections_total: AtomicU64,
    /// Connections currently open — a gauge: incremented on accept,
    /// decremented when the connection is dropped (client disconnect,
    /// protocol error, or shutdown drain).
    pub active_connections: AtomicU64,
    /// Ready requests waiting in the worker-pool queue right now (gauge;
    /// always 0 in legacy mode, which has no queue).
    pub queue_depth: AtomicU64,
    /// Requests served (monotonic; both modes).
    pub requests: AtomicU64,
    /// Time a ready request waited in the queue before a worker picked it
    /// up (enqueue -> dequeue), in microseconds. Pool mode only.
    pub queue_wait: Histogram,
    /// Connections currently parked with a response in flight — either
    /// awaiting a deferred handler completion (a long-poll
    /// `WaitOperation`) or holding a half-written response until the
    /// peer drains its receive window. Gauge; pool mode only.
    pub parked_responses: AtomicU64,
    /// Connections evicted by the idle timeout or the write-park
    /// deadline (monotonic; pool mode only).
    pub idle_evictions: AtomicU64,
    /// Connections refused because `max_connections` was reached
    /// (monotonic; pool mode only).
    pub connections_refused: AtomicU64,
    /// Event-loop poller wakeups, including timeout backstops
    /// (monotonic; pool mode only).
    pub loop_wakeups: AtomicU64,
    /// Cumulative per-wakeup poller work: fds scanned under poll(2),
    /// events delivered under epoll (see
    /// [`crate::util::netpoll::Poller::scan_cost`]). `loop_scan_cost /
    /// loop_wakeups` is the number C-FRONTEND-EPOLL asserts does not
    /// scale with fleet size under epoll. Monotonic; pool mode only.
    pub loop_scan_cost: AtomicU64,
}

impl FrontendMetrics {
    pub fn conn_opened(&self) {
        self.connections_total.fetch_add(1, Ordering::Relaxed);
        self.active_connections.fetch_add(1, Ordering::Relaxed);
    }

    pub fn conn_closed(&self) {
        self.active_connections.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn active_connections(&self) -> u64 {
        self.active_connections.load(Ordering::Relaxed)
    }

    pub fn connections_total(&self) -> u64 {
        self.connections_total.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn parked_inc(&self) {
        self.parked_responses.fetch_add(1, Ordering::Relaxed);
    }

    /// Saturating decrement: a racy double-unpark must not wrap the
    /// gauge to u64::MAX.
    pub fn parked_dec(&self) {
        let _ = self
            .parked_responses
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }

    pub fn parked_responses(&self) -> u64 {
        self.parked_responses.load(Ordering::Relaxed)
    }

    pub fn idle_eviction(&self) {
        self.idle_evictions.fetch_add(1, Ordering::Relaxed);
    }

    pub fn idle_evictions(&self) -> u64 {
        self.idle_evictions.load(Ordering::Relaxed)
    }

    pub fn connection_refused(&self) {
        self.connections_refused.fetch_add(1, Ordering::Relaxed);
    }

    pub fn connections_refused(&self) -> u64 {
        self.connections_refused.load(Ordering::Relaxed)
    }

    /// Record one event-loop wakeup and the poller work it cost.
    pub fn loop_wakeup(&self, scan_cost: u64) {
        self.loop_wakeups.fetch_add(1, Ordering::Relaxed);
        self.loop_scan_cost.fetch_add(scan_cost, Ordering::Relaxed);
    }

    pub fn loop_wakeups(&self) -> u64 {
        self.loop_wakeups.load(Ordering::Relaxed)
    }

    pub fn loop_scan_cost(&self) -> u64 {
        self.loop_scan_cost.load(Ordering::Relaxed)
    }

    /// Render a plain-text report fragment.
    pub fn report(&self) -> String {
        format!(
            "frontend: {} active / {} total connections ({} refused, {} evicted), \
             queue depth {}, {} parked responses, \
             {} requests (queue wait mean {:.1} us, p99 {} us), \
             {} loop wakeups ({} scan cost)\n",
            self.active_connections(),
            self.connections_total(),
            self.connections_refused(),
            self.idle_evictions(),
            self.queue_depth(),
            self.parked_responses(),
            self.requests(),
            self.queue_wait.mean_micros(),
            self.queue_wait.quantile_micros(0.99),
            self.loop_wakeups(),
            self.loop_scan_cost(),
        )
    }
}

/// Durable-store (WAL) instrumentation, shared between
/// [`crate::datastore::wal::WalDatastore`] and [`ServiceMetrics::report`]
/// so the segment lifecycle and commit-path health are visible alongside
/// the RPC metrics.
#[derive(Debug, Default)]
pub struct WalMetrics {
    /// Segment files currently on disk (`.log` + `.base`); 1 for the
    /// single-file layout. Gauge.
    pub segments: AtomicU64,
    /// Active-segment rotations performed (monotonic; segmented only).
    pub rotations: AtomicU64,
    /// Compactions completed (monotonic).
    pub compactions: AtomicU64,
    /// Wall time of each compaction (snapshot + publish + delete), in
    /// microseconds.
    pub compaction_micros: Histogram,
    /// Log bytes reclaimed by compaction (superseded segments deleted
    /// minus the base written), monotonic.
    pub reclaimed_bytes: AtomicU64,
    /// Time a writer spent in the commit path — entering the commit gate
    /// through the durability acknowledgement — in microseconds. This is
    /// where a commit stall shows up: the single-file `compact()` parks
    /// writers at the gate for the whole snapshot, the segmented
    /// compactor must not.
    pub commit_wait: Histogram,
    /// Worst commit wait observed, in microseconds (gauge; the
    /// commit-stall headline number for C-WAL-ROTATE).
    pub commit_stall_max_micros: AtomicU64,
}

impl WalMetrics {
    pub fn segments(&self) -> u64 {
        self.segments.load(Ordering::Relaxed)
    }

    pub fn rotations(&self) -> u64 {
        self.rotations.load(Ordering::Relaxed)
    }

    pub fn compactions(&self) -> u64 {
        self.compactions.load(Ordering::Relaxed)
    }

    pub fn reclaimed_bytes(&self) -> u64 {
        self.reclaimed_bytes.load(Ordering::Relaxed)
    }

    pub fn record_commit_wait(&self, micros: u64) {
        self.commit_wait.record(micros);
        self.commit_stall_max_micros.fetch_max(micros, Ordering::Relaxed);
    }

    pub fn commit_stall_max_micros(&self) -> u64 {
        self.commit_stall_max_micros.load(Ordering::Relaxed)
    }

    /// Render a plain-text report fragment.
    pub fn report(&self) -> String {
        format!(
            "wal: {} segment file(s), {} rotations, {} compactions \
             (mean {:.1} us, {} bytes reclaimed), \
             commit wait mean {:.1} us p99 {} us max {} us\n",
            self.segments(),
            self.rotations(),
            self.compactions(),
            self.compaction_micros.mean_micros(),
            self.reclaimed_bytes(),
            self.commit_wait.mean_micros(),
            self.commit_wait.quantile_micros(0.99),
            self.commit_stall_max_micros(),
        )
    }
}

/// In-memory datastore instrumentation: copy-on-write snapshot publish
/// counters plus reader/writer contention gauges, shared between
/// [`crate::datastore::memory::InMemoryDatastore`] and
/// [`ServiceMetrics::report`]. The C-DS-SNAP bench and the lockdep CI
/// legs use `locked_reads`/`snapshot_loads` to *prove* which read path
/// served a workload: in CoW mode a full compaction cycle must finish
/// with `locked_reads` unchanged.
#[derive(Debug, Default)]
pub struct DatastoreMetrics {
    /// New shard images published by writers (monotonic; CoW mode only —
    /// one per state-changing write batch).
    pub snapshot_publishes: AtomicU64,
    /// Reads served from an atomically loaded snapshot image with zero
    /// shard locks held (monotonic; CoW mode only).
    pub snapshot_loads: AtomicU64,
    /// Reads served under a shard read lock (monotonic; baseline
    /// `--datastore-cow=off` mode only — stays 0 in CoW mode).
    pub locked_reads: AtomicU64,
    /// State-changing operations applied under a shard write lock
    /// (monotonic; both modes).
    pub shard_writes: AtomicU64,
    /// Retired images currently parked in the reclamation graveyard
    /// waiting for pinned readers to drain (gauge; CoW mode only).
    pub retired_images: AtomicU64,
    /// Readers currently inside the pin window of a snapshot load
    /// (gauge; transiently nonzero under read load, CoW mode only).
    pub pinned_readers: AtomicU64,
}

impl DatastoreMetrics {
    pub fn record_snapshot_publish(&self) {
        self.snapshot_publishes.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot_publishes(&self) -> u64 {
        self.snapshot_publishes.load(Ordering::Relaxed)
    }

    pub fn record_snapshot_load(&self) {
        self.snapshot_loads.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot_loads(&self) -> u64 {
        self.snapshot_loads.load(Ordering::Relaxed)
    }

    pub fn record_locked_read(&self) {
        self.locked_reads.fetch_add(1, Ordering::Relaxed);
    }

    pub fn locked_reads(&self) -> u64 {
        self.locked_reads.load(Ordering::Relaxed)
    }

    pub fn record_shard_write(&self) {
        self.shard_writes.fetch_add(1, Ordering::Relaxed);
    }

    pub fn shard_writes(&self) -> u64 {
        self.shard_writes.load(Ordering::Relaxed)
    }

    pub fn retired_images(&self) -> u64 {
        self.retired_images.load(Ordering::Relaxed)
    }

    pub fn pinned_inc(&self) {
        self.pinned_readers.fetch_add(1, Ordering::Relaxed);
    }

    /// Saturating decrement (mirrors the front-end gauges: a racy double
    /// unpin must not wrap to u64::MAX).
    pub fn pinned_dec(&self) {
        let _ = self
            .pinned_readers
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }

    pub fn pinned_readers(&self) -> u64 {
        self.pinned_readers.load(Ordering::Relaxed)
    }

    /// Render a plain-text report fragment.
    pub fn report(&self) -> String {
        format!(
            "datastore: {} snapshot publishes, {} snapshot loads, \
             {} locked reads, {} shard writes, \
             {} retired image(s), {} pinned reader(s)\n",
            self.snapshot_publishes(),
            self.snapshot_loads(),
            self.locked_reads(),
            self.shard_writes(),
            self.retired_images(),
            self.pinned_readers(),
        )
    }
}

/// Registry of per-method metrics.
#[derive(Debug)]
pub struct ServiceMetrics {
    methods: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
    pub errors: AtomicU64,
    /// Pythia suggest invocations (one per coalesced batch).
    pub policy_runs: AtomicU64,
    /// Suggest operations served by those invocations. With per-study
    /// coalescing under load, `policy_runs < suggest_ops_served`.
    pub suggest_ops_served: AtomicU64,
    /// Suggest / early-stopping operations accepted but not yet
    /// completed — queued behind the coalescer, waiting for a policy
    /// worker, or mid-policy-run. Gauge; with async dispatch this can
    /// exceed the policy-worker count by orders of magnitude.
    pub in_flight_policy_jobs: AtomicU64,
    /// Latency from a client parking in `WaitOperation` to its watcher
    /// firing at operation completion, in microseconds.
    pub wait_wakeup: Histogram,
    /// Streaming `WaitOperation` watchers currently registered (wire v2).
    /// Gauge; the cross-version tests assert it returns to zero after
    /// `CANCEL` and mid-stream disconnect.
    pub watch_streams: AtomicU64,
    /// Front-end metrics, linked by the TCP server at start so
    /// [`ServiceMetrics::report`] covers the whole stack.
    frontend: Mutex<Option<std::sync::Arc<FrontendMetrics>>>,
    /// Durable-store metrics, linked by the launcher when the datastore
    /// is WAL-backed.
    wal: Mutex<Option<std::sync::Arc<WalMetrics>>>,
    /// In-memory datastore snapshot/contention metrics, linked by the
    /// launcher for both the pure in-memory and WAL-backed stores.
    datastore: Mutex<Option<std::sync::Arc<DatastoreMetrics>>>,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        Self {
            methods: Mutex::new(&classes::MET_METHODS, BTreeMap::new()),
            errors: AtomicU64::new(0),
            policy_runs: AtomicU64::new(0),
            suggest_ops_served: AtomicU64::new(0),
            in_flight_policy_jobs: AtomicU64::new(0),
            wait_wakeup: Histogram::default(),
            watch_streams: AtomicU64::new(0),
            frontend: Mutex::new(&classes::MET_FRONTEND, None),
            wal: Mutex::new(&classes::MET_WAL, None),
            datastore: Mutex::new(&classes::MET_DATASTORE, None),
        }
    }
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn histogram(&self, method: &str) -> std::sync::Arc<Histogram> {
        let mut m = self.methods.lock();
        m.entry(method.to_string()).or_default().clone()
    }

    pub fn record(&self, method: &str, micros: u64) {
        self.histogram(method).record(micros);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_policy_run(&self) {
        self.policy_runs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_suggest_ops(&self, n: u64) {
        self.suggest_ops_served.fetch_add(n, Ordering::Relaxed);
    }

    pub fn policy_runs(&self) -> u64 {
        self.policy_runs.load(Ordering::Relaxed)
    }

    pub fn suggest_ops_served(&self) -> u64 {
        self.suggest_ops_served.load(Ordering::Relaxed)
    }

    pub fn inc_in_flight_policy_jobs(&self) {
        self.in_flight_policy_jobs.fetch_add(1, Ordering::Relaxed);
    }

    /// Saturating decrement (a duplicate completion during crash-resume
    /// races must not wrap the gauge).
    pub fn dec_in_flight_policy_jobs(&self) {
        let _ = self
            .in_flight_policy_jobs
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }

    pub fn in_flight_policy_jobs(&self) -> u64 {
        self.in_flight_policy_jobs.load(Ordering::Relaxed)
    }

    pub fn record_wait_wakeup(&self, micros: u64) {
        self.wait_wakeup.record(micros);
    }

    pub fn inc_watch_streams(&self) {
        self.watch_streams.fetch_add(1, Ordering::Relaxed);
    }

    /// Saturating decrement (mirrors the in-flight gauge: a racy double
    /// removal must not wrap).
    pub fn dec_watch_streams(&self) {
        let _ = self
            .watch_streams
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }

    pub fn watch_streams(&self) -> u64 {
        self.watch_streams.load(Ordering::Relaxed)
    }

    /// Snapshot of the per-method latency histograms, for the structured
    /// `GetServiceMetrics` export.
    pub fn method_histograms(&self) -> Vec<(String, std::sync::Arc<Histogram>)> {
        let m = self.methods.lock();
        m.iter().map(|(n, h)| (n.clone(), h.clone())).collect()
    }

    /// Attach the front-end's metrics (called by the TCP server).
    pub fn set_frontend(&self, fe: std::sync::Arc<FrontendMetrics>) {
        *self.frontend.lock() = Some(fe);
    }

    pub fn frontend(&self) -> Option<std::sync::Arc<FrontendMetrics>> {
        self.frontend.lock().clone()
    }

    /// Attach the durable store's metrics (called by the launcher when
    /// the datastore is a [`crate::datastore::wal::WalDatastore`]).
    pub fn set_wal(&self, wal: std::sync::Arc<WalMetrics>) {
        *self.wal.lock() = Some(wal);
    }

    pub fn wal(&self) -> Option<std::sync::Arc<WalMetrics>> {
        self.wal.lock().clone()
    }

    /// Attach the in-memory datastore's snapshot/contention metrics
    /// (called by the launcher for both `memory` and `wal` stores).
    pub fn set_datastore(&self, ds: std::sync::Arc<DatastoreMetrics>) {
        *self.datastore.lock() = Some(ds);
    }

    pub fn datastore(&self) -> Option<std::sync::Arc<DatastoreMetrics>> {
        self.datastore.lock().clone()
    }

    /// Render a plain-text report (one line per method).
    pub fn report(&self) -> String {
        let m = self.methods.lock();
        let mut out = String::from("method                     count    mean_us    p50_us    p99_us\n");
        for (name, h) in m.iter() {
            out.push_str(&format!(
                "{name:<25} {:>7} {:>10.1} {:>9} {:>9}\n",
                h.count(),
                h.mean_micros(),
                h.quantile_micros(0.5),
                h.quantile_micros(0.99),
            ));
        }
        out.push_str(&format!("errors: {}\n", self.errors.load(Ordering::Relaxed)));
        out.push_str(&format!(
            "policy runs: {} (serving {} suggest ops), {} in flight\n",
            self.policy_runs(),
            self.suggest_ops_served(),
            self.in_flight_policy_jobs(),
        ));
        out.push_str(&format!(
            "wait wakeups: {} (mean {:.1} us, p99 {} us)\n",
            self.wait_wakeup.count(),
            self.wait_wakeup.mean_micros(),
            self.wait_wakeup.quantile_micros(0.99),
        ));
        if let Some(fe) = self.frontend() {
            out.push_str(&fe.report());
        }
        if let Some(wal) = self.wal() {
            out.push_str(&wal.report());
        }
        if let Some(ds) = self.datastore() {
            out.push_str(&ds.report());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::default();
        for us in [1u64, 10, 100, 1000, 10_000, 100_000] {
            for _ in 0..10 {
                h.record(us);
            }
        }
        assert_eq!(h.count(), 60);
        assert!(h.mean_micros() > 0.0);
        let p50 = h.quantile_micros(0.5);
        let p99 = h.quantile_micros(0.99);
        assert!(p50 <= p99);
        assert!(p99 >= 65_536, "p99 bucket {p99}"); // >= the 100ms-ish bucket
    }

    #[test]
    fn metrics_report_contains_methods() {
        let m = ServiceMetrics::new();
        m.record("SuggestTrials", 1500);
        m.record("SuggestTrials", 2500);
        m.record("CompleteTrial", 300);
        m.record_error();
        let r = m.report();
        assert!(r.contains("SuggestTrials"));
        assert!(r.contains("CompleteTrial"));
        assert!(r.contains("errors: 1"));
    }

    #[test]
    fn frontend_gauge_tracks_open_connections() {
        let fe = FrontendMetrics::default();
        fe.conn_opened();
        fe.conn_opened();
        fe.conn_opened();
        fe.conn_closed();
        assert_eq!(fe.active_connections(), 2);
        assert_eq!(fe.connections_total(), 3);
        fe.queue_wait.record(120);
        let m = ServiceMetrics::new();
        assert!(m.frontend().is_none());
        m.set_frontend(std::sync::Arc::new(fe));
        let r = m.report();
        assert!(r.contains("2 active / 3 total"), "{r}");
    }

    #[test]
    fn wal_metrics_report_linked() {
        let w = WalMetrics::default();
        w.segments.store(3, Ordering::Relaxed);
        w.rotations.fetch_add(2, Ordering::Relaxed);
        w.compactions.fetch_add(1, Ordering::Relaxed);
        w.record_commit_wait(500);
        w.record_commit_wait(90);
        assert_eq!(w.commit_stall_max_micros(), 500);
        let m = ServiceMetrics::new();
        assert!(m.wal().is_none());
        m.set_wal(std::sync::Arc::new(w));
        let r = m.report();
        assert!(r.contains("3 segment file(s)"), "{r}");
        assert!(r.contains("max 500 us"), "{r}");
    }

    #[test]
    fn datastore_metrics_report_linked() {
        let d = DatastoreMetrics::default();
        d.record_snapshot_publish();
        d.record_snapshot_load();
        d.record_snapshot_load();
        d.record_shard_write();
        d.pinned_inc();
        d.pinned_dec();
        d.pinned_dec(); // saturates, must not wrap
        assert_eq!(d.pinned_readers(), 0);
        assert_eq!(d.snapshot_loads(), 2);
        assert_eq!(d.locked_reads(), 0);
        let m = ServiceMetrics::new();
        assert!(m.datastore().is_none());
        m.set_datastore(std::sync::Arc::new(d));
        let r = m.report();
        assert!(r.contains("1 snapshot publishes"), "{r}");
        assert!(r.contains("2 snapshot loads"), "{r}");
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(ServiceMetrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        m.record("X", i);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.histogram("X").count(), 4000);
    }
}
