//! Shared bounded worker-pool TCP front-end.
//!
//! The paper's reference service multiplexes thousands of worker clients
//! behind `grpc.server(ThreadPoolExecutor(max_workers=100))` (Code Block
//! 4): connections do not cost a thread; only *ready requests* occupy
//! workers. This module is the Rust analogue, replacing the original
//! thread-per-connection front-end that spawned an unbounded OS thread
//! per client:
//!
//! * One **event-loop thread** (`<name>-io`) owns the listener and every
//!   idle connection. It blocks in [`Poller::wait`]
//!   ([`crate::util::netpoll`]: `epoll(7)` with incremental registration
//!   by default, the rebuilt-each-wakeup `poll(2)` set as the
//!   [`PollerKind::Poll`] baseline — `--poller=poll`) over all of them
//!   plus a [`WakePipe`]. Fds are registered / deregistered only on
//!   connection state changes (accept, hand-off to a worker, read or
//!   write re-park, close), so under epoll a wakeup costs O(ready), not
//!   O(fleet). Idle or stalled connections park here without a thread;
//!   partial frames accumulate in a per-connection [`FrameReader`] so a
//!   slow client can never pin a worker.
//! * **N worker threads** (`<name>-w<i>`) take complete framed requests
//!   off a bounded queue, run the [`ConnectionHandler`], write the
//!   response, and hand the connection back to the event loop. One frame
//!   = one job; a connection is owned by at most one thread at a time, so
//!   requests on a connection stay sequential (same contract as the old
//!   per-connection loop).
//! * **Deferred responses** ([`HandleOutcome::Pending`]): a handler that
//!   cannot answer yet (a long-poll `WaitOperation` whose operation is
//!   still running) calls [`RequestContext::defer`], stashes the returned
//!   [`ResponseHandle`], and returns `Pending`. The worker parks the
//!   connection in a ticketed registry and moves on; whoever completes
//!   the handle later (a policy-completion watcher on any thread)
//!   re-queues the connection with its response bytes. No thread waits.
//! * **Write-side parking**: a response that hits `WouldBlock` mid-write
//!   (the client stopped reading) is handed back to the event loop with
//!   its offset; the loop polls the socket for *writability* and
//!   re-queues the remainder when the peer drains its window. A slow
//!   reader costs a parked buffer, never a worker thread.
//! * **Graceful shutdown** stops the event loop (closing the listener and
//!   every idle connection), drains queued + in-flight requests up to a
//!   deadline, then joins all pool threads — no orphaned connection
//!   threads, unlike the old front-end which leaked its `vizier-conn`
//!   threads.
//!
//! * **Wire-v2 multiplexing** (`rust/docs/WIRE.md`): a connection whose
//!   first frame is a v2 `HELLO` upgrades to the multiplexed protocol.
//!   The event loop *keeps* the connection (it never hands ownership to
//!   a worker); each complete `REQUEST` frame becomes an independent
//!   [`Job::Mux`] tagged with its correlation id, answered through a
//!   thread-safe [`MuxSink`] over a shared per-connection out-buffer
//!   ([`MuxConn`]) — many requests in flight on one connection, answers
//!   in completion order. A per-connection in-flight cap throttles the
//!   read side (the loop deregisters read interest at the cap and
//!   re-arms when a request completes); `CANCEL` frames and connection
//!   death run per-correlation cancel hooks so server-side watchers
//!   never leak.
//!
//! [`FrontendMetrics`] tracks the `active_connections` and
//! `parked_responses` gauges, queue depth and queue-wait histogram; the
//! `C-FRONTEND` and `C-ASYNC-DISPATCH` benches drive 1000+ mostly-idle
//! connections / 3x-oversubscribed policy fleets through this module and
//! assert the thread budget stays at `workers + 2`.
//!
//! The locks here are registered with [`crate::util::sync::classes`]:
//! `frontend.park_slots` is always taken before (or released before
//! taking) `frontend.job_queue` — completion hooks drop the slots guard
//! before `push_job` — and the per-connection `frontend.mux_corrs` →
//! `frontend.mux_out` pair nests inside the service watcher registry and
//! outside nothing. Checked under lockdep; see `rust/docs/INVARIANTS.md`
//! for the full hierarchy.

use crate::service::metrics::FrontendMetrics;
use crate::util::netpoll::{Poller, PollerKind, WakePipe, EV_READ, EV_WRITE};
use crate::util::sync::{classes, Condvar, Mutex};
use crate::wire::codec::{decode as wire_decode, encode as wire_encode, WireMessage};
use crate::wire::framing::{
    encode_v2, is_v2_head, parse_v2, FrameKind, FrameProgress, FrameReader, Status,
    WIRE_VERSION_MAX,
};
use crate::wire::messages::HelloProto;
use std::cell::Cell;
use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How the worker should proceed after [`ConnectionHandler::handle`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandleOutcome {
    /// `out` holds the complete response; keep serving the connection.
    Reply,
    /// `out` holds the complete response; close the connection after it
    /// is flushed (protocol violations).
    Close,
    /// No response yet: the handler called [`RequestContext::defer`] and
    /// will complete the [`ResponseHandle`] later. The connection parks
    /// without occupying a worker.
    Pending,
}

/// Per-connection protocol logic run on worker threads.
pub trait ConnectionHandler: Send + Sync + 'static {
    /// Per-connection state (e.g. a lazily-opened upstream channel).
    /// Travels with the connection between the event loop and workers.
    type Conn: Send + 'static;

    /// Called on the event-loop thread at accept time — must not block.
    fn on_connect(&self) -> Self::Conn;

    /// Handle one framed request. Either write the complete response
    /// frame into `out` and return [`HandleOutcome::Reply`] /
    /// [`HandleOutcome::Close`], or call [`RequestContext::defer`] and
    /// return [`HandleOutcome::Pending`] to answer later without holding
    /// a worker.
    fn handle(
        &self,
        conn: &mut Self::Conn,
        head: u8,
        payload: &[u8],
        out: &mut Vec<u8>,
        cx: &RequestContext<'_>,
    ) -> HandleOutcome;

    /// Handle one multiplexed (wire-v2) request. Unlike [`handle`], the
    /// connection is *not* exclusively owned — many requests on the same
    /// connection run concurrently — so there is no per-connection state
    /// and no out-buffer: every answer (unary response, stream items, or
    /// an error) goes through the [`MuxSink`], from this thread or any
    /// later one. Dropping the sink without a terminal send answers the
    /// client with an internal error, so a lost sink can never hang a
    /// correlation id.
    ///
    /// The default rejects v2 requests; endpoints opt in by overriding.
    /// (v1 clients are unaffected — they never reach this path.)
    ///
    /// [`handle`]: ConnectionHandler::handle
    fn handle_mux(&self, method: u8, payload: &[u8], sink: MuxSink) {
        let _ = (method, payload);
        sink.error(Status::Unimplemented, "wire v2 not supported by this endpoint");
    }
}

/// Tuning knobs for a [`FrontendServer`].
pub struct FrontendOptions {
    /// Thread-name prefix (shows up in `/proc/self/task/*/comm`; keep it
    /// short, Linux truncates names to 15 bytes).
    pub name: &'static str,
    /// Worker threads. 0 = [`default_workers`] (the CPU count).
    pub workers: usize,
    /// Bounded queue capacity. 0 = `workers * 64`. When full, the event
    /// loop applies backpressure by pausing reads (connections stay
    /// parked, nothing is dropped). Internal re-queues — deferred
    /// completions and resumed writes — bypass the cap (they only drain
    /// already-admitted work).
    pub queue_capacity: usize,
    /// How long shutdown waits for queued + in-flight requests to drain
    /// before abandoning the remainder.
    pub drain: Duration,
    /// Evict connections that have been idle (no read progress) longer
    /// than this. `None` = never evict (connections park for free but a
    /// dead fleet accumulates fds forever).
    pub idle_timeout: Option<Duration>,
    /// Refuse new connections once `active_connections` reaches this
    /// many (0 = unlimited). Refused sockets are accepted and
    /// immediately closed so the backlog cannot wedge the listener.
    pub max_connections: usize,
    /// Readiness backend for the event loop. The default honors the
    /// `OSSVIZIER_POLLER` env knob (the CI matrix runs both), falling
    /// back to epoll.
    pub poller: PollerKind,
    /// Metrics sink; supply one to share with [`super::metrics::ServiceMetrics`].
    pub metrics: Option<Arc<FrontendMetrics>>,
    /// Per-connection cap on concurrently in-flight wire-v2 requests
    /// (advertised in the HELLO reply). At the cap the event loop stops
    /// reading the connection until a request completes — per-connection
    /// backpressure, mirroring the queue-level backpressure v1 gets from
    /// one-request-per-connection. 0 = [`DEFAULT_MUX_INFLIGHT`].
    pub mux_max_inflight: usize,
}

impl Default for FrontendOptions {
    fn default() -> Self {
        Self {
            name: "frontend",
            workers: 0,
            queue_capacity: 0,
            drain: Duration::from_secs(5),
            idle_timeout: None,
            max_connections: 0,
            poller: PollerKind::from_env(),
            metrics: None,
            mux_max_inflight: 0,
        }
    }
}

/// Default per-connection in-flight cap for multiplexed connections.
pub const DEFAULT_MUX_INFLIGHT: usize = 64;

/// Default worker count: the machine's CPU parallelism (the paper's
/// fixed `max_workers=100` sized for Google's servers; CPUs is the right
/// default for a bounded request-compute pool).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Hard cap on how long a half-written response may stay parked waiting
/// for the peer to read (the pre-parking front-end spent this budget
/// blocking a worker; now it bounds a parked buffer instead).
const WRITE_CAP: Duration = Duration::from_secs(30);

/// A live connection. Owned by exactly one thread at a time: the event
/// loop while idle/reading, a worker while a request is in flight, the
/// parked-response registry while a deferred answer is pending.
struct Conn<S> {
    stream: TcpStream,
    reader: FrameReader,
    state: S,
    metrics: Arc<FrontendMetrics>,
    /// Present once the connection negotiated wire v2 (first frame was a
    /// HELLO). Multiplexed connections stay owned by the event loop; the
    /// shared half referenced here is what worker-side [`MuxSink`]s
    /// answer through.
    mux: Option<Arc<MuxConn>>,
    /// Set the moment the first frame turns out to be v1: the connection
    /// is served by the v1 path forever (a later 0xE0.. head byte is an
    /// invalid v1 method, never a handshake).
    v1_locked: bool,
}

impl<S> Drop for Conn<S> {
    fn drop(&mut self) {
        // Closing the socket and decrementing the gauge happen together,
        // wherever the connection dies (event loop, worker, queue drop,
        // parked-registry teardown).
        self.metrics.conn_closed();
    }
}

/// Event-loop maintenance notes from worker-side mux sends, drained with
/// the re-arm channel. Both carry the connection's read token.
enum MuxNote {
    /// The out-buffer parked on `WouldBlock` (register write interest) —
    /// or died (the loop observes `is_dead` and reaps).
    WritePark(u64),
    /// A request completed below the in-flight cap: re-register read
    /// interest for a throttled connection.
    ReadRearm(u64),
}

/// One in-flight correlation id on a multiplexed connection.
struct CorrEntry {
    /// The client sent CANCEL: suppress every later send for this id.
    /// The entry stays until the sink's terminal send retires it, so a
    /// recycled correlation id cannot alias the canceled request.
    canceled: bool,
    /// Runs (outside all locks) when the request is canceled or the
    /// connection dies — handlers park stream/watch cleanup here.
    on_cancel: Option<Box<dyn FnOnce() + Send>>,
}

/// Correlation-id registry for one multiplexed connection. The note
/// sender lives inside the mutex so [`MuxConn`] stays `Sync` without
/// requiring `Sender: Sync`.
struct MuxCorrs {
    active: HashMap<u32, CorrEntry>,
    /// Live (not canceled) requests; drives the in-flight cap.
    inflight: usize,
    notes: Sender<MuxNote>,
}

/// Write half of a multiplexed connection: a shared out-buffer over a
/// dup'd fd. Sinks append frames here from any thread; a send that hits
/// `WouldBlock` parks the buffer and the event loop drains it on
/// writability — same slow-reader contract as v1 write parking.
struct MuxOut {
    stream: TcpStream,
    buf: Vec<u8>,
    off: usize,
    parked: bool,
    parked_since: Instant,
    /// A write failed or the connection was closed: drop all sends.
    dead: bool,
    notes: Sender<MuxNote>,
}

/// The shared half of a wire-v2 connection. The event loop keeps the
/// read half (frame assembly, CANCEL handling, throttling); every
/// in-flight request holds an `Arc` of this through its [`MuxSink`].
///
/// Lock order: `corrs` (`frontend.mux_corrs`) before `out`
/// (`frontend.mux_out`); both nest inside the service watcher registry
/// so streaming watchers may send while holding it.
struct MuxConn {
    /// The read token the event loop knows this connection by.
    token: u64,
    max_inflight: usize,
    /// Read interest withdrawn at the in-flight cap. Set by the loop,
    /// cleared (with a [`MuxNote::ReadRearm`]) by the completing send;
    /// both transitions happen under the `corrs` lock so a completion
    /// racing the throttle decision cannot strand the connection.
    throttled: AtomicBool,
    wake: Arc<WakePipe>,
    metrics: Arc<FrontendMetrics>,
    corrs: Mutex<MuxCorrs>,
    out: Mutex<MuxOut>,
}

impl MuxConn {
    fn write_fd(&self) -> RawFd {
        self.out.lock().stream.as_raw_fd()
    }

    fn is_dead(&self) -> bool {
        self.out.lock().dead
    }

    /// Anything that must keep the connection alive past idle eviction:
    /// in-flight requests (including streams) or undelivered bytes.
    fn busy(&self) -> bool {
        if self.corrs.lock().inflight > 0 {
            return true;
        }
        let out = self.out.lock();
        out.parked && !out.dead
    }

    fn parked_expired(&self, cap: Duration, now: Instant) -> bool {
        let out = self.out.lock();
        out.parked && !out.dead && now.duration_since(out.parked_since) > cap
    }

    /// Admit a new correlation id. `false` = duplicate (protocol
    /// violation; the caller closes the connection).
    fn begin_request(&self, corr: u32) -> bool {
        let mut c = self.corrs.lock();
        if c.active.contains_key(&corr) {
            return false;
        }
        c.active.insert(corr, CorrEntry { canceled: false, on_cancel: None });
        c.inflight += 1;
        true
    }

    /// Called by the event loop after admitting a request: decide — under
    /// the same lock completions take — whether to withdraw read
    /// interest. A completion that lands first leaves `inflight` below
    /// the cap and no throttle happens; one that lands after sees the
    /// flag and re-arms.
    fn try_throttle(&self) -> bool {
        let c = self.corrs.lock();
        if c.inflight >= self.max_inflight {
            self.throttled.store(true, Ordering::SeqCst);
            true
        } else {
            false
        }
    }

    /// Client CANCEL (or client drop). Returns the handler's cancel hook
    /// to run outside all locks. The entry is retained (marked canceled)
    /// until the terminal send retires it.
    fn cancel_corr(&self, corr: u32) -> Option<Box<dyn FnOnce() + Send>> {
        let mut c = self.corrs.lock();
        match c.active.get_mut(&corr) {
            Some(e) if !e.canceled => {
                e.canceled = true;
                c.inflight = c.inflight.saturating_sub(1);
                let hook = e.on_cancel.take();
                if self.throttled.swap(false, Ordering::SeqCst) {
                    let _ = c.notes.send(MuxNote::ReadRearm(self.token));
                    self.wake.wake();
                }
                hook
            }
            _ => None,
        }
    }

    fn corr_canceled(&self, corr: u32) -> bool {
        match self.corrs.lock().active.get(&corr) {
            Some(e) => e.canceled,
            // Retired (terminal sent) or the connection died.
            None => true,
        }
    }

    /// Install a cancel hook; hands it back when the request is already
    /// canceled/gone so the caller can run it immediately (outside the
    /// lock).
    fn set_cancel_hook(
        &self,
        corr: u32,
        hook: Box<dyn FnOnce() + Send>,
    ) -> Option<Box<dyn FnOnce() + Send>> {
        let mut c = self.corrs.lock();
        match c.active.get_mut(&corr) {
            Some(e) if !e.canceled => {
                e.on_cancel = Some(hook);
                None
            }
            _ => Some(hook),
        }
    }

    /// Send the frame that finishes a correlation id (RESPONSE,
    /// STREAM_END, or ERROR), retiring its entry and re-arming a
    /// throttled read side. Canceled/retired ids send nothing.
    fn send_terminal(&self, corr: u32, kind: FrameKind, body: &[u8]) {
        let deliver = {
            let mut c = self.corrs.lock();
            match c.active.remove(&corr) {
                // CANCEL already decremented inflight and unthrottled.
                Some(e) if e.canceled => false,
                Some(_) => {
                    c.inflight = c.inflight.saturating_sub(1);
                    if self.throttled.swap(false, Ordering::SeqCst) {
                        let _ = c.notes.send(MuxNote::ReadRearm(self.token));
                        self.wake.wake();
                    }
                    true
                }
                None => false,
            }
        };
        if !deliver {
            return;
        }
        match encode_v2(kind, corr, body) {
            Ok(frame) => self.send_raw(&frame),
            Err(_) => {
                // Oversized response: the client must still see the id
                // terminate. The error body always fits.
                let mut eb = vec![Status::Internal as u8];
                eb.extend_from_slice(b"response exceeds frame limit");
                if let Ok(frame) = encode_v2(FrameKind::Error, corr, &eb) {
                    self.send_raw(&frame);
                }
            }
        }
    }

    /// Send a non-terminal STREAM_ITEM; dropped silently once the id is
    /// canceled or retired.
    fn send_item(&self, corr: u32, body: &[u8]) {
        let alive = {
            let c = self.corrs.lock();
            matches!(c.active.get(&corr), Some(e) if !e.canceled)
        };
        if !alive {
            return;
        }
        if let Ok(frame) = encode_v2(FrameKind::StreamItem, corr, body) {
            self.send_raw(&frame);
        }
    }

    /// Append a complete frame to the out-buffer and flush as much as
    /// the socket accepts. `WouldBlock` parks the buffer (the event loop
    /// takes over on writability); a hard error marks the connection
    /// dead and asks the loop to reap it.
    fn send_raw(&self, frame: &[u8]) {
        let mut out = self.out.lock();
        if out.dead {
            return;
        }
        out.buf.extend_from_slice(frame);
        if !out.parked {
            self.flush_locked(&mut out);
        }
    }

    /// Event loop, on writability: drain what the socket will take.
    /// Returns `(still_parked, dead)`.
    fn flush_ready(&self) -> (bool, bool) {
        let mut out = self.out.lock();
        if out.dead {
            return (false, true);
        }
        self.flush_locked(&mut out);
        (out.parked, out.dead)
    }

    fn flush_locked(&self, out: &mut MuxOut) {
        loop {
            if out.off >= out.buf.len() {
                out.buf.clear();
                out.off = 0;
                if out.parked {
                    out.parked = false;
                    self.metrics.parked_dec();
                }
                return;
            }
            let res = { Write::write(&mut out.stream, &out.buf[out.off..]) };
            match res {
                Ok(0) => return self.die_locked(out),
                Ok(n) => out.off += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if !out.parked {
                        out.parked = true;
                        out.parked_since = Instant::now();
                        self.metrics.parked_inc();
                        let _ = out.notes.send(MuxNote::WritePark(self.token));
                        self.wake.wake();
                    }
                    return;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return self.die_locked(out),
            }
        }
    }

    fn die_locked(&self, out: &mut MuxOut) {
        out.dead = true;
        out.buf.clear();
        out.off = 0;
        if out.parked {
            out.parked = false;
            self.metrics.parked_dec();
        }
        // The loop routes WritePark to either "register write interest"
        // or "reap" by checking is_dead.
        let _ = out.notes.send(MuxNote::WritePark(self.token));
        self.wake.wake();
    }

    /// Tear the connection down: kill the write half (shutting the
    /// socket down so the peer sees EOF even while sinks still hold
    /// `Arc`s of the dup'd fd) and cancel every in-flight request.
    /// Returns the cancel hooks for the caller to run outside all locks.
    #[must_use]
    fn close(&self) -> Vec<Box<dyn FnOnce() + Send>> {
        let mut hooks = Vec::new();
        {
            let mut c = self.corrs.lock();
            for (_corr, e) in c.active.drain() {
                if !e.canceled {
                    if let Some(h) = e.on_cancel {
                        hooks.push(h);
                    }
                }
            }
            c.inflight = 0;
        }
        {
            let mut out = self.out.lock();
            out.dead = true;
            out.buf.clear();
            out.off = 0;
            if out.parked {
                out.parked = false;
                self.metrics.parked_dec();
            }
            let _ = out.stream.shutdown(std::net::Shutdown::Both);
        }
        hooks
    }
}

/// The answer channel for one multiplexed request, handed to
/// [`ConnectionHandler::handle_mux`]. Thread-safe and `Arc`-shareable:
/// a streaming handler clones it into a watcher and keeps sending
/// [`stream_item`](Self::stream_item)s until it finishes with
/// [`stream_end`](Self::stream_end). Exactly one terminal send wins;
/// the rest (and everything after) are no-ops. Dropping the sink
/// without a terminal send reports an internal error to the client.
pub struct MuxSink {
    mux: Arc<MuxConn>,
    corr: u32,
    terminated: AtomicBool,
}

impl MuxSink {
    /// The request's correlation id (diagnostics only).
    pub fn corr(&self) -> u32 {
        self.corr
    }

    /// Did the client cancel this request (or the connection die)?
    /// Streaming handlers poll this to stop early; unary handlers can
    /// ignore it — sends to canceled ids are dropped.
    pub fn canceled(&self) -> bool {
        self.mux.corr_canceled(self.corr)
    }

    /// Register cleanup to run when the request is canceled or the
    /// connection dies (runs at most once, outside all frontend locks).
    /// If the request is already canceled the hook runs immediately.
    pub fn on_cancel(&self, hook: Box<dyn FnOnce() + Send>) {
        if let Some(h) = self.mux.set_cancel_hook(self.corr, hook) {
            h();
        }
    }

    /// Terminal: answer with an OK unary response.
    pub fn respond_ok<M: WireMessage>(&self, msg: &M) {
        self.terminal(FrameKind::Response, &wire_encode(msg));
    }

    /// Terminal: answer with a pre-encoded response payload.
    pub fn respond_bytes(&self, payload: &[u8]) {
        self.terminal(FrameKind::Response, payload);
    }

    /// Terminal: answer with an error status.
    pub fn error(&self, status: Status, message: &str) {
        let mut body = vec![status as u8];
        body.extend_from_slice(message.as_bytes());
        self.terminal(FrameKind::Error, &body);
    }

    /// Terminal: translate a complete v1 response frame
    /// (`[u32 len][status][payload]`, as built by `write_ok`/`write_err`
    /// into a buffer) into the equivalent v2 RESPONSE or ERROR — the
    /// bridge that lets v1 dispatch code serve v2 requests unchanged.
    pub fn respond_v1_frame(&self, frame: &[u8]) {
        if frame.len() < 5 {
            return self.error(Status::Internal, "malformed response frame");
        }
        let status = frame[4];
        let payload = &frame[5..];
        if status == Status::Ok as u8 {
            self.terminal(FrameKind::Response, payload);
        } else {
            let mut body = vec![status];
            body.extend_from_slice(payload);
            self.terminal(FrameKind::Error, &body);
        }
    }

    /// Non-terminal: push one STREAM_ITEM.
    pub fn stream_item<M: WireMessage>(&self, msg: &M) {
        self.mux.send_item(self.corr, &wire_encode(msg));
    }

    /// Terminal: close the stream.
    pub fn stream_end(&self) {
        self.terminal(FrameKind::StreamEnd, &[]);
    }

    fn terminal(&self, kind: FrameKind, body: &[u8]) {
        if self.terminated.swap(true, Ordering::SeqCst) {
            return;
        }
        self.mux.send_terminal(self.corr, kind, body);
    }
}

impl Drop for MuxSink {
    fn drop(&mut self) {
        if !self.terminated.swap(true, Ordering::SeqCst) {
            let mut body = vec![Status::Internal as u8];
            body.extend_from_slice(b"request dropped");
            self.mux.send_terminal(self.corr, FrameKind::Error, &body);
        }
    }
}

/// A (possibly partially written) response on its way out.
struct WriteJob<S> {
    conn: Conn<S>,
    frame: Vec<u8>,
    off: usize,
    /// Re-arm the connection for reading once the frame is flushed?
    keep: bool,
    /// Parked writes past this instant are abandoned (connection closed).
    deadline: Instant,
}

/// One unit of worker-pool work.
enum Job<S> {
    /// A complete framed request from the event loop.
    Request { conn: Conn<S>, head: u8, payload: Vec<u8>, enqueued: Instant },
    /// A complete multiplexed (wire-v2) request. The connection stays
    /// with the event loop; only the sink travels. Dropping the job
    /// (queue abort at shutdown) answers the client through the sink's
    /// drop guard.
    Mux { sink: MuxSink, method: u8, payload: Vec<u8>, enqueued: Instant },
    /// A response to (continue) writing: a deferred completion, a
    /// long-poll timeout flush, or a write resumed after the peer
    /// drained its receive window.
    Write(WriteJob<S>),
}

/// Connections returned from workers to the event loop.
enum Back<S> {
    /// Served: park for the next request.
    Read(Conn<S>),
    /// Response stalled mid-write: park for writability.
    Write(WriteJob<S>),
}

/// A ticketed slot for a deferred response. The worker and the completer
/// race to the slot; whichever arrives second pairs the connection with
/// its response bytes and re-queues the write.
enum ParkSlot<S> {
    /// Ticket reserved by [`RequestContext::defer`]; the worker still
    /// holds the connection.
    Reserved { deadline: Option<Instant>, timeout_frame: Vec<u8> },
    /// Connection parked, waiting for the deferred response.
    AwaitingResponse { conn: Conn<S>, deadline: Option<Instant>, timeout_frame: Vec<u8> },
    /// Response arrived before the worker parked the connection.
    AwaitingConn { frame: Vec<u8>, keep: bool },
}

/// State shared between the event loop, workers, completers, and
/// shutdown.
struct Shared<S> {
    queue: Mutex<VecDeque<Job<S>>>,
    job_ready: Condvar,
    space_ready: Condvar,
    capacity: usize,
    /// Workers exit once this is set and the queue is empty.
    worker_stop: AtomicBool,
    /// Set when the drain deadline passes: abort in-flight writes.
    force_abort: AtomicBool,
    active_jobs: AtomicUsize,
    /// Deferred-response registry (ticket -> slot).
    slots: Mutex<HashMap<u64, ParkSlot<S>>>,
    next_ticket: AtomicU64,
    metrics: Arc<FrontendMetrics>,
}

impl<S> Shared<S> {
    fn pending(&self) -> usize {
        self.queue.lock().len() + self.active_jobs.load(Ordering::SeqCst)
    }

    fn abort_pending(&self) {
        let dropped = {
            let mut q = self.queue.lock();
            let n = q.len();
            q.clear(); // drops Jobs -> closes their connections
            n
        };
        if dropped > 0 {
            self.metrics.queue_depth.fetch_sub(dropped as u64, Ordering::Relaxed);
        }
        self.force_abort.store(true, Ordering::SeqCst);
    }

    fn stop_workers(&self) {
        self.worker_stop.store(true, Ordering::SeqCst);
        self.job_ready.notify_all();
        self.space_ready.notify_all();
    }

    /// Internal enqueue for deferred completions / resumed writes: no
    /// capacity check (bounded by the number of admitted connections),
    /// callable from any thread.
    fn push_job(&self, job: Job<S>) {
        let mut q = self.queue.lock();
        q.push_back(job);
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        drop(q);
        self.job_ready.notify_one();
    }

    /// Drop every deferred-response slot (closing parked connections).
    /// Called at shutdown after the workers have been joined; later
    /// completions find no slot and are no-ops.
    fn clear_parked(&self) {
        let drained: Vec<ParkSlot<S>> =
            self.slots.lock().drain().map(|(_, slot)| slot).collect();
        for slot in drained {
            if matches!(slot, ParkSlot::AwaitingResponse { .. }) {
                self.metrics.parked_dec();
            }
        }
    }
}

/// Type-erased hooks a worker hands to handlers through
/// [`RequestContext`] (erased so [`ResponseHandle`] has no generic
/// parameter and can be stored by service-layer watcher registries).
#[derive(Clone)]
struct DeferHooks {
    reserve: Arc<dyn Fn(Option<Instant>, Vec<u8>) -> u64 + Send + Sync>,
    /// Returns whether the frame was delivered toward a live ticket
    /// (false: the ticket timed out / was evicted and the bytes were
    /// dropped).
    complete: Arc<dyn Fn(u64, Vec<u8>, bool) -> bool + Send + Sync>,
    cancel: Arc<dyn Fn(u64) + Send + Sync>,
}

/// Per-request context given to [`ConnectionHandler::handle`].
pub struct RequestContext<'a> {
    hooks: &'a DeferHooks,
    ticket: Cell<Option<u64>>,
}

impl RequestContext<'_> {
    /// Reserve a deferred-response ticket. Returns a [`ResponseHandle`]
    /// to complete later from any thread; the handler must then return
    /// [`HandleOutcome::Pending`].
    ///
    /// If `deadline` is reached before the handle is completed, the
    /// event loop answers the parked connection with `timeout_frame`
    /// (and keeps serving it) — the deferred-response analogue of a
    /// long-poll timeout. A handle dropped without completing aborts
    /// the ticket: the parked connection is closed.
    pub fn defer(&self, deadline: Option<Instant>, timeout_frame: Vec<u8>) -> ResponseHandle {
        let ticket = (self.hooks.reserve)(deadline, timeout_frame);
        self.ticket.set(Some(ticket));
        ResponseHandle {
            ticket,
            complete: Some(Arc::clone(&self.hooks.complete)),
            cancel: Arc::clone(&self.hooks.cancel),
        }
    }
}

/// Completes a deferred response from any thread. Consumed by
/// [`complete`](Self::complete); dropping it uncompleted aborts the
/// ticket (closing the parked connection), so a vanished watcher cannot
/// leak a parked client forever.
pub struct ResponseHandle {
    ticket: u64,
    complete: Option<Arc<dyn Fn(u64, Vec<u8>, bool) -> bool + Send + Sync>>,
    cancel: Arc<dyn Fn(u64) + Send + Sync>,
}

impl ResponseHandle {
    /// Deliver the response frame and keep serving the connection.
    /// Returns false when the ticket is gone (the long-poll timed out
    /// or the connection was evicted) and the frame was dropped —
    /// callers can use this to keep wakeup metrics honest.
    pub fn complete(mut self, frame: Vec<u8>) -> bool {
        match self.complete.take() {
            Some(c) => c(self.ticket, frame, true),
            None => false,
        }
    }

    /// Deliver the response frame, then close the connection.
    pub fn complete_and_close(mut self, frame: Vec<u8>) -> bool {
        match self.complete.take() {
            Some(c) => c(self.ticket, frame, false),
            None => false,
        }
    }
}

impl Drop for ResponseHandle {
    fn drop(&mut self) {
        if self.complete.is_some() {
            (self.cancel)(self.ticket);
        }
    }
}

/// A running event-loop + worker-pool server. Dropping it performs the
/// same graceful shutdown as [`FrontendServer::shutdown`].
pub struct FrontendServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    wake: Arc<WakePipe>,
    io_thread: Option<JoinHandle<()>>,
    worker_threads: Vec<JoinHandle<()>>,
    metrics: Arc<FrontendMetrics>,
    drain: Duration,
    /// Guards shutdown_inner: an explicit `shutdown()` consumes `self`,
    /// which runs Drop — the sequence must not execute twice.
    shutdown_done: bool,
    // Type-erased handles into the generic Shared<S>.
    pending: Box<dyn Fn() -> usize + Send + Sync>,
    abort_pending: Box<dyn Fn() + Send + Sync>,
    stop_workers: Box<dyn Fn() + Send + Sync>,
    clear_parked: Box<dyn Fn() + Send + Sync>,
}

impl FrontendServer {
    /// Bind `addr` and start the event loop and worker pool.
    pub fn start<H: ConnectionHandler>(
        handler: H,
        addr: &str,
        opts: FrontendOptions,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;

        let workers = if opts.workers == 0 { default_workers() } else { opts.workers };
        let capacity =
            if opts.queue_capacity == 0 { workers * 64 } else { opts.queue_capacity };
        let metrics = opts.metrics.unwrap_or_default();
        let handler = Arc::new(handler);
        let stop = Arc::new(AtomicBool::new(false));
        let wake = Arc::new(WakePipe::new()?);
        // Build and seed the poller here so a failure (no epoll support,
        // fd exhaustion) surfaces as a start error instead of a dead
        // event loop. The wake pipe and listener are registered exactly
        // once; everything else is per-connection.
        let mut poller = Poller::new(opts.poller)?;
        poller.register(wake.read_fd(), TOK_WAKE, EV_READ)?;
        poller.register(listener.as_raw_fd(), TOK_LISTENER, EV_READ)?;
        let shared = Arc::new(Shared::<H::Conn> {
            queue: Mutex::new(&classes::FE_QUEUE, VecDeque::new()),
            job_ready: Condvar::new(),
            space_ready: Condvar::new(),
            capacity,
            worker_stop: AtomicBool::new(false),
            force_abort: AtomicBool::new(false),
            active_jobs: AtomicUsize::new(0),
            slots: Mutex::new(&classes::FE_SLOTS, HashMap::new()),
            next_ticket: AtomicU64::new(1),
            metrics: Arc::clone(&metrics),
        });
        let (rearm_tx, rearm_rx) = mpsc::channel::<Back<H::Conn>>();

        let hooks = {
            let reserve = {
                let shared = Arc::clone(&shared);
                Arc::new(move |deadline, timeout_frame| {
                    let ticket = shared.next_ticket.fetch_add(1, Ordering::SeqCst);
                    shared
                        .slots
                        .lock()
                        .insert(ticket, ParkSlot::Reserved { deadline, timeout_frame });
                    ticket
                }) as Arc<dyn Fn(Option<Instant>, Vec<u8>) -> u64 + Send + Sync>
            };
            let complete = {
                let shared = Arc::clone(&shared);
                Arc::new(move |ticket: u64, frame: Vec<u8>, keep: bool| {
                    let mut slots = shared.slots.lock();
                    match slots.remove(&ticket) {
                        Some(ParkSlot::Reserved { .. }) => {
                            // Completed before the worker parked the
                            // connection: leave the bytes for it.
                            slots.insert(ticket, ParkSlot::AwaitingConn { frame, keep });
                            true
                        }
                        Some(ParkSlot::AwaitingResponse { conn, .. }) => {
                            drop(slots);
                            shared.metrics.parked_dec();
                            shared.push_job(Job::Write(WriteJob {
                                conn,
                                frame,
                                off: 0,
                                keep,
                                deadline: Instant::now() + WRITE_CAP,
                            }));
                            true
                        }
                        // Already completed: the first response wins.
                        Some(other @ ParkSlot::AwaitingConn { .. }) => {
                            slots.insert(ticket, other);
                            false
                        }
                        // Timed out / evicted / canceled meanwhile:
                        // drop the bytes.
                        None => false,
                    }
                }) as Arc<dyn Fn(u64, Vec<u8>, bool) -> bool + Send + Sync>
            };
            let cancel = {
                let shared = Arc::clone(&shared);
                Arc::new(move |ticket: u64| {
                    let slot = shared.slots.lock().remove(&ticket);
                    if matches!(slot, Some(ParkSlot::AwaitingResponse { .. })) {
                        shared.metrics.parked_dec();
                    }
                    // Dropping an AwaitingResponse slot closes its conn.
                }) as Arc<dyn Fn(u64) + Send + Sync>
            };
            DeferHooks { reserve, complete, cancel }
        };

        // On any partial spawn failure, already-running workers must be
        // stopped and joined — not leaked looping on an orphan queue.
        let reap = |threads: Vec<JoinHandle<()>>| {
            shared.stop_workers();
            for t in threads {
                let _ = t.join();
            }
        };
        let mut worker_threads = Vec::with_capacity(workers);
        for i in 0..workers {
            let spawn = {
                let handler = Arc::clone(&handler);
                let shared = Arc::clone(&shared);
                let tx = rearm_tx.clone();
                let wake = Arc::clone(&wake);
                let hooks = hooks.clone();
                std::thread::Builder::new()
                    .name(format!("{}-w{i}", opts.name))
                    .spawn(move || worker_loop(handler, shared, tx, wake, hooks))
            };
            match spawn {
                Ok(t) => worker_threads.push(t),
                Err(e) => {
                    reap(worker_threads);
                    return Err(e);
                }
            }
        }
        drop(rearm_tx);

        let loop_opts = LoopOptions {
            idle_timeout: opts.idle_timeout,
            max_connections: opts.max_connections,
            mux_max_inflight: if opts.mux_max_inflight == 0 {
                DEFAULT_MUX_INFLIGHT
            } else {
                opts.mux_max_inflight
            },
        };
        let io_spawn = {
            let handler = Arc::clone(&handler);
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            let wake = Arc::clone(&wake);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new().name(format!("{}-io", opts.name)).spawn(move || {
                io_loop(
                    listener, handler, shared, rearm_rx, wake, stop, metrics, poller, loop_opts,
                )
            })
        };
        let io_thread = match io_spawn {
            Ok(t) => t,
            Err(e) => {
                reap(worker_threads);
                return Err(e);
            }
        };

        let s1 = Arc::clone(&shared);
        let s2 = Arc::clone(&shared);
        let s3 = Arc::clone(&shared);
        let s4 = shared;
        Ok(Self {
            addr: local,
            stop,
            wake,
            io_thread: Some(io_thread),
            worker_threads,
            metrics,
            drain: opts.drain,
            shutdown_done: false,
            pending: Box::new(move || s1.pending()),
            abort_pending: Box::new(move || s2.abort_pending()),
            stop_workers: Box::new(move || s3.stop_workers()),
            clear_parked: Box::new(move || s4.clear_parked()),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &Arc<FrontendMetrics> {
        &self.metrics
    }

    /// Graceful shutdown: stop accepting and reading, drain queued and
    /// in-flight requests up to the drain deadline, then join every pool
    /// thread and drop every parked connection. On return no
    /// `<name>-io` / `<name>-w*` threads remain; deferred completions
    /// that fire afterwards are no-ops.
    ///
    /// The deadline bounds queued work and response writes; a handler
    /// blocked inside an unbounded syscall (e.g. a remote read with no
    /// timeout) cannot be interrupted and still delays the final join —
    /// handlers doing remote I/O should use timeouts or cooperative
    /// cancellation.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.shutdown_done {
            return;
        }
        self.shutdown_done = true;
        self.stop.store(true, Ordering::SeqCst);
        self.wake.wake();
        if let Some(t) = self.io_thread.take() {
            let _ = t.join();
        }
        // Drain: let workers finish what is queued/in flight.
        let deadline = Instant::now() + self.drain;
        while (self.pending)() > 0 {
            if Instant::now() >= deadline {
                (self.abort_pending)();
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        (self.stop_workers)();
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
        (self.clear_parked)();
    }
}

impl Drop for FrontendServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

struct LoopOptions {
    idle_timeout: Option<Duration>,
    max_connections: usize,
    mux_max_inflight: usize,
}

/// A connection parked in the event loop, plus the loop-side registration
/// bookkeeping the mux path needs.
struct Parked<S> {
    conn: Conn<S>,
    /// Last read progress (idle-eviction clock).
    last: Instant,
    /// Read interest withdrawn at the mux in-flight cap.
    throttled: bool,
    /// Poller token under which the (dup'd) write fd is registered while
    /// the mux out-buffer is parked.
    wtoken: Option<u64>,
}

/// Fixed poller tokens: the wake pipe and the listener are registered
/// once at start; connection tokens count up from [`FIRST_CONN_TOKEN`]
/// and are never reused within one server's lifetime, so a stale event
/// can never alias a newer connection.
const TOK_WAKE: u64 = 0;
const TOK_LISTENER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// The event loop: accepts, parks idle connections, assembles frames,
/// feeds ready requests to the worker queue, re-arms write-parked
/// responses, and sweeps idle / expired parked state.
///
/// Registration-state invariant: an fd is registered with `poller` iff
/// its connection is owned by this loop (present in `conns` or
/// `wparked`, or it is the wake pipe / listener). Every path that moves
/// a connection out — hand-off to a worker, eviction, reap — must
/// deregister *before* the connection can be closed elsewhere, because a
/// closed fd's number may be reused by the next `accept`.
#[allow(clippy::too_many_arguments)]
fn io_loop<H: ConnectionHandler>(
    listener: TcpListener,
    handler: Arc<H>,
    shared: Arc<Shared<H::Conn>>,
    rearm_rx: Receiver<Back<H::Conn>>,
    wake: Arc<WakePipe>,
    stop: Arc<AtomicBool>,
    metrics: Arc<FrontendMetrics>,
    mut poller: Poller,
    opts: LoopOptions,
) {
    // Read-parked connections (token -> conn + loop bookkeeping).
    let mut conns: HashMap<u64, Parked<H::Conn>> = HashMap::new();
    // Write-parked v1 responses (token -> half-written job).
    let mut wparked: HashMap<u64, WriteJob<H::Conn>> = HashMap::new();
    // Write-parked mux out-buffers (write token -> read token).
    let mut mux_wparked: HashMap<u64, u64> = HashMap::new();
    // Maintenance notes from worker-side mux sends; the senders live
    // inside each MuxConn's mutexes.
    let (mux_tx, mux_rx) = mpsc::channel::<MuxNote>();
    let mut next_token: u64 = FIRST_CONN_TOKEN;
    let mut ready_read = Vec::new();
    let mut ready_write = Vec::new();
    let mut ready_mwrite = Vec::new();
    // The poll timeout is a liveness backstop and the sweep cadence
    // (idle eviction, parked-response deadlines); stop flags and re-arms
    // arrive via the wake pipe.
    const POLL_MS: i32 = 250;
    let mut last_sweep = Instant::now();
    let mut prev_scan = poller.scan_cost();

    while !stop.load(Ordering::SeqCst) {
        let mut wake_ready = false;
        let mut accept_ready = false;
        ready_read.clear();
        ready_write.clear();
        ready_mwrite.clear();
        match poller.wait(POLL_MS) {
            Ok(events) => {
                for ev in events {
                    match ev.token {
                        TOK_WAKE => wake_ready = true,
                        TOK_LISTENER => accept_ready = true,
                        // Route by owner: the read-parked, write-parked
                        // and mux-write registries never share a token.
                        tok if conns.contains_key(&tok) => ready_read.push(tok),
                        tok if wparked.contains_key(&tok) => ready_write.push(tok),
                        tok if mux_wparked.contains_key(&tok) => ready_mwrite.push(tok),
                        // Token retired between the kernel queuing the
                        // event and us reading it: ignore.
                        _ => {}
                    }
                }
            }
            Err(_) => {
                // A persistent poller error (EBADF after an fd race,
                // etc.) must not busy-spin the loop at 100% CPU.
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        }
        metrics.loop_wakeup(poller.scan_cost() - prev_scan);
        prev_scan = poller.scan_cost();
        if stop.load(Ordering::SeqCst) {
            break;
        }
        if wake_ready {
            // Drain before harvesting re-arms: a wake racing in for a
            // re-arm this iteration misses leaves the pipe readable, so
            // the next wait returns immediately instead of losing it.
            wake.drain();
        }

        // Reclaim connections whose request a worker just finished (any
        // bytes the client pipelined meanwhile are still in the kernel
        // buffer and will show up in the next poll), and responses that
        // stalled mid-write. Registration failure here means the loop
        // could never see the fd again — drop the connection instead of
        // leaking it into an unpollable limbo.
        while let Ok(back) = rearm_rx.try_recv() {
            match back {
                Back::Read(conn) => {
                    if poller.register(conn.stream.as_raw_fd(), next_token, EV_READ).is_ok() {
                        conns.insert(
                            next_token,
                            Parked { conn, last: Instant::now(), throttled: false, wtoken: None },
                        );
                    }
                }
                Back::Write(wj) => {
                    if poller.register(wj.conn.stream.as_raw_fd(), next_token, EV_WRITE).is_ok()
                    {
                        wparked.insert(next_token, wj);
                    } else {
                        metrics.parked_dec();
                    }
                }
            }
            next_token += 1;
        }

        // Mux maintenance notes from worker-side sends.
        while let Ok(note) = mux_rx.try_recv() {
            match note {
                MuxNote::ReadRearm(tok) => {
                    let mut failed = false;
                    if let Some(p) = conns.get_mut(&tok) {
                        if p.throttled {
                            // Deliberate token reuse: the connection never
                            // left this loop, so the token still refers to
                            // it (the no-reuse rule guards hand-offs).
                            if poller.register(p.conn.stream.as_raw_fd(), tok, EV_READ).is_ok() {
                                p.throttled = false;
                            } else {
                                // The loop can never see this fd again.
                                failed = true;
                            }
                        }
                    }
                    if failed {
                        reap_conn(tok, &mut conns, &mut mux_wparked, &mut poller);
                    }
                }
                MuxNote::WritePark(tok) => {
                    let dead = conns
                        .get(&tok)
                        .and_then(|p| p.conn.mux.as_ref())
                        .map(|m| m.is_dead());
                    match dead {
                        Some(true) => {
                            reap_conn(tok, &mut conns, &mut mux_wparked, &mut poller);
                        }
                        Some(false) => {
                            let mut failed = false;
                            if let Some(p) = conns.get_mut(&tok) {
                                if p.wtoken.is_none() {
                                    if let Some(m) = &p.conn.mux {
                                        let wtok = next_token;
                                        next_token += 1;
                                        if poller.register(m.write_fd(), wtok, EV_WRITE).is_ok() {
                                            p.wtoken = Some(wtok);
                                            mux_wparked.insert(wtok, tok);
                                        } else {
                                            // Can never learn about
                                            // writability: drop the conn.
                                            failed = true;
                                        }
                                    }
                                }
                            }
                            if failed {
                                reap_conn(tok, &mut conns, &mut mux_wparked, &mut poller);
                            }
                        }
                        None => {}
                    }
                }
            }
        }

        if accept_ready {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if opts.max_connections > 0
                            && metrics.active_connections() >= opts.max_connections as u64
                        {
                            // Over the cap: accept (to clear the
                            // backlog) and close immediately.
                            metrics.connection_refused();
                            drop(stream);
                            continue;
                        }
                        let _ = stream.set_nonblocking(true);
                        let _ = stream.set_nodelay(true);
                        if poller.register(stream.as_raw_fd(), next_token, EV_READ).is_err() {
                            drop(stream);
                            continue;
                        }
                        metrics.conn_opened();
                        conns.insert(
                            next_token,
                            Parked {
                                conn: Conn {
                                    stream,
                                    reader: FrameReader::new(),
                                    state: handler.on_connect(),
                                    metrics: Arc::clone(&metrics),
                                    mux: None,
                                    v1_locked: false,
                                },
                                last: Instant::now(),
                                throttled: false,
                                wtoken: None,
                            },
                        );
                        next_token += 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    // Per-connection transients (peer reset before we
                    // accepted): skip that connection, keep accepting.
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::ConnectionAborted
                                | std::io::ErrorKind::ConnectionReset
                                | std::io::ErrorKind::Interrupted
                        ) =>
                    {
                        continue;
                    }
                    Err(_) => {
                        // Resource exhaustion (EMFILE/ENFILE): the
                        // pending connection stays in the backlog, so
                        // level-triggered poll would report the listener
                        // ready again immediately. Back off instead of
                        // spinning until an fd frees.
                        std::thread::sleep(Duration::from_millis(10));
                        break;
                    }
                }
            }
        }

        for &tok in &ready_read {
            drive_readable(
                tok,
                &mut conns,
                &mut mux_wparked,
                &mut poller,
                &shared,
                &stop,
                &wake,
                &metrics,
                &mux_tx,
                opts.mux_max_inflight,
            );
        }

        // The peer drained its window (or hung up — the write observes
        // which): hand the remainder back to a worker.
        for &tok in &ready_write {
            if let Some(wj) = wparked.remove(&tok) {
                let _ = poller.deregister(wj.conn.stream.as_raw_fd());
                metrics.parked_dec();
                shared.push_job(Job::Write(wj));
            }
        }

        // A mux peer drained its window: flush the shared out-buffer
        // from the loop (workers only ever append).
        for &wtok in &ready_mwrite {
            let Some(&ctok) = mux_wparked.get(&wtok) else { continue };
            let mut reap = false;
            if let Some(p) = conns.get_mut(&ctok) {
                if let Some(m) = &p.conn.mux {
                    let mux = Arc::clone(m);
                    let wfd = mux.write_fd();
                    let _ = poller.deregister(wfd);
                    mux_wparked.remove(&wtok);
                    p.wtoken = None;
                    let (still_parked, dead) = mux.flush_ready();
                    if dead {
                        reap = true;
                    } else if still_parked {
                        let nwtok = next_token;
                        next_token += 1;
                        if poller.register(wfd, nwtok, EV_WRITE).is_ok() {
                            p.wtoken = Some(nwtok);
                            mux_wparked.insert(nwtok, ctok);
                        } else {
                            reap = true;
                        }
                    }
                }
            }
            if reap {
                reap_conn(ctok, &mut conns, &mut mux_wparked, &mut poller);
            }
        }

        // Sweeps. Readiness events can wake the loop far more often
        // than POLL_MS; throttle to the intended cadence so a busy
        // server does not pay an O(connections + parked) scan — and the
        // slots-lock hold contending with completion wakeups — per
        // event.
        if last_sweep.elapsed() >= Duration::from_millis(POLL_MS as u64) {
            last_sweep = Instant::now();
            if let Some(idle) = opts.idle_timeout {
                let now = Instant::now();
                // A mux connection with in-flight requests (including
                // open watch streams) or undelivered bytes is not idle,
                // however long the read side has been silent.
                let evict: Vec<u64> = conns
                    .iter()
                    .filter_map(|(&t, p)| {
                        let busy = p.conn.mux.as_ref().map(|m| m.busy()).unwrap_or(false);
                        (!busy && now.duration_since(p.last) > idle).then_some(t)
                    })
                    .collect();
                for t in evict {
                    metrics.idle_eviction();
                    reap_conn(t, &mut conns, &mut mux_wparked, &mut poller);
                }
            }
            if !wparked.is_empty() {
                let now = Instant::now();
                wparked.retain(|_, wj| {
                    let keep = now < wj.deadline;
                    if !keep {
                        let _ = poller.deregister(wj.conn.stream.as_raw_fd());
                        metrics.idle_eviction();
                        metrics.parked_dec();
                    }
                    keep
                });
            }
            if !mux_wparked.is_empty() {
                // A mux peer that stopped reading gets the same WRITE_CAP
                // budget as a v1 slow reader before the connection goes.
                let now = Instant::now();
                let expired: Vec<u64> = mux_wparked
                    .values()
                    .filter(|&&ctok| {
                        conns
                            .get(&ctok)
                            .and_then(|p| p.conn.mux.as_ref())
                            .map(|m| m.parked_expired(WRITE_CAP, now))
                            .unwrap_or(false)
                    })
                    .copied()
                    .collect();
                for t in expired {
                    metrics.idle_eviction();
                    reap_conn(t, &mut conns, &mut mux_wparked, &mut poller);
                }
            }
            sweep_parked_deadlines(&shared);
        }
    }
    // Shutdown: close every mux connection first (cancelling in-flight
    // requests and running their hooks so watchers deregister), then
    // dropping the maps actively closes every idle connection and
    // abandons half-written responses; queued/in-flight requests are
    // drained by FrontendServer::shutdown, parked deferred responses are
    // dropped by its clear_parked step.
    for (_t, p) in conns.drain() {
        if let Some(m) = &p.conn.mux {
            for hook in m.close() {
                hook();
            }
        }
    }
    drop(conns);
    drop(wparked);
    drop(listener);
}

/// Remove a connection from the loop, deregistering whatever interests
/// it still has, closing its mux half (if any) and running the cancel
/// hooks of its in-flight requests.
fn reap_conn<S>(
    tok: u64,
    conns: &mut HashMap<u64, Parked<S>>,
    mux_wparked: &mut HashMap<u64, u64>,
    poller: &mut Poller,
) {
    let Some(p) = conns.remove(&tok) else { return };
    if !p.throttled {
        let _ = poller.deregister(p.conn.stream.as_raw_fd());
    }
    if let Some(wtok) = p.wtoken {
        mux_wparked.remove(&wtok);
        if let Some(m) = &p.conn.mux {
            let _ = poller.deregister(m.write_fd());
        }
    }
    if let Some(m) = &p.conn.mux {
        for hook in m.close() {
            hook();
        }
    }
    // Dropping `p` closes the socket and decrements the gauge.
}

/// Drive one readable connection: assemble frames, decide the protocol
/// on the first one, and either hand the connection to a worker (v1) or
/// fan complete v2 frames out as mux jobs while the connection stays
/// here. Bounded per event; level-triggered readiness redelivers
/// whatever is left.
#[allow(clippy::too_many_arguments)]
fn drive_readable<H: ConnectionHandler>(
    tok: u64,
    conns: &mut HashMap<u64, Parked<H::Conn>>,
    mux_wparked: &mut HashMap<u64, u64>,
    poller: &mut Poller,
    shared: &Arc<Shared<H::Conn>>,
    stop: &Arc<AtomicBool>,
    wake: &Arc<WakePipe>,
    metrics: &Arc<FrontendMetrics>,
    mux_tx: &Sender<MuxNote>,
    mux_max_inflight: usize,
) {
    /// Frames drained per readiness event, so one firehose connection
    /// cannot starve the rest of the loop's work.
    const DRAIN_MAX: usize = 32;
    let mut reap = false;
    let mut cancel_hooks: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
    for _ in 0..DRAIN_MAX {
        // Re-borrowed each iteration: the v1 arm removes the entry.
        let Some(p) = conns.get_mut(&tok) else { break };
        p.last = Instant::now();
        let progress = p.conn.reader.poll_frame(&mut p.conn.stream);
        match progress {
            Ok(FrameProgress::Frame(head, payload)) => {
                let is_v1 = p.conn.mux.is_none() && (p.conn.v1_locked || !is_v2_head(head));
                if is_v1 {
                    // v1 request: hand the whole connection to a worker
                    // (one in-flight request per connection, as ever).
                    // Deregister before the hand-off: the worker may
                    // close the fd at any point afterwards, and its
                    // number could come back from the next accept.
                    if let Some(p) = conns.remove(&tok) {
                        let _ = poller.deregister(p.conn.stream.as_raw_fd());
                        let mut conn = p.conn;
                        conn.v1_locked = true;
                        enqueue(
                            shared,
                            stop,
                            Job::Request { conn, head, payload, enqueued: Instant::now() },
                        );
                    }
                    break;
                }
                let v2 = match parse_v2(head, payload) {
                    Ok(f) => f,
                    Err(_) => {
                        reap = true;
                        break;
                    }
                };
                if p.conn.mux.is_none() {
                    // First v2 frame on the connection: must be HELLO.
                    if v2.kind != FrameKind::Hello || v2.corr != 0 {
                        reap = true;
                        break;
                    }
                    let hello: HelloProto = match wire_decode(&v2.body) {
                        Ok(h) => h,
                        Err(_) => {
                            reap = true;
                            break;
                        }
                    };
                    // The write half is a dup of the same file
                    // description (shares O_NONBLOCK); sends go through
                    // the shared out-buffer with WouldBlock parking.
                    let wstream = match p.conn.stream.try_clone() {
                        Ok(s) => s,
                        Err(_) => {
                            reap = true;
                            break;
                        }
                    };
                    let mux = Arc::new(MuxConn {
                        token: tok,
                        max_inflight: mux_max_inflight,
                        throttled: AtomicBool::new(false),
                        wake: Arc::clone(wake),
                        metrics: Arc::clone(metrics),
                        corrs: Mutex::new(
                            &classes::FE_MUX_CORR,
                            MuxCorrs {
                                active: HashMap::new(),
                                inflight: 0,
                                notes: mux_tx.clone(),
                            },
                        ),
                        out: Mutex::new(
                            &classes::FE_MUX_OUT,
                            MuxOut {
                                stream: wstream,
                                buf: Vec::new(),
                                off: 0,
                                parked: false,
                                parked_since: Instant::now(),
                                dead: false,
                                notes: mux_tx.clone(),
                            },
                        ),
                    });
                    let reply = HelloProto {
                        version: hello.version.min(WIRE_VERSION_MAX),
                        max_inflight: mux_max_inflight as u64,
                    };
                    if let Ok(frame) = encode_v2(FrameKind::Hello, 0, &wire_encode(&reply)) {
                        mux.send_raw(&frame);
                    }
                    p.conn.mux = Some(mux);
                    continue;
                }
                let Some(mux) = p.conn.mux.as_ref().map(Arc::clone) else { break };
                match v2.kind {
                    // Duplicate HELLO: harmless, ignore.
                    FrameKind::Hello => {}
                    FrameKind::Request => {
                        let mut body = v2.body;
                        if body.is_empty() {
                            reap = true;
                            break;
                        }
                        let payload = body.split_off(1);
                        let method = body[0];
                        if !mux.begin_request(v2.corr) {
                            // Duplicate correlation id: protocol
                            // violation, ambiguous forever — close.
                            reap = true;
                            break;
                        }
                        let sink = MuxSink {
                            mux: Arc::clone(&mux),
                            corr: v2.corr,
                            terminated: AtomicBool::new(false),
                        };
                        enqueue(
                            shared,
                            stop,
                            Job::Mux { sink, method, payload, enqueued: Instant::now() },
                        );
                        if mux.try_throttle() {
                            if let Some(p) = conns.get_mut(&tok) {
                                p.throttled = true;
                                let _ = poller.deregister(p.conn.stream.as_raw_fd());
                            }
                            break;
                        }
                    }
                    FrameKind::Cancel => {
                        if let Some(hook) = mux.cancel_corr(v2.corr) {
                            cancel_hooks.push(hook);
                        }
                    }
                    // Server-to-client kinds from a client: violation.
                    FrameKind::Response
                    | FrameKind::StreamItem
                    | FrameKind::StreamEnd
                    | FrameKind::Error => {
                        reap = true;
                        break;
                    }
                }
            }
            // Mid-frame stall: the connection keeps waiting here in the
            // event loop — no worker is occupied.
            Ok(FrameProgress::Pending) => break,
            // Disconnect or protocol-level framing error (oversized/zero
            // frame, EOF mid-frame): reap the connection.
            Ok(FrameProgress::Closed) | Err(_) => {
                reap = true;
                break;
            }
        }
    }
    if reap {
        reap_conn(tok, conns, mux_wparked, poller);
    }
    // Cancel hooks run outside every frontend lock (they typically take
    // service-layer locks to deregister watchers).
    for hook in cancel_hooks {
        hook();
    }
}

/// Answer every deferred response whose long-poll deadline has passed
/// with its prepared timeout frame (the connection survives; the late
/// completion becomes a no-op).
fn sweep_parked_deadlines<S>(shared: &Arc<Shared<S>>) {
    let now = Instant::now();
    let mut due: Vec<(Conn<S>, Vec<u8>)> = Vec::new();
    {
        let mut slots = shared.slots.lock();
        let expired: Vec<u64> = slots
            .iter()
            .filter_map(|(&t, slot)| match slot {
                ParkSlot::AwaitingResponse { deadline: Some(d), .. } if now >= *d => Some(t),
                _ => None,
            })
            .collect();
        for t in expired {
            if let Some(ParkSlot::AwaitingResponse { conn, timeout_frame, .. }) = slots.remove(&t)
            {
                due.push((conn, timeout_frame));
            }
        }
    }
    for (conn, frame) in due {
        shared.metrics.parked_dec();
        shared.push_job(Job::Write(WriteJob {
            conn,
            frame,
            off: 0,
            keep: true,
            deadline: now + WRITE_CAP,
        }));
    }
}

/// Push a ready request (v1 hand-off or v2 mux job) onto the bounded
/// queue, applying backpressure (bounded wait) when the pool is
/// saturated.
fn enqueue<S>(shared: &Arc<Shared<S>>, stop: &Arc<AtomicBool>, job: Job<S>) {
    let mut q = shared.queue.lock();
    while q.len() >= shared.capacity {
        if stop.load(Ordering::SeqCst) {
            // Shutting down: drop the request. A v1 job closes its
            // connection; a mux job answers through the sink drop guard.
            return;
        }
        let (guard, _timeout) =
            shared.space_ready.wait_timeout(q, Duration::from_millis(100));
        q = guard;
    }
    q.push_back(job);
    shared.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
    drop(q);
    shared.job_ready.notify_one();
}

/// Worker: pop a unit of work, run the handler / continue the write,
/// return the connection to the event loop.
fn worker_loop<H: ConnectionHandler>(
    handler: Arc<H>,
    shared: Arc<Shared<H::Conn>>,
    rearm_tx: Sender<Back<H::Conn>>,
    wake: Arc<WakePipe>,
    hooks: DeferHooks,
) {
    loop {
        let job = {
            let mut q = shared.queue.lock();
            loop {
                if let Some(j) = q.pop_front() {
                    // Under the same lock as the pop: Shared::pending()
                    // (queue len + active_jobs, read under this lock)
                    // must never transiently miss an in-flight job, or
                    // shutdown could skip its drain.
                    shared.active_jobs.fetch_add(1, Ordering::SeqCst);
                    break Some(j);
                }
                if shared.worker_stop.load(Ordering::SeqCst) {
                    break None;
                }
                let (guard, _timeout) =
                    shared.job_ready.wait_timeout(q, Duration::from_millis(200));
                q = guard;
            }
        };
        let Some(job) = job else { break };
        shared.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        shared.space_ready.notify_one();

        match job {
            Job::Request { mut conn, head, payload, enqueued } => {
                let waited = enqueued.elapsed().as_micros() as u64;
                shared.metrics.queue_wait.record(waited);
                // Leave the wait for the dispatch span (which knows the
                // trace context — it is still inside the frame). Clamped
                // to 1 us: 0 means "no note", but a queued request that
                // waited under a microsecond still made the hop.
                crate::util::trace::note_queue_wait(waited.max(1));
                shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
                let mut out = Vec::new();
                let cx = RequestContext { hooks: &hooks, ticket: Cell::new(None) };
                // A panicking handler must not shrink the pool: treat it
                // as a connection-fatal error and keep the worker alive.
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    handler.handle(&mut conn.state, head, &payload, &mut out, &cx)
                }))
                .unwrap_or(HandleOutcome::Close);
                let ticket = cx.ticket.get();
                match outcome {
                    HandleOutcome::Pending => match ticket {
                        Some(t) => park_deferred(&shared, &rearm_tx, &wake, conn, t),
                        // Pending without a defer() is a handler bug:
                        // there is no way to ever answer — close.
                        None => drop(conn),
                    },
                    reply => {
                        if let Some(t) = ticket {
                            // Replied despite reserving a ticket: void
                            // it so a late completion is a no-op.
                            (hooks.cancel)(t);
                        }
                        let keep = reply == HandleOutcome::Reply;
                        finish_write(
                            &shared,
                            &rearm_tx,
                            &wake,
                            WriteJob {
                                conn,
                                frame: out,
                                off: 0,
                                keep,
                                deadline: Instant::now() + WRITE_CAP,
                            },
                        );
                    }
                }
            }
            Job::Mux { sink, method, payload, enqueued } => {
                let waited = enqueued.elapsed().as_micros() as u64;
                shared.metrics.queue_wait.record(waited);
                crate::util::trace::note_queue_wait(waited.max(1));
                shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
                // A panic before the sink's terminal send unwinds through
                // the sink's Drop, which answers the client with an
                // internal error — the worker and connection both live.
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    handler.handle_mux(method, &payload, sink);
                }));
            }
            Job::Write(wj) => finish_write(&shared, &rearm_tx, &wake, wj),
        }

        shared.active_jobs.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Park a connection whose handler deferred its response — unless the
/// completion already raced ahead, in which case write it now.
fn park_deferred<S: Send + 'static>(
    shared: &Arc<Shared<S>>,
    rearm_tx: &Sender<Back<S>>,
    wake: &Arc<WakePipe>,
    conn: Conn<S>,
    ticket: u64,
) {
    let mut slots = shared.slots.lock();
    match slots.remove(&ticket) {
        Some(ParkSlot::Reserved { deadline, timeout_frame }) => {
            slots.insert(ticket, ParkSlot::AwaitingResponse { conn, deadline, timeout_frame });
            // Gauge inc under the slots lock: a completer that takes the
            // slot the moment the lock drops runs its (saturating) dec
            // strictly after this inc, so the gauge cannot drift.
            shared.metrics.parked_inc();
            drop(slots);
        }
        Some(ParkSlot::AwaitingConn { frame, keep }) => {
            drop(slots);
            finish_write(
                shared,
                rearm_tx,
                wake,
                WriteJob { conn, frame, off: 0, keep, deadline: Instant::now() + WRITE_CAP },
            );
        }
        // Canceled (watcher dropped) before the connection parked: no
        // response can ever arrive — close.
        _ => drop(conn),
    }
}

/// Write as much of the response as the socket accepts. On completion
/// the connection re-arms for reading (if `keep`); on `WouldBlock` it
/// parks in the event loop for writability — the worker never waits.
fn finish_write<S>(
    shared: &Arc<Shared<S>>,
    rearm_tx: &Sender<Back<S>>,
    wake: &Arc<WakePipe>,
    mut wj: WriteJob<S>,
) {
    loop {
        if wj.off >= wj.frame.len() {
            if wj.keep {
                // Hand the connection back; if the event loop is gone
                // (shutdown) the send fails and the connection closes.
                if rearm_tx.send(Back::Read(wj.conn)).is_ok() {
                    wake.wake();
                }
            }
            return;
        }
        match wj.conn.stream.write(&wj.frame[wj.off..]) {
            Ok(0) => return, // peer gone: drop the connection
            Ok(n) => wj.off += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if shared.force_abort.load(Ordering::SeqCst) || Instant::now() >= wj.deadline {
                    return;
                }
                // Park the half-written response in the event loop
                // (ROADMAP follow-on (c)): a client that stopped
                // reading costs a buffer, not a worker.
                shared.metrics.parked_inc();
                if rearm_tx.send(Back::Write(wj)).is_ok() {
                    wake.wake();
                } else {
                    shared.metrics.parked_dec();
                }
                return;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::framing::{
        encode_v2_request, read_frame, read_response, write_err, write_ok, write_request, Method,
        Status, V2Frame,
    };
    use crate::wire::messages::{EmptyResponse, GetStudyRequest};
    use std::io::BufReader;

    /// Echo-style handler: replies OK to `Ping`, errors-and-closes on
    /// anything else. Counts per-connection requests in its state.
    struct PingHandler;

    impl ConnectionHandler for PingHandler {
        type Conn = u64;
        fn on_connect(&self) -> u64 {
            0
        }
        fn handle(
            &self,
            served: &mut u64,
            head: u8,
            _payload: &[u8],
            out: &mut Vec<u8>,
            _cx: &RequestContext<'_>,
        ) -> HandleOutcome {
            *served += 1;
            if head == Method::Ping as u8 {
                let _ = write_ok(out, &EmptyResponse::default());
                HandleOutcome::Reply
            } else {
                let _ = write_err(out, Status::InvalidArgument, "bad method");
                HandleOutcome::Close
            }
        }
    }

    fn ping(stream: &mut TcpStream) {
        write_request(stream, Method::Ping, &EmptyResponse::default()).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let _: EmptyResponse = read_response(&mut r).unwrap();
    }

    #[test]
    fn serves_many_connections_with_two_workers() {
        let server = FrontendServer::start(
            PingHandler,
            "127.0.0.1:0",
            FrontendOptions { name: "fe-test", workers: 2, ..Default::default() },
        )
        .unwrap();
        let addr = server.local_addr();
        let mut conns: Vec<TcpStream> =
            (0..32).map(|_| TcpStream::connect(addr).unwrap()).collect();
        for c in conns.iter_mut() {
            ping(c);
            ping(c); // sequential requests on one connection
        }
        assert_eq!(server.metrics().requests(), 64);
        assert_eq!(server.metrics().active_connections(), 32);
        assert_eq!(server.metrics().connections_total(), 32);
        server.shutdown();
    }

    #[test]
    fn handler_close_and_gauge_decrement() {
        let server = FrontendServer::start(
            PingHandler,
            "127.0.0.1:0",
            FrontendOptions { name: "fe-test2", workers: 1, ..Default::default() },
        )
        .unwrap();
        let addr = server.local_addr();
        let mut good = TcpStream::connect(addr).unwrap();
        ping(&mut good);
        let mut bad = TcpStream::connect(addr).unwrap();
        write_request(&mut bad, Method::GetStudy, &GetStudyRequest::default()).unwrap();
        let mut r = BufReader::new(bad.try_clone().unwrap());
        let err = read_response::<_, EmptyResponse>(&mut r).unwrap_err();
        assert!(matches!(
            err,
            crate::wire::framing::FrameError::Rpc { status: Status::InvalidArgument, .. }
        ));
        // The handler returned Close: the server closes `bad` and the
        // gauge drops back to 1.
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.metrics().active_connections() != 1 {
            assert!(Instant::now() < deadline, "gauge never decremented");
            std::thread::sleep(Duration::from_millis(5));
        }
        ping(&mut good); // the survivor still works
        server.shutdown();
    }

    /// A handler that defers every Ping and completes it from a helper
    /// thread after a short delay — the deferred-response plumbing end
    /// to end, including the parked_responses gauge.
    struct DeferredPing {
        delay: Duration,
        /// Long-poll deadline given to defer(); None = no timeout.
        deadline_in: Option<Duration>,
        /// Complete at all? false exercises the timeout path.
        complete: bool,
    }

    impl ConnectionHandler for DeferredPing {
        type Conn = ();
        fn on_connect(&self) {}
        fn handle(
            &self,
            _state: &mut (),
            _head: u8,
            _payload: &[u8],
            _out: &mut Vec<u8>,
            cx: &RequestContext<'_>,
        ) -> HandleOutcome {
            let mut timeout_frame = Vec::new();
            let _ = write_err(&mut timeout_frame, Status::Unimplemented, "timed out");
            let deadline = self.deadline_in.map(|d| Instant::now() + d);
            let handle = cx.defer(deadline, timeout_frame);
            if self.complete {
                let delay = self.delay;
                std::thread::spawn(move || {
                    std::thread::sleep(delay);
                    let mut frame = Vec::new();
                    let _ = write_ok(&mut frame, &EmptyResponse::default());
                    handle.complete(frame);
                });
            } else {
                // Dropping the handle here would abort the ticket and
                // close the client; hold it past the deadline instead.
                let delay = self.delay;
                std::thread::spawn(move || {
                    std::thread::sleep(delay);
                    drop(handle);
                });
            }
            HandleOutcome::Pending
        }
    }

    #[test]
    fn deferred_response_wakes_parked_connection() {
        let server = FrontendServer::start(
            DeferredPing {
                delay: Duration::from_millis(120),
                deadline_in: None,
                complete: true,
            },
            "127.0.0.1:0",
            FrontendOptions { name: "fe-defer", workers: 1, ..Default::default() },
        )
        .unwrap();
        let addr = server.local_addr();
        // Two clients park concurrently on the single worker: deferral
        // must free it between them.
        let mut a = TcpStream::connect(addr).unwrap();
        let mut b = TcpStream::connect(addr).unwrap();
        write_request(&mut a, Method::Ping, &EmptyResponse::default()).unwrap();
        write_request(&mut b, Method::Ping, &EmptyResponse::default()).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.metrics().parked_responses() < 2 {
            assert!(Instant::now() < deadline, "responses never parked");
            std::thread::sleep(Duration::from_millis(5));
        }
        let mut ra = BufReader::new(a.try_clone().unwrap());
        let mut rb = BufReader::new(b.try_clone().unwrap());
        let _: EmptyResponse = read_response(&mut ra).unwrap();
        let _: EmptyResponse = read_response(&mut rb).unwrap();
        assert_eq!(server.metrics().parked_responses(), 0);
        // The connections survive and serve the next (deferred) request.
        write_request(&mut a, Method::Ping, &EmptyResponse::default()).unwrap();
        let _: EmptyResponse = read_response(&mut ra).unwrap();
        server.shutdown();
    }

    #[test]
    fn deferred_deadline_answers_with_timeout_frame() {
        let server = FrontendServer::start(
            DeferredPing {
                delay: Duration::from_secs(2),
                deadline_in: Some(Duration::from_millis(50)),
                complete: false,
            },
            "127.0.0.1:0",
            FrontendOptions { name: "fe-dtime", workers: 1, ..Default::default() },
        )
        .unwrap();
        let addr = server.local_addr();
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write_request(&mut c, Method::Ping, &EmptyResponse::default()).unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        // The sweep (<= 250ms cadence) answers with the timeout frame
        // long before the 2s never-completing handle resolves.
        let err = read_response::<_, EmptyResponse>(&mut r).unwrap_err();
        assert!(matches!(
            err,
            crate::wire::framing::FrameError::Rpc { status: Status::Unimplemented, .. }
        ));
        server.shutdown();
    }

    #[test]
    fn max_connections_refuses_excess() {
        let server = FrontendServer::start(
            PingHandler,
            "127.0.0.1:0",
            FrontendOptions {
                name: "fe-cap",
                workers: 1,
                max_connections: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let addr = server.local_addr();
        let mut a = TcpStream::connect(addr).unwrap();
        let mut b = TcpStream::connect(addr).unwrap();
        ping(&mut a);
        ping(&mut b);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // The refused socket is closed without a response.
        let mut buf = [0u8; 1];
        use std::io::Read as _;
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match c.read(&mut buf) {
                Ok(0) => break,
                Ok(_) => panic!("refused connection received bytes"),
                Err(_) => assert!(Instant::now() < deadline, "refused conn never closed"),
            }
        }
        assert_eq!(server.metrics().connections_refused(), 1);
        assert_eq!(server.metrics().active_connections(), 2);
        ping(&mut a); // survivors unaffected
        server.shutdown();
    }

    // ---- wire-v2 multiplexing ----

    fn send_hello(s: &mut TcpStream) {
        let hello = HelloProto { version: WIRE_VERSION_MAX, max_inflight: 0 };
        let frame = encode_v2(FrameKind::Hello, 0, &wire_encode(&hello)).unwrap();
        s.write_all(&frame).unwrap();
    }

    fn recv_v2(r: &mut BufReader<TcpStream>) -> V2Frame {
        let (head, payload) = read_frame(r).unwrap();
        parse_v2(head, payload).unwrap()
    }

    /// Mux-aware ping: answers v2 Pings through the sink, v1 Pings
    /// through the classic path.
    struct MuxPing;

    impl ConnectionHandler for MuxPing {
        type Conn = ();
        fn on_connect(&self) {}
        fn handle(
            &self,
            _state: &mut (),
            head: u8,
            _payload: &[u8],
            out: &mut Vec<u8>,
            _cx: &RequestContext<'_>,
        ) -> HandleOutcome {
            if head == Method::Ping as u8 {
                let _ = write_ok(out, &EmptyResponse::default());
                HandleOutcome::Reply
            } else {
                let _ = write_err(out, Status::InvalidArgument, "bad method");
                HandleOutcome::Close
            }
        }
        fn handle_mux(&self, method: u8, _payload: &[u8], sink: MuxSink) {
            if method == Method::Ping as u8 {
                sink.respond_ok(&EmptyResponse::default());
            } else {
                sink.error(Status::InvalidArgument, "bad method");
            }
        }
    }

    #[test]
    fn mux_hello_negotiates_and_multiplexes() {
        let server = FrontendServer::start(
            MuxPing,
            "127.0.0.1:0",
            FrontendOptions { name: "fe-mux", workers: 2, ..Default::default() },
        )
        .unwrap();
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        send_hello(&mut c);
        let hello = recv_v2(&mut r);
        assert_eq!(hello.kind, FrameKind::Hello);
        assert_eq!(hello.corr, 0);
        let negotiated: HelloProto = wire_decode(&hello.body).unwrap();
        assert_eq!(negotiated.version, WIRE_VERSION_MAX);
        assert_eq!(negotiated.max_inflight, DEFAULT_MUX_INFLIGHT as u64);
        // >= 8 requests in flight on ONE connection before reading any
        // response (the acceptance-criteria multiplex shape).
        for corr in 1..=9u32 {
            let frame =
                encode_v2_request(corr, Method::Ping, &EmptyResponse::default()).unwrap();
            c.write_all(&frame).unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..9 {
            let f = recv_v2(&mut r);
            assert_eq!(f.kind, FrameKind::Response);
            assert!(seen.insert(f.corr), "duplicate corr {}", f.corr);
        }
        assert_eq!(seen.len(), 9);
        assert_eq!(server.metrics().requests(), 9);
        assert_eq!(server.metrics().active_connections(), 1);
        // The same server still speaks v1 on a fresh connection.
        let mut v1 = TcpStream::connect(server.local_addr()).unwrap();
        ping(&mut v1);
        server.shutdown();
    }

    #[test]
    fn mux_default_handler_rejects_v2_requests() {
        let server = FrontendServer::start(
            PingHandler, // no handle_mux override
            "127.0.0.1:0",
            FrontendOptions { name: "fe-muxrej", workers: 1, ..Default::default() },
        )
        .unwrap();
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        send_hello(&mut c);
        let _ = recv_v2(&mut r); // HELLO reply: the handshake itself works
        let frame = encode_v2_request(7, Method::Ping, &EmptyResponse::default()).unwrap();
        c.write_all(&frame).unwrap();
        let f = recv_v2(&mut r);
        assert_eq!(f.kind, FrameKind::Error);
        assert_eq!(f.corr, 7);
        assert_eq!(f.body[0], Status::Unimplemented as u8);
        server.shutdown();
    }

    /// Slow streaming-ish handler for cancel tests: answers after a
    /// delay from another thread, and records cancel-hook delivery.
    struct SlowPing {
        delay: Duration,
        hook_ran: Arc<AtomicBool>,
    }

    impl ConnectionHandler for SlowPing {
        type Conn = ();
        fn on_connect(&self) {}
        fn handle(
            &self,
            _state: &mut (),
            _head: u8,
            _payload: &[u8],
            out: &mut Vec<u8>,
            _cx: &RequestContext<'_>,
        ) -> HandleOutcome {
            let _ = write_ok(out, &EmptyResponse::default());
            HandleOutcome::Reply
        }
        fn handle_mux(&self, _method: u8, _payload: &[u8], sink: MuxSink) {
            let ran = Arc::clone(&self.hook_ran);
            sink.on_cancel(Box::new(move || {
                ran.store(true, Ordering::SeqCst);
            }));
            let delay = self.delay;
            std::thread::spawn(move || {
                std::thread::sleep(delay);
                // Suppressed (silently) if the request was canceled.
                sink.respond_ok(&EmptyResponse::default());
            });
        }
    }

    #[test]
    fn mux_cancel_runs_hook_and_suppresses_response() {
        let hook_ran = Arc::new(AtomicBool::new(false));
        let server = FrontendServer::start(
            SlowPing { delay: Duration::from_millis(150), hook_ran: Arc::clone(&hook_ran) },
            "127.0.0.1:0",
            FrontendOptions { name: "fe-muxcan", workers: 2, ..Default::default() },
        )
        .unwrap();
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        send_hello(&mut c);
        let _ = recv_v2(&mut r);
        // Request 1, canceled immediately; request 2 follows and is
        // slower end-to-end, so by the time its response arrives the
        // canceled response (had it leaked) would already be buffered.
        let f1 = encode_v2_request(1, Method::Ping, &EmptyResponse::default()).unwrap();
        c.write_all(&f1).unwrap();
        let cancel = encode_v2(FrameKind::Cancel, 1, &[]).unwrap();
        c.write_all(&cancel).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        let f2 = encode_v2_request(2, Method::Ping, &EmptyResponse::default()).unwrap();
        c.write_all(&f2).unwrap();
        let f = recv_v2(&mut r);
        assert_eq!(f.kind, FrameKind::Response);
        assert_eq!(f.corr, 2, "canceled request leaked a response");
        let deadline = Instant::now() + Duration::from_secs(5);
        while !hook_ran.load(Ordering::SeqCst) {
            assert!(Instant::now() < deadline, "cancel hook never ran");
            std::thread::sleep(Duration::from_millis(5));
        }
        server.shutdown();
    }

    #[test]
    fn mux_inflight_cap_throttles_and_recovers() {
        let server = FrontendServer::start(
            SlowPing { delay: Duration::from_millis(30), hook_ran: Arc::new(AtomicBool::new(false)) },
            "127.0.0.1:0",
            FrontendOptions {
                name: "fe-muxcap",
                workers: 4,
                mux_max_inflight: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        send_hello(&mut c);
        let hello = recv_v2(&mut r);
        let negotiated: HelloProto = wire_decode(&hello.body).unwrap();
        assert_eq!(negotiated.max_inflight, 2);
        // 6 requests against a cap of 2: the loop must throttle reads
        // and re-arm as completions land; every request still answers.
        for corr in 1..=6u32 {
            let frame =
                encode_v2_request(corr, Method::Ping, &EmptyResponse::default()).unwrap();
            c.write_all(&frame).unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..6 {
            let f = recv_v2(&mut r);
            assert_eq!(f.kind, FrameKind::Response);
            seen.insert(f.corr);
        }
        assert_eq!(seen.len(), 6);
        server.shutdown();
    }

    #[test]
    fn idle_timeout_evicts_parked_connections() {
        let server = FrontendServer::start(
            PingHandler,
            "127.0.0.1:0",
            FrontendOptions {
                name: "fe-idle",
                workers: 1,
                idle_timeout: Some(Duration::from_millis(200)),
                ..Default::default()
            },
        )
        .unwrap();
        let addr = server.local_addr();
        let mut idle = TcpStream::connect(addr).unwrap();
        ping(&mut idle);
        assert_eq!(server.metrics().active_connections(), 1);
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.metrics().active_connections() != 0 {
            assert!(Instant::now() < deadline, "idle connection never evicted");
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(server.metrics().idle_evictions() >= 1);
        // A fresh connection still works.
        let mut fresh = TcpStream::connect(addr).unwrap();
        ping(&mut fresh);
        server.shutdown();
    }
}
