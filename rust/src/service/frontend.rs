//! Shared bounded worker-pool TCP front-end.
//!
//! The paper's reference service multiplexes thousands of worker clients
//! behind `grpc.server(ThreadPoolExecutor(max_workers=100))` (Code Block
//! 4): connections do not cost a thread; only *ready requests* occupy
//! workers. This module is the Rust analogue, replacing the original
//! thread-per-connection front-end that spawned an unbounded OS thread
//! per client:
//!
//! * One **event-loop thread** (`<name>-io`) owns the listener and every
//!   idle connection. It blocks in [`Poller::wait`]
//!   ([`crate::util::netpoll`]: `epoll(7)` with incremental registration
//!   by default, the rebuilt-each-wakeup `poll(2)` set as the
//!   [`PollerKind::Poll`] baseline — `--poller=poll`) over all of them
//!   plus a [`WakePipe`]. Fds are registered / deregistered only on
//!   connection state changes (accept, hand-off to a worker, read or
//!   write re-park, close), so under epoll a wakeup costs O(ready), not
//!   O(fleet). Idle or stalled connections park here without a thread;
//!   partial frames accumulate in a per-connection [`FrameReader`] so a
//!   slow client can never pin a worker.
//! * **N worker threads** (`<name>-w<i>`) take complete framed requests
//!   off a bounded queue, run the [`ConnectionHandler`], write the
//!   response, and hand the connection back to the event loop. One frame
//!   = one job; a connection is owned by at most one thread at a time, so
//!   requests on a connection stay sequential (same contract as the old
//!   per-connection loop).
//! * **Deferred responses** ([`HandleOutcome::Pending`]): a handler that
//!   cannot answer yet (a long-poll `WaitOperation` whose operation is
//!   still running) calls [`RequestContext::defer`], stashes the returned
//!   [`ResponseHandle`], and returns `Pending`. The worker parks the
//!   connection in a ticketed registry and moves on; whoever completes
//!   the handle later (a policy-completion watcher on any thread)
//!   re-queues the connection with its response bytes. No thread waits.
//! * **Write-side parking**: a response that hits `WouldBlock` mid-write
//!   (the client stopped reading) is handed back to the event loop with
//!   its offset; the loop polls the socket for *writability* and
//!   re-queues the remainder when the peer drains its window. A slow
//!   reader costs a parked buffer, never a worker thread.
//! * **Graceful shutdown** stops the event loop (closing the listener and
//!   every idle connection), drains queued + in-flight requests up to a
//!   deadline, then joins all pool threads — no orphaned connection
//!   threads, unlike the old front-end which leaked its `vizier-conn`
//!   threads.
//!
//! [`FrontendMetrics`] tracks the `active_connections` and
//! `parked_responses` gauges, queue depth and queue-wait histogram; the
//! `C-FRONTEND` and `C-ASYNC-DISPATCH` benches drive 1000+ mostly-idle
//! connections / 3x-oversubscribed policy fleets through this module and
//! assert the thread budget stays at `workers + 2`.
//!
//! The two locks here are registered with
//! [`crate::util::sync::classes`]: `frontend.park_slots` is always taken
//! before (or released before taking) `frontend.job_queue` — completion
//! hooks drop the slots guard before `push_job`. Checked under lockdep;
//! see `rust/docs/INVARIANTS.md` for the full hierarchy.

use crate::service::metrics::FrontendMetrics;
use crate::util::netpoll::{Poller, PollerKind, WakePipe, EV_READ, EV_WRITE};
use crate::util::sync::{classes, Condvar, Mutex};
use crate::wire::framing::{FrameProgress, FrameReader};
use std::cell::Cell;
use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How the worker should proceed after [`ConnectionHandler::handle`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandleOutcome {
    /// `out` holds the complete response; keep serving the connection.
    Reply,
    /// `out` holds the complete response; close the connection after it
    /// is flushed (protocol violations).
    Close,
    /// No response yet: the handler called [`RequestContext::defer`] and
    /// will complete the [`ResponseHandle`] later. The connection parks
    /// without occupying a worker.
    Pending,
}

/// Per-connection protocol logic run on worker threads.
pub trait ConnectionHandler: Send + Sync + 'static {
    /// Per-connection state (e.g. a lazily-opened upstream channel).
    /// Travels with the connection between the event loop and workers.
    type Conn: Send + 'static;

    /// Called on the event-loop thread at accept time — must not block.
    fn on_connect(&self) -> Self::Conn;

    /// Handle one framed request. Either write the complete response
    /// frame into `out` and return [`HandleOutcome::Reply`] /
    /// [`HandleOutcome::Close`], or call [`RequestContext::defer`] and
    /// return [`HandleOutcome::Pending`] to answer later without holding
    /// a worker.
    fn handle(
        &self,
        conn: &mut Self::Conn,
        head: u8,
        payload: &[u8],
        out: &mut Vec<u8>,
        cx: &RequestContext<'_>,
    ) -> HandleOutcome;
}

/// Tuning knobs for a [`FrontendServer`].
pub struct FrontendOptions {
    /// Thread-name prefix (shows up in `/proc/self/task/*/comm`; keep it
    /// short, Linux truncates names to 15 bytes).
    pub name: &'static str,
    /// Worker threads. 0 = [`default_workers`] (the CPU count).
    pub workers: usize,
    /// Bounded queue capacity. 0 = `workers * 64`. When full, the event
    /// loop applies backpressure by pausing reads (connections stay
    /// parked, nothing is dropped). Internal re-queues — deferred
    /// completions and resumed writes — bypass the cap (they only drain
    /// already-admitted work).
    pub queue_capacity: usize,
    /// How long shutdown waits for queued + in-flight requests to drain
    /// before abandoning the remainder.
    pub drain: Duration,
    /// Evict connections that have been idle (no read progress) longer
    /// than this. `None` = never evict (connections park for free but a
    /// dead fleet accumulates fds forever).
    pub idle_timeout: Option<Duration>,
    /// Refuse new connections once `active_connections` reaches this
    /// many (0 = unlimited). Refused sockets are accepted and
    /// immediately closed so the backlog cannot wedge the listener.
    pub max_connections: usize,
    /// Readiness backend for the event loop. The default honors the
    /// `OSSVIZIER_POLLER` env knob (the CI matrix runs both), falling
    /// back to epoll.
    pub poller: PollerKind,
    /// Metrics sink; supply one to share with [`super::metrics::ServiceMetrics`].
    pub metrics: Option<Arc<FrontendMetrics>>,
}

impl Default for FrontendOptions {
    fn default() -> Self {
        Self {
            name: "frontend",
            workers: 0,
            queue_capacity: 0,
            drain: Duration::from_secs(5),
            idle_timeout: None,
            max_connections: 0,
            poller: PollerKind::from_env(),
            metrics: None,
        }
    }
}

/// Default worker count: the machine's CPU parallelism (the paper's
/// fixed `max_workers=100` sized for Google's servers; CPUs is the right
/// default for a bounded request-compute pool).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Hard cap on how long a half-written response may stay parked waiting
/// for the peer to read (the pre-parking front-end spent this budget
/// blocking a worker; now it bounds a parked buffer instead).
const WRITE_CAP: Duration = Duration::from_secs(30);

/// A live connection. Owned by exactly one thread at a time: the event
/// loop while idle/reading, a worker while a request is in flight, the
/// parked-response registry while a deferred answer is pending.
struct Conn<S> {
    stream: TcpStream,
    reader: FrameReader,
    state: S,
    metrics: Arc<FrontendMetrics>,
}

impl<S> Drop for Conn<S> {
    fn drop(&mut self) {
        // Closing the socket and decrementing the gauge happen together,
        // wherever the connection dies (event loop, worker, queue drop,
        // parked-registry teardown).
        self.metrics.conn_closed();
    }
}

/// A (possibly partially written) response on its way out.
struct WriteJob<S> {
    conn: Conn<S>,
    frame: Vec<u8>,
    off: usize,
    /// Re-arm the connection for reading once the frame is flushed?
    keep: bool,
    /// Parked writes past this instant are abandoned (connection closed).
    deadline: Instant,
}

/// One unit of worker-pool work.
enum Job<S> {
    /// A complete framed request from the event loop.
    Request { conn: Conn<S>, head: u8, payload: Vec<u8>, enqueued: Instant },
    /// A response to (continue) writing: a deferred completion, a
    /// long-poll timeout flush, or a write resumed after the peer
    /// drained its receive window.
    Write(WriteJob<S>),
}

/// Connections returned from workers to the event loop.
enum Back<S> {
    /// Served: park for the next request.
    Read(Conn<S>),
    /// Response stalled mid-write: park for writability.
    Write(WriteJob<S>),
}

/// A ticketed slot for a deferred response. The worker and the completer
/// race to the slot; whichever arrives second pairs the connection with
/// its response bytes and re-queues the write.
enum ParkSlot<S> {
    /// Ticket reserved by [`RequestContext::defer`]; the worker still
    /// holds the connection.
    Reserved { deadline: Option<Instant>, timeout_frame: Vec<u8> },
    /// Connection parked, waiting for the deferred response.
    AwaitingResponse { conn: Conn<S>, deadline: Option<Instant>, timeout_frame: Vec<u8> },
    /// Response arrived before the worker parked the connection.
    AwaitingConn { frame: Vec<u8>, keep: bool },
}

/// State shared between the event loop, workers, completers, and
/// shutdown.
struct Shared<S> {
    queue: Mutex<VecDeque<Job<S>>>,
    job_ready: Condvar,
    space_ready: Condvar,
    capacity: usize,
    /// Workers exit once this is set and the queue is empty.
    worker_stop: AtomicBool,
    /// Set when the drain deadline passes: abort in-flight writes.
    force_abort: AtomicBool,
    active_jobs: AtomicUsize,
    /// Deferred-response registry (ticket -> slot).
    slots: Mutex<HashMap<u64, ParkSlot<S>>>,
    next_ticket: AtomicU64,
    metrics: Arc<FrontendMetrics>,
}

impl<S> Shared<S> {
    fn pending(&self) -> usize {
        self.queue.lock().len() + self.active_jobs.load(Ordering::SeqCst)
    }

    fn abort_pending(&self) {
        let dropped = {
            let mut q = self.queue.lock();
            let n = q.len();
            q.clear(); // drops Jobs -> closes their connections
            n
        };
        if dropped > 0 {
            self.metrics.queue_depth.fetch_sub(dropped as u64, Ordering::Relaxed);
        }
        self.force_abort.store(true, Ordering::SeqCst);
    }

    fn stop_workers(&self) {
        self.worker_stop.store(true, Ordering::SeqCst);
        self.job_ready.notify_all();
        self.space_ready.notify_all();
    }

    /// Internal enqueue for deferred completions / resumed writes: no
    /// capacity check (bounded by the number of admitted connections),
    /// callable from any thread.
    fn push_job(&self, job: Job<S>) {
        let mut q = self.queue.lock();
        q.push_back(job);
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        drop(q);
        self.job_ready.notify_one();
    }

    /// Drop every deferred-response slot (closing parked connections).
    /// Called at shutdown after the workers have been joined; later
    /// completions find no slot and are no-ops.
    fn clear_parked(&self) {
        let drained: Vec<ParkSlot<S>> =
            self.slots.lock().drain().map(|(_, slot)| slot).collect();
        for slot in drained {
            if matches!(slot, ParkSlot::AwaitingResponse { .. }) {
                self.metrics.parked_dec();
            }
        }
    }
}

/// Type-erased hooks a worker hands to handlers through
/// [`RequestContext`] (erased so [`ResponseHandle`] has no generic
/// parameter and can be stored by service-layer watcher registries).
#[derive(Clone)]
struct DeferHooks {
    reserve: Arc<dyn Fn(Option<Instant>, Vec<u8>) -> u64 + Send + Sync>,
    /// Returns whether the frame was delivered toward a live ticket
    /// (false: the ticket timed out / was evicted and the bytes were
    /// dropped).
    complete: Arc<dyn Fn(u64, Vec<u8>, bool) -> bool + Send + Sync>,
    cancel: Arc<dyn Fn(u64) + Send + Sync>,
}

/// Per-request context given to [`ConnectionHandler::handle`].
pub struct RequestContext<'a> {
    hooks: &'a DeferHooks,
    ticket: Cell<Option<u64>>,
}

impl RequestContext<'_> {
    /// Reserve a deferred-response ticket. Returns a [`ResponseHandle`]
    /// to complete later from any thread; the handler must then return
    /// [`HandleOutcome::Pending`].
    ///
    /// If `deadline` is reached before the handle is completed, the
    /// event loop answers the parked connection with `timeout_frame`
    /// (and keeps serving it) — the deferred-response analogue of a
    /// long-poll timeout. A handle dropped without completing aborts
    /// the ticket: the parked connection is closed.
    pub fn defer(&self, deadline: Option<Instant>, timeout_frame: Vec<u8>) -> ResponseHandle {
        let ticket = (self.hooks.reserve)(deadline, timeout_frame);
        self.ticket.set(Some(ticket));
        ResponseHandle {
            ticket,
            complete: Some(Arc::clone(&self.hooks.complete)),
            cancel: Arc::clone(&self.hooks.cancel),
        }
    }
}

/// Completes a deferred response from any thread. Consumed by
/// [`complete`](Self::complete); dropping it uncompleted aborts the
/// ticket (closing the parked connection), so a vanished watcher cannot
/// leak a parked client forever.
pub struct ResponseHandle {
    ticket: u64,
    complete: Option<Arc<dyn Fn(u64, Vec<u8>, bool) -> bool + Send + Sync>>,
    cancel: Arc<dyn Fn(u64) + Send + Sync>,
}

impl ResponseHandle {
    /// Deliver the response frame and keep serving the connection.
    /// Returns false when the ticket is gone (the long-poll timed out
    /// or the connection was evicted) and the frame was dropped —
    /// callers can use this to keep wakeup metrics honest.
    pub fn complete(mut self, frame: Vec<u8>) -> bool {
        match self.complete.take() {
            Some(c) => c(self.ticket, frame, true),
            None => false,
        }
    }

    /// Deliver the response frame, then close the connection.
    pub fn complete_and_close(mut self, frame: Vec<u8>) -> bool {
        match self.complete.take() {
            Some(c) => c(self.ticket, frame, false),
            None => false,
        }
    }
}

impl Drop for ResponseHandle {
    fn drop(&mut self) {
        if self.complete.is_some() {
            (self.cancel)(self.ticket);
        }
    }
}

/// A running event-loop + worker-pool server. Dropping it performs the
/// same graceful shutdown as [`FrontendServer::shutdown`].
pub struct FrontendServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    wake: Arc<WakePipe>,
    io_thread: Option<JoinHandle<()>>,
    worker_threads: Vec<JoinHandle<()>>,
    metrics: Arc<FrontendMetrics>,
    drain: Duration,
    /// Guards shutdown_inner: an explicit `shutdown()` consumes `self`,
    /// which runs Drop — the sequence must not execute twice.
    shutdown_done: bool,
    // Type-erased handles into the generic Shared<S>.
    pending: Box<dyn Fn() -> usize + Send + Sync>,
    abort_pending: Box<dyn Fn() + Send + Sync>,
    stop_workers: Box<dyn Fn() + Send + Sync>,
    clear_parked: Box<dyn Fn() + Send + Sync>,
}

impl FrontendServer {
    /// Bind `addr` and start the event loop and worker pool.
    pub fn start<H: ConnectionHandler>(
        handler: H,
        addr: &str,
        opts: FrontendOptions,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;

        let workers = if opts.workers == 0 { default_workers() } else { opts.workers };
        let capacity =
            if opts.queue_capacity == 0 { workers * 64 } else { opts.queue_capacity };
        let metrics = opts.metrics.unwrap_or_default();
        let handler = Arc::new(handler);
        let stop = Arc::new(AtomicBool::new(false));
        let wake = Arc::new(WakePipe::new()?);
        // Build and seed the poller here so a failure (no epoll support,
        // fd exhaustion) surfaces as a start error instead of a dead
        // event loop. The wake pipe and listener are registered exactly
        // once; everything else is per-connection.
        let mut poller = Poller::new(opts.poller)?;
        poller.register(wake.read_fd(), TOK_WAKE, EV_READ)?;
        poller.register(listener.as_raw_fd(), TOK_LISTENER, EV_READ)?;
        let shared = Arc::new(Shared::<H::Conn> {
            queue: Mutex::new(&classes::FE_QUEUE, VecDeque::new()),
            job_ready: Condvar::new(),
            space_ready: Condvar::new(),
            capacity,
            worker_stop: AtomicBool::new(false),
            force_abort: AtomicBool::new(false),
            active_jobs: AtomicUsize::new(0),
            slots: Mutex::new(&classes::FE_SLOTS, HashMap::new()),
            next_ticket: AtomicU64::new(1),
            metrics: Arc::clone(&metrics),
        });
        let (rearm_tx, rearm_rx) = mpsc::channel::<Back<H::Conn>>();

        let hooks = {
            let reserve = {
                let shared = Arc::clone(&shared);
                Arc::new(move |deadline, timeout_frame| {
                    let ticket = shared.next_ticket.fetch_add(1, Ordering::SeqCst);
                    shared
                        .slots
                        .lock()
                        .insert(ticket, ParkSlot::Reserved { deadline, timeout_frame });
                    ticket
                }) as Arc<dyn Fn(Option<Instant>, Vec<u8>) -> u64 + Send + Sync>
            };
            let complete = {
                let shared = Arc::clone(&shared);
                Arc::new(move |ticket: u64, frame: Vec<u8>, keep: bool| {
                    let mut slots = shared.slots.lock();
                    match slots.remove(&ticket) {
                        Some(ParkSlot::Reserved { .. }) => {
                            // Completed before the worker parked the
                            // connection: leave the bytes for it.
                            slots.insert(ticket, ParkSlot::AwaitingConn { frame, keep });
                            true
                        }
                        Some(ParkSlot::AwaitingResponse { conn, .. }) => {
                            drop(slots);
                            shared.metrics.parked_dec();
                            shared.push_job(Job::Write(WriteJob {
                                conn,
                                frame,
                                off: 0,
                                keep,
                                deadline: Instant::now() + WRITE_CAP,
                            }));
                            true
                        }
                        // Already completed: the first response wins.
                        Some(other @ ParkSlot::AwaitingConn { .. }) => {
                            slots.insert(ticket, other);
                            false
                        }
                        // Timed out / evicted / canceled meanwhile:
                        // drop the bytes.
                        None => false,
                    }
                }) as Arc<dyn Fn(u64, Vec<u8>, bool) -> bool + Send + Sync>
            };
            let cancel = {
                let shared = Arc::clone(&shared);
                Arc::new(move |ticket: u64| {
                    let slot = shared.slots.lock().remove(&ticket);
                    if matches!(slot, Some(ParkSlot::AwaitingResponse { .. })) {
                        shared.metrics.parked_dec();
                    }
                    // Dropping an AwaitingResponse slot closes its conn.
                }) as Arc<dyn Fn(u64) + Send + Sync>
            };
            DeferHooks { reserve, complete, cancel }
        };

        // On any partial spawn failure, already-running workers must be
        // stopped and joined — not leaked looping on an orphan queue.
        let reap = |threads: Vec<JoinHandle<()>>| {
            shared.stop_workers();
            for t in threads {
                let _ = t.join();
            }
        };
        let mut worker_threads = Vec::with_capacity(workers);
        for i in 0..workers {
            let spawn = {
                let handler = Arc::clone(&handler);
                let shared = Arc::clone(&shared);
                let tx = rearm_tx.clone();
                let wake = Arc::clone(&wake);
                let hooks = hooks.clone();
                std::thread::Builder::new()
                    .name(format!("{}-w{i}", opts.name))
                    .spawn(move || worker_loop(handler, shared, tx, wake, hooks))
            };
            match spawn {
                Ok(t) => worker_threads.push(t),
                Err(e) => {
                    reap(worker_threads);
                    return Err(e);
                }
            }
        }
        drop(rearm_tx);

        let loop_opts = LoopOptions {
            idle_timeout: opts.idle_timeout,
            max_connections: opts.max_connections,
        };
        let io_spawn = {
            let handler = Arc::clone(&handler);
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            let wake = Arc::clone(&wake);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new().name(format!("{}-io", opts.name)).spawn(move || {
                io_loop(
                    listener, handler, shared, rearm_rx, wake, stop, metrics, poller, loop_opts,
                )
            })
        };
        let io_thread = match io_spawn {
            Ok(t) => t,
            Err(e) => {
                reap(worker_threads);
                return Err(e);
            }
        };

        let s1 = Arc::clone(&shared);
        let s2 = Arc::clone(&shared);
        let s3 = Arc::clone(&shared);
        let s4 = shared;
        Ok(Self {
            addr: local,
            stop,
            wake,
            io_thread: Some(io_thread),
            worker_threads,
            metrics,
            drain: opts.drain,
            shutdown_done: false,
            pending: Box::new(move || s1.pending()),
            abort_pending: Box::new(move || s2.abort_pending()),
            stop_workers: Box::new(move || s3.stop_workers()),
            clear_parked: Box::new(move || s4.clear_parked()),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &Arc<FrontendMetrics> {
        &self.metrics
    }

    /// Graceful shutdown: stop accepting and reading, drain queued and
    /// in-flight requests up to the drain deadline, then join every pool
    /// thread and drop every parked connection. On return no
    /// `<name>-io` / `<name>-w*` threads remain; deferred completions
    /// that fire afterwards are no-ops.
    ///
    /// The deadline bounds queued work and response writes; a handler
    /// blocked inside an unbounded syscall (e.g. a remote read with no
    /// timeout) cannot be interrupted and still delays the final join —
    /// handlers doing remote I/O should use timeouts or cooperative
    /// cancellation.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.shutdown_done {
            return;
        }
        self.shutdown_done = true;
        self.stop.store(true, Ordering::SeqCst);
        self.wake.wake();
        if let Some(t) = self.io_thread.take() {
            let _ = t.join();
        }
        // Drain: let workers finish what is queued/in flight.
        let deadline = Instant::now() + self.drain;
        while (self.pending)() > 0 {
            if Instant::now() >= deadline {
                (self.abort_pending)();
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        (self.stop_workers)();
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
        (self.clear_parked)();
    }
}

impl Drop for FrontendServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

struct LoopOptions {
    idle_timeout: Option<Duration>,
    max_connections: usize,
}

/// Fixed poller tokens: the wake pipe and the listener are registered
/// once at start; connection tokens count up from [`FIRST_CONN_TOKEN`]
/// and are never reused within one server's lifetime, so a stale event
/// can never alias a newer connection.
const TOK_WAKE: u64 = 0;
const TOK_LISTENER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// The event loop: accepts, parks idle connections, assembles frames,
/// feeds ready requests to the worker queue, re-arms write-parked
/// responses, and sweeps idle / expired parked state.
///
/// Registration-state invariant: an fd is registered with `poller` iff
/// its connection is owned by this loop (present in `conns` or
/// `wparked`, or it is the wake pipe / listener). Every path that moves
/// a connection out — hand-off to a worker, eviction, reap — must
/// deregister *before* the connection can be closed elsewhere, because a
/// closed fd's number may be reused by the next `accept`.
#[allow(clippy::too_many_arguments)]
fn io_loop<H: ConnectionHandler>(
    listener: TcpListener,
    handler: Arc<H>,
    shared: Arc<Shared<H::Conn>>,
    rearm_rx: Receiver<Back<H::Conn>>,
    wake: Arc<WakePipe>,
    stop: Arc<AtomicBool>,
    metrics: Arc<FrontendMetrics>,
    mut poller: Poller,
    opts: LoopOptions,
) {
    // Read-parked connections (token -> conn + last read progress).
    let mut conns: HashMap<u64, (Conn<H::Conn>, Instant)> = HashMap::new();
    // Write-parked responses (token -> half-written job).
    let mut wparked: HashMap<u64, WriteJob<H::Conn>> = HashMap::new();
    let mut next_token: u64 = FIRST_CONN_TOKEN;
    let mut ready_read = Vec::new();
    let mut ready_write = Vec::new();
    // The poll timeout is a liveness backstop and the sweep cadence
    // (idle eviction, parked-response deadlines); stop flags and re-arms
    // arrive via the wake pipe.
    const POLL_MS: i32 = 250;
    let mut last_sweep = Instant::now();
    let mut prev_scan = poller.scan_cost();

    while !stop.load(Ordering::SeqCst) {
        let mut wake_ready = false;
        let mut accept_ready = false;
        ready_read.clear();
        ready_write.clear();
        match poller.wait(POLL_MS) {
            Ok(events) => {
                for ev in events {
                    match ev.token {
                        TOK_WAKE => wake_ready = true,
                        TOK_LISTENER => accept_ready = true,
                        // Route by owner: the read-parked and
                        // write-parked registries never share a token.
                        tok if conns.contains_key(&tok) => ready_read.push(tok),
                        tok if wparked.contains_key(&tok) => ready_write.push(tok),
                        // Token retired between the kernel queuing the
                        // event and us reading it: ignore.
                        _ => {}
                    }
                }
            }
            Err(_) => {
                // A persistent poller error (EBADF after an fd race,
                // etc.) must not busy-spin the loop at 100% CPU.
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        }
        metrics.loop_wakeup(poller.scan_cost() - prev_scan);
        prev_scan = poller.scan_cost();
        if stop.load(Ordering::SeqCst) {
            break;
        }
        if wake_ready {
            // Drain before harvesting re-arms: a wake racing in for a
            // re-arm this iteration misses leaves the pipe readable, so
            // the next wait returns immediately instead of losing it.
            wake.drain();
        }

        // Reclaim connections whose request a worker just finished (any
        // bytes the client pipelined meanwhile are still in the kernel
        // buffer and will show up in the next poll), and responses that
        // stalled mid-write. Registration failure here means the loop
        // could never see the fd again — drop the connection instead of
        // leaking it into an unpollable limbo.
        while let Ok(back) = rearm_rx.try_recv() {
            match back {
                Back::Read(conn) => {
                    if poller.register(conn.stream.as_raw_fd(), next_token, EV_READ).is_ok() {
                        conns.insert(next_token, (conn, Instant::now()));
                    }
                }
                Back::Write(wj) => {
                    if poller.register(wj.conn.stream.as_raw_fd(), next_token, EV_WRITE).is_ok()
                    {
                        wparked.insert(next_token, wj);
                    } else {
                        metrics.parked_dec();
                    }
                }
            }
            next_token += 1;
        }

        if accept_ready {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if opts.max_connections > 0
                            && metrics.active_connections() >= opts.max_connections as u64
                        {
                            // Over the cap: accept (to clear the
                            // backlog) and close immediately.
                            metrics.connection_refused();
                            drop(stream);
                            continue;
                        }
                        let _ = stream.set_nonblocking(true);
                        let _ = stream.set_nodelay(true);
                        if poller.register(stream.as_raw_fd(), next_token, EV_READ).is_err() {
                            drop(stream);
                            continue;
                        }
                        metrics.conn_opened();
                        conns.insert(
                            next_token,
                            (
                                Conn {
                                    stream,
                                    reader: FrameReader::new(),
                                    state: handler.on_connect(),
                                    metrics: Arc::clone(&metrics),
                                },
                                Instant::now(),
                            ),
                        );
                        next_token += 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    // Per-connection transients (peer reset before we
                    // accepted): skip that connection, keep accepting.
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::ConnectionAborted
                                | std::io::ErrorKind::ConnectionReset
                                | std::io::ErrorKind::Interrupted
                        ) =>
                    {
                        continue;
                    }
                    Err(_) => {
                        // Resource exhaustion (EMFILE/ENFILE): the
                        // pending connection stays in the backlog, so
                        // level-triggered poll would report the listener
                        // ready again immediately. Back off instead of
                        // spinning until an fd frees.
                        std::thread::sleep(Duration::from_millis(10));
                        break;
                    }
                }
            }
        }

        for &tok in &ready_read {
            let mut outcome = None;
            if let Some((conn, last)) = conns.get_mut(&tok) {
                *last = Instant::now();
                outcome = Some(conn.reader.poll_frame(&mut conn.stream));
            }
            match outcome {
                Some(Ok(FrameProgress::Frame(head, payload))) => {
                    if let Some((conn, _)) = conns.remove(&tok) {
                        // Deregister before the hand-off: the worker may
                        // close the fd at any point afterwards, and its
                        // number could come back from the next accept.
                        let _ = poller.deregister(conn.stream.as_raw_fd());
                        enqueue(&shared, &stop, conn, head, payload);
                    }
                }
                // Mid-frame stall: the connection keeps waiting here in
                // the event loop — no worker is occupied.
                Some(Ok(FrameProgress::Pending)) => {}
                // Disconnect or protocol-level framing error (oversized/
                // zero frame, EOF mid-frame): reap the connection.
                Some(Ok(FrameProgress::Closed)) | Some(Err(_)) => {
                    if let Some((conn, _)) = conns.remove(&tok) {
                        let _ = poller.deregister(conn.stream.as_raw_fd());
                    }
                }
                None => {}
            }
        }

        // The peer drained its window (or hung up — the write observes
        // which): hand the remainder back to a worker.
        for &tok in &ready_write {
            if let Some(wj) = wparked.remove(&tok) {
                let _ = poller.deregister(wj.conn.stream.as_raw_fd());
                metrics.parked_dec();
                shared.push_job(Job::Write(wj));
            }
        }

        // Sweeps. Readiness events can wake the loop far more often
        // than POLL_MS; throttle to the intended cadence so a busy
        // server does not pay an O(connections + parked) scan — and the
        // slots-lock hold contending with completion wakeups — per
        // event.
        if last_sweep.elapsed() >= Duration::from_millis(POLL_MS as u64) {
            last_sweep = Instant::now();
            if let Some(idle) = opts.idle_timeout {
                let now = Instant::now();
                conns.retain(|_, (conn, last)| {
                    let keep = now.duration_since(*last) <= idle;
                    if !keep {
                        let _ = poller.deregister(conn.stream.as_raw_fd());
                        metrics.idle_eviction();
                    }
                    keep
                });
            }
            if !wparked.is_empty() {
                let now = Instant::now();
                wparked.retain(|_, wj| {
                    let keep = now < wj.deadline;
                    if !keep {
                        let _ = poller.deregister(wj.conn.stream.as_raw_fd());
                        metrics.idle_eviction();
                        metrics.parked_dec();
                    }
                    keep
                });
            }
            sweep_parked_deadlines(&shared);
        }
    }
    // Shutdown: dropping the maps actively closes every idle connection
    // and abandons half-written responses; queued/in-flight requests are
    // drained by FrontendServer::shutdown, parked deferred responses are
    // dropped by its clear_parked step.
    drop(conns);
    drop(wparked);
    drop(listener);
}

/// Answer every deferred response whose long-poll deadline has passed
/// with its prepared timeout frame (the connection survives; the late
/// completion becomes a no-op).
fn sweep_parked_deadlines<S>(shared: &Arc<Shared<S>>) {
    let now = Instant::now();
    let mut due: Vec<(Conn<S>, Vec<u8>)> = Vec::new();
    {
        let mut slots = shared.slots.lock();
        let expired: Vec<u64> = slots
            .iter()
            .filter_map(|(&t, slot)| match slot {
                ParkSlot::AwaitingResponse { deadline: Some(d), .. } if now >= *d => Some(t),
                _ => None,
            })
            .collect();
        for t in expired {
            if let Some(ParkSlot::AwaitingResponse { conn, timeout_frame, .. }) = slots.remove(&t)
            {
                due.push((conn, timeout_frame));
            }
        }
    }
    for (conn, frame) in due {
        shared.metrics.parked_dec();
        shared.push_job(Job::Write(WriteJob {
            conn,
            frame,
            off: 0,
            keep: true,
            deadline: now + WRITE_CAP,
        }));
    }
}

/// Push a ready request onto the bounded queue, applying backpressure
/// (bounded wait) when the pool is saturated.
fn enqueue<S>(
    shared: &Arc<Shared<S>>,
    stop: &Arc<AtomicBool>,
    conn: Conn<S>,
    head: u8,
    payload: Vec<u8>,
) {
    let mut q = shared.queue.lock();
    while q.len() >= shared.capacity {
        if stop.load(Ordering::SeqCst) {
            return; // shutting down: drop the request, closing the conn
        }
        let (guard, _timeout) =
            shared.space_ready.wait_timeout(q, Duration::from_millis(100));
        q = guard;
    }
    q.push_back(Job::Request { conn, head, payload, enqueued: Instant::now() });
    shared.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
    drop(q);
    shared.job_ready.notify_one();
}

/// Worker: pop a unit of work, run the handler / continue the write,
/// return the connection to the event loop.
fn worker_loop<H: ConnectionHandler>(
    handler: Arc<H>,
    shared: Arc<Shared<H::Conn>>,
    rearm_tx: Sender<Back<H::Conn>>,
    wake: Arc<WakePipe>,
    hooks: DeferHooks,
) {
    loop {
        let job = {
            let mut q = shared.queue.lock();
            loop {
                if let Some(j) = q.pop_front() {
                    // Under the same lock as the pop: Shared::pending()
                    // (queue len + active_jobs, read under this lock)
                    // must never transiently miss an in-flight job, or
                    // shutdown could skip its drain.
                    shared.active_jobs.fetch_add(1, Ordering::SeqCst);
                    break Some(j);
                }
                if shared.worker_stop.load(Ordering::SeqCst) {
                    break None;
                }
                let (guard, _timeout) =
                    shared.job_ready.wait_timeout(q, Duration::from_millis(200));
                q = guard;
            }
        };
        let Some(job) = job else { break };
        shared.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        shared.space_ready.notify_one();

        match job {
            Job::Request { mut conn, head, payload, enqueued } => {
                shared.metrics.queue_wait.record(enqueued.elapsed().as_micros() as u64);
                shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
                let mut out = Vec::new();
                let cx = RequestContext { hooks: &hooks, ticket: Cell::new(None) };
                // A panicking handler must not shrink the pool: treat it
                // as a connection-fatal error and keep the worker alive.
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    handler.handle(&mut conn.state, head, &payload, &mut out, &cx)
                }))
                .unwrap_or(HandleOutcome::Close);
                let ticket = cx.ticket.get();
                match outcome {
                    HandleOutcome::Pending => match ticket {
                        Some(t) => park_deferred(&shared, &rearm_tx, &wake, conn, t),
                        // Pending without a defer() is a handler bug:
                        // there is no way to ever answer — close.
                        None => drop(conn),
                    },
                    reply => {
                        if let Some(t) = ticket {
                            // Replied despite reserving a ticket: void
                            // it so a late completion is a no-op.
                            (hooks.cancel)(t);
                        }
                        let keep = reply == HandleOutcome::Reply;
                        finish_write(
                            &shared,
                            &rearm_tx,
                            &wake,
                            WriteJob {
                                conn,
                                frame: out,
                                off: 0,
                                keep,
                                deadline: Instant::now() + WRITE_CAP,
                            },
                        );
                    }
                }
            }
            Job::Write(wj) => finish_write(&shared, &rearm_tx, &wake, wj),
        }

        shared.active_jobs.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Park a connection whose handler deferred its response — unless the
/// completion already raced ahead, in which case write it now.
fn park_deferred<S: Send + 'static>(
    shared: &Arc<Shared<S>>,
    rearm_tx: &Sender<Back<S>>,
    wake: &Arc<WakePipe>,
    conn: Conn<S>,
    ticket: u64,
) {
    let mut slots = shared.slots.lock();
    match slots.remove(&ticket) {
        Some(ParkSlot::Reserved { deadline, timeout_frame }) => {
            slots.insert(ticket, ParkSlot::AwaitingResponse { conn, deadline, timeout_frame });
            // Gauge inc under the slots lock: a completer that takes the
            // slot the moment the lock drops runs its (saturating) dec
            // strictly after this inc, so the gauge cannot drift.
            shared.metrics.parked_inc();
            drop(slots);
        }
        Some(ParkSlot::AwaitingConn { frame, keep }) => {
            drop(slots);
            finish_write(
                shared,
                rearm_tx,
                wake,
                WriteJob { conn, frame, off: 0, keep, deadline: Instant::now() + WRITE_CAP },
            );
        }
        // Canceled (watcher dropped) before the connection parked: no
        // response can ever arrive — close.
        _ => drop(conn),
    }
}

/// Write as much of the response as the socket accepts. On completion
/// the connection re-arms for reading (if `keep`); on `WouldBlock` it
/// parks in the event loop for writability — the worker never waits.
fn finish_write<S>(
    shared: &Arc<Shared<S>>,
    rearm_tx: &Sender<Back<S>>,
    wake: &Arc<WakePipe>,
    mut wj: WriteJob<S>,
) {
    loop {
        if wj.off >= wj.frame.len() {
            if wj.keep {
                // Hand the connection back; if the event loop is gone
                // (shutdown) the send fails and the connection closes.
                if rearm_tx.send(Back::Read(wj.conn)).is_ok() {
                    wake.wake();
                }
            }
            return;
        }
        match wj.conn.stream.write(&wj.frame[wj.off..]) {
            Ok(0) => return, // peer gone: drop the connection
            Ok(n) => wj.off += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if shared.force_abort.load(Ordering::SeqCst) || Instant::now() >= wj.deadline {
                    return;
                }
                // Park the half-written response in the event loop
                // (ROADMAP follow-on (c)): a client that stopped
                // reading costs a buffer, not a worker.
                shared.metrics.parked_inc();
                if rearm_tx.send(Back::Write(wj)).is_ok() {
                    wake.wake();
                } else {
                    shared.metrics.parked_dec();
                }
                return;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::framing::{read_response, write_err, write_ok, write_request, Method, Status};
    use crate::wire::messages::{EmptyResponse, GetStudyRequest};
    use std::io::BufReader;

    /// Echo-style handler: replies OK to `Ping`, errors-and-closes on
    /// anything else. Counts per-connection requests in its state.
    struct PingHandler;

    impl ConnectionHandler for PingHandler {
        type Conn = u64;
        fn on_connect(&self) -> u64 {
            0
        }
        fn handle(
            &self,
            served: &mut u64,
            head: u8,
            _payload: &[u8],
            out: &mut Vec<u8>,
            _cx: &RequestContext<'_>,
        ) -> HandleOutcome {
            *served += 1;
            if head == Method::Ping as u8 {
                let _ = write_ok(out, &EmptyResponse::default());
                HandleOutcome::Reply
            } else {
                let _ = write_err(out, Status::InvalidArgument, "bad method");
                HandleOutcome::Close
            }
        }
    }

    fn ping(stream: &mut TcpStream) {
        write_request(stream, Method::Ping, &EmptyResponse::default()).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let _: EmptyResponse = read_response(&mut r).unwrap();
    }

    #[test]
    fn serves_many_connections_with_two_workers() {
        let server = FrontendServer::start(
            PingHandler,
            "127.0.0.1:0",
            FrontendOptions { name: "fe-test", workers: 2, ..Default::default() },
        )
        .unwrap();
        let addr = server.local_addr();
        let mut conns: Vec<TcpStream> =
            (0..32).map(|_| TcpStream::connect(addr).unwrap()).collect();
        for c in conns.iter_mut() {
            ping(c);
            ping(c); // sequential requests on one connection
        }
        assert_eq!(server.metrics().requests(), 64);
        assert_eq!(server.metrics().active_connections(), 32);
        assert_eq!(server.metrics().connections_total(), 32);
        server.shutdown();
    }

    #[test]
    fn handler_close_and_gauge_decrement() {
        let server = FrontendServer::start(
            PingHandler,
            "127.0.0.1:0",
            FrontendOptions { name: "fe-test2", workers: 1, ..Default::default() },
        )
        .unwrap();
        let addr = server.local_addr();
        let mut good = TcpStream::connect(addr).unwrap();
        ping(&mut good);
        let mut bad = TcpStream::connect(addr).unwrap();
        write_request(&mut bad, Method::GetStudy, &GetStudyRequest::default()).unwrap();
        let mut r = BufReader::new(bad.try_clone().unwrap());
        let err = read_response::<_, EmptyResponse>(&mut r).unwrap_err();
        assert!(matches!(
            err,
            crate::wire::framing::FrameError::Rpc { status: Status::InvalidArgument, .. }
        ));
        // The handler returned Close: the server closes `bad` and the
        // gauge drops back to 1.
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.metrics().active_connections() != 1 {
            assert!(Instant::now() < deadline, "gauge never decremented");
            std::thread::sleep(Duration::from_millis(5));
        }
        ping(&mut good); // the survivor still works
        server.shutdown();
    }

    /// A handler that defers every Ping and completes it from a helper
    /// thread after a short delay — the deferred-response plumbing end
    /// to end, including the parked_responses gauge.
    struct DeferredPing {
        delay: Duration,
        /// Long-poll deadline given to defer(); None = no timeout.
        deadline_in: Option<Duration>,
        /// Complete at all? false exercises the timeout path.
        complete: bool,
    }

    impl ConnectionHandler for DeferredPing {
        type Conn = ();
        fn on_connect(&self) {}
        fn handle(
            &self,
            _state: &mut (),
            _head: u8,
            _payload: &[u8],
            _out: &mut Vec<u8>,
            cx: &RequestContext<'_>,
        ) -> HandleOutcome {
            let mut timeout_frame = Vec::new();
            let _ = write_err(&mut timeout_frame, Status::Unimplemented, "timed out");
            let deadline = self.deadline_in.map(|d| Instant::now() + d);
            let handle = cx.defer(deadline, timeout_frame);
            if self.complete {
                let delay = self.delay;
                std::thread::spawn(move || {
                    std::thread::sleep(delay);
                    let mut frame = Vec::new();
                    let _ = write_ok(&mut frame, &EmptyResponse::default());
                    handle.complete(frame);
                });
            } else {
                // Dropping the handle here would abort the ticket and
                // close the client; hold it past the deadline instead.
                let delay = self.delay;
                std::thread::spawn(move || {
                    std::thread::sleep(delay);
                    drop(handle);
                });
            }
            HandleOutcome::Pending
        }
    }

    #[test]
    fn deferred_response_wakes_parked_connection() {
        let server = FrontendServer::start(
            DeferredPing {
                delay: Duration::from_millis(120),
                deadline_in: None,
                complete: true,
            },
            "127.0.0.1:0",
            FrontendOptions { name: "fe-defer", workers: 1, ..Default::default() },
        )
        .unwrap();
        let addr = server.local_addr();
        // Two clients park concurrently on the single worker: deferral
        // must free it between them.
        let mut a = TcpStream::connect(addr).unwrap();
        let mut b = TcpStream::connect(addr).unwrap();
        write_request(&mut a, Method::Ping, &EmptyResponse::default()).unwrap();
        write_request(&mut b, Method::Ping, &EmptyResponse::default()).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.metrics().parked_responses() < 2 {
            assert!(Instant::now() < deadline, "responses never parked");
            std::thread::sleep(Duration::from_millis(5));
        }
        let mut ra = BufReader::new(a.try_clone().unwrap());
        let mut rb = BufReader::new(b.try_clone().unwrap());
        let _: EmptyResponse = read_response(&mut ra).unwrap();
        let _: EmptyResponse = read_response(&mut rb).unwrap();
        assert_eq!(server.metrics().parked_responses(), 0);
        // The connections survive and serve the next (deferred) request.
        write_request(&mut a, Method::Ping, &EmptyResponse::default()).unwrap();
        let _: EmptyResponse = read_response(&mut ra).unwrap();
        server.shutdown();
    }

    #[test]
    fn deferred_deadline_answers_with_timeout_frame() {
        let server = FrontendServer::start(
            DeferredPing {
                delay: Duration::from_secs(2),
                deadline_in: Some(Duration::from_millis(50)),
                complete: false,
            },
            "127.0.0.1:0",
            FrontendOptions { name: "fe-dtime", workers: 1, ..Default::default() },
        )
        .unwrap();
        let addr = server.local_addr();
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write_request(&mut c, Method::Ping, &EmptyResponse::default()).unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        // The sweep (<= 250ms cadence) answers with the timeout frame
        // long before the 2s never-completing handle resolves.
        let err = read_response::<_, EmptyResponse>(&mut r).unwrap_err();
        assert!(matches!(
            err,
            crate::wire::framing::FrameError::Rpc { status: Status::Unimplemented, .. }
        ));
        server.shutdown();
    }

    #[test]
    fn max_connections_refuses_excess() {
        let server = FrontendServer::start(
            PingHandler,
            "127.0.0.1:0",
            FrontendOptions {
                name: "fe-cap",
                workers: 1,
                max_connections: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let addr = server.local_addr();
        let mut a = TcpStream::connect(addr).unwrap();
        let mut b = TcpStream::connect(addr).unwrap();
        ping(&mut a);
        ping(&mut b);
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // The refused socket is closed without a response.
        let mut buf = [0u8; 1];
        use std::io::Read as _;
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match c.read(&mut buf) {
                Ok(0) => break,
                Ok(_) => panic!("refused connection received bytes"),
                Err(_) => assert!(Instant::now() < deadline, "refused conn never closed"),
            }
        }
        assert_eq!(server.metrics().connections_refused(), 1);
        assert_eq!(server.metrics().active_connections(), 2);
        ping(&mut a); // survivors unaffected
        server.shutdown();
    }

    #[test]
    fn idle_timeout_evicts_parked_connections() {
        let server = FrontendServer::start(
            PingHandler,
            "127.0.0.1:0",
            FrontendOptions {
                name: "fe-idle",
                workers: 1,
                idle_timeout: Some(Duration::from_millis(200)),
                ..Default::default()
            },
        )
        .unwrap();
        let addr = server.local_addr();
        let mut idle = TcpStream::connect(addr).unwrap();
        ping(&mut idle);
        assert_eq!(server.metrics().active_connections(), 1);
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.metrics().active_connections() != 0 {
            assert!(Instant::now() < deadline, "idle connection never evicted");
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(server.metrics().idle_evictions() >= 1);
        // A fresh connection still works.
        let mut fresh = TcpStream::connect(addr).unwrap();
        ping(&mut fresh);
        server.shutdown();
    }
}
