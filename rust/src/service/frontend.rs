//! Shared bounded worker-pool TCP front-end.
//!
//! The paper's reference service multiplexes thousands of worker clients
//! behind `grpc.server(ThreadPoolExecutor(max_workers=100))` (Code Block
//! 4): connections do not cost a thread; only *ready requests* occupy
//! workers. This module is the Rust analogue, replacing the original
//! thread-per-connection front-end that spawned an unbounded OS thread
//! per client:
//!
//! * One **event-loop thread** (`<name>-io`) owns the listener and every
//!   idle connection. It blocks in [`crate::util::netpoll::wait_readable`]
//!   (raw POSIX `poll(2)`, no crates) over all of them plus a
//!   [`WakePipe`]. Idle or stalled connections park here without a
//!   thread; partial frames accumulate in a per-connection
//!   [`FrameReader`] so a slow client can never pin a worker.
//! * **N worker threads** (`<name>-w<i>`) take complete framed requests
//!   off a bounded queue, run the [`ConnectionHandler`], write the
//!   response, and hand the connection back to the event loop. One frame
//!   = one job; a connection is owned by at most one thread at a time, so
//!   requests on a connection stay sequential (same contract as the old
//!   per-connection loop).
//! * **Graceful shutdown** stops the event loop (closing the listener and
//!   every idle connection), drains queued + in-flight requests up to a
//!   deadline, then joins all pool threads — no orphaned connection
//!   threads, unlike the old front-end which leaked its `vizier-conn`
//!   threads.
//!
//! [`FrontendMetrics`] tracks the `active_connections` gauge, queue depth
//! and queue-wait histogram; the `C-FRONTEND` bench
//! (`benches/bench_frontend.rs`) drives 1000+ mostly-idle connections
//! through this module and asserts the thread budget stays at
//! `workers + 2` (io loop + accept handled by the same thread).

use crate::service::metrics::FrontendMetrics;
use crate::util::netpoll::{self, PollSet, WakePipe};
use crate::wire::framing::{FrameProgress, FrameReader};
use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Per-connection protocol logic run on worker threads.
pub trait ConnectionHandler: Send + Sync + 'static {
    /// Per-connection state (e.g. a lazily-opened upstream channel).
    /// Travels with the connection between the event loop and workers.
    type Conn: Send + 'static;

    /// Called on the event-loop thread at accept time — must not block.
    fn on_connect(&self) -> Self::Conn;

    /// Handle one framed request: write the complete response frame into
    /// `out`. Return `false` to close the connection after `out` is
    /// flushed (protocol violations), `true` to keep serving it.
    fn handle(&self, conn: &mut Self::Conn, head: u8, payload: &[u8], out: &mut Vec<u8>) -> bool;
}

/// Tuning knobs for a [`FrontendServer`].
pub struct FrontendOptions {
    /// Thread-name prefix (shows up in `/proc/self/task/*/comm`; keep it
    /// short, Linux truncates names to 15 bytes).
    pub name: &'static str,
    /// Worker threads. 0 = [`default_workers`] (the CPU count).
    pub workers: usize,
    /// Bounded queue capacity. 0 = `workers * 64`. When full, the event
    /// loop applies backpressure by pausing reads (connections stay
    /// parked, nothing is dropped).
    pub queue_capacity: usize,
    /// How long shutdown waits for queued + in-flight requests to drain
    /// before abandoning the remainder.
    pub drain: Duration,
    /// Metrics sink; supply one to share with [`super::metrics::ServiceMetrics`].
    pub metrics: Option<Arc<FrontendMetrics>>,
}

impl Default for FrontendOptions {
    fn default() -> Self {
        Self {
            name: "frontend",
            workers: 0,
            queue_capacity: 0,
            drain: Duration::from_secs(5),
            metrics: None,
        }
    }
}

/// Default worker count: the machine's CPU parallelism (the paper's
/// fixed `max_workers=100` sized for Google's servers; CPUs is the right
/// default for a bounded request-compute pool).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// A live connection. Owned by exactly one thread at a time: the event
/// loop while idle/reading, a worker while a request is in flight.
struct Conn<S> {
    stream: TcpStream,
    reader: FrameReader,
    state: S,
    metrics: Arc<FrontendMetrics>,
}

impl<S> Drop for Conn<S> {
    fn drop(&mut self) {
        // Closing the socket and decrementing the gauge happen together,
        // wherever the connection dies (event loop, worker, queue drop).
        self.metrics.conn_closed();
    }
}

/// One ready request: the connection plus its decoded frame.
struct Job<S> {
    conn: Conn<S>,
    head: u8,
    payload: Vec<u8>,
    enqueued: Instant,
}

/// State shared between the event loop, workers, and shutdown.
struct Shared<S> {
    queue: Mutex<VecDeque<Job<S>>>,
    job_ready: Condvar,
    space_ready: Condvar,
    capacity: usize,
    /// Workers exit once this is set and the queue is empty.
    worker_stop: AtomicBool,
    /// Set when the drain deadline passes: abort in-flight writes.
    force_abort: AtomicBool,
    active_jobs: AtomicUsize,
    metrics: Arc<FrontendMetrics>,
}

impl<S> Shared<S> {
    fn pending(&self) -> usize {
        self.queue.lock().unwrap().len() + self.active_jobs.load(Ordering::SeqCst)
    }

    fn abort_pending(&self) {
        let dropped = {
            let mut q = self.queue.lock().unwrap();
            let n = q.len();
            q.clear(); // drops Jobs -> closes their connections
            n
        };
        if dropped > 0 {
            self.metrics.queue_depth.fetch_sub(dropped as u64, Ordering::Relaxed);
        }
        self.force_abort.store(true, Ordering::SeqCst);
    }

    fn stop_workers(&self) {
        self.worker_stop.store(true, Ordering::SeqCst);
        self.job_ready.notify_all();
        self.space_ready.notify_all();
    }
}

/// A running event-loop + worker-pool server. Dropping it performs the
/// same graceful shutdown as [`FrontendServer::shutdown`].
pub struct FrontendServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    wake: Arc<WakePipe>,
    io_thread: Option<JoinHandle<()>>,
    worker_threads: Vec<JoinHandle<()>>,
    metrics: Arc<FrontendMetrics>,
    drain: Duration,
    /// Guards shutdown_inner: an explicit `shutdown()` consumes `self`,
    /// which runs Drop — the sequence must not execute twice.
    shutdown_done: bool,
    // Type-erased handles into the generic Shared<S>.
    pending: Box<dyn Fn() -> usize + Send + Sync>,
    abort_pending: Box<dyn Fn() + Send + Sync>,
    stop_workers: Box<dyn Fn() + Send + Sync>,
}

impl FrontendServer {
    /// Bind `addr` and start the event loop and worker pool.
    pub fn start<H: ConnectionHandler>(
        handler: H,
        addr: &str,
        opts: FrontendOptions,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;

        let workers = if opts.workers == 0 { default_workers() } else { opts.workers };
        let capacity =
            if opts.queue_capacity == 0 { workers * 64 } else { opts.queue_capacity };
        let metrics = opts.metrics.unwrap_or_default();
        let handler = Arc::new(handler);
        let stop = Arc::new(AtomicBool::new(false));
        let wake = Arc::new(WakePipe::new()?);
        let shared = Arc::new(Shared::<H::Conn> {
            queue: Mutex::new(VecDeque::new()),
            job_ready: Condvar::new(),
            space_ready: Condvar::new(),
            capacity,
            worker_stop: AtomicBool::new(false),
            force_abort: AtomicBool::new(false),
            active_jobs: AtomicUsize::new(0),
            metrics: Arc::clone(&metrics),
        });
        let (rearm_tx, rearm_rx) = mpsc::channel::<Conn<H::Conn>>();

        // On any partial spawn failure, already-running workers must be
        // stopped and joined — not leaked looping on an orphan queue.
        let reap = |threads: Vec<JoinHandle<()>>| {
            shared.stop_workers();
            for t in threads {
                let _ = t.join();
            }
        };
        let mut worker_threads = Vec::with_capacity(workers);
        for i in 0..workers {
            let spawn = {
                let handler = Arc::clone(&handler);
                let shared = Arc::clone(&shared);
                let tx = rearm_tx.clone();
                let wake = Arc::clone(&wake);
                std::thread::Builder::new()
                    .name(format!("{}-w{i}", opts.name))
                    .spawn(move || worker_loop(handler, shared, tx, wake))
            };
            match spawn {
                Ok(t) => worker_threads.push(t),
                Err(e) => {
                    reap(worker_threads);
                    return Err(e);
                }
            }
        }
        drop(rearm_tx);

        let io_spawn = {
            let handler = Arc::clone(&handler);
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            let wake = Arc::clone(&wake);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new().name(format!("{}-io", opts.name)).spawn(move || {
                io_loop(listener, handler, shared, rearm_rx, wake, stop, metrics)
            })
        };
        let io_thread = match io_spawn {
            Ok(t) => t,
            Err(e) => {
                reap(worker_threads);
                return Err(e);
            }
        };

        let s1 = Arc::clone(&shared);
        let s2 = Arc::clone(&shared);
        let s3 = shared;
        Ok(Self {
            addr: local,
            stop,
            wake,
            io_thread: Some(io_thread),
            worker_threads,
            metrics,
            drain: opts.drain,
            shutdown_done: false,
            pending: Box::new(move || s1.pending()),
            abort_pending: Box::new(move || s2.abort_pending()),
            stop_workers: Box::new(move || s3.stop_workers()),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &Arc<FrontendMetrics> {
        &self.metrics
    }

    /// Graceful shutdown: stop accepting and reading, drain queued and
    /// in-flight requests up to the drain deadline, then join every pool
    /// thread. On return no `<name>-io` / `<name>-w*` threads remain.
    ///
    /// The deadline bounds queued work and response writes; a handler
    /// blocked inside an unbounded syscall (e.g. a remote read with no
    /// timeout) cannot be interrupted and still delays the final join —
    /// handlers doing remote I/O should use timeouts or cooperative
    /// cancellation.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.shutdown_done {
            return;
        }
        self.shutdown_done = true;
        self.stop.store(true, Ordering::SeqCst);
        self.wake.wake();
        if let Some(t) = self.io_thread.take() {
            let _ = t.join();
        }
        // Drain: let workers finish what is queued/in flight.
        let deadline = Instant::now() + self.drain;
        while (self.pending)() > 0 {
            if Instant::now() >= deadline {
                (self.abort_pending)();
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        (self.stop_workers)();
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for FrontendServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// The event loop: accepts, parks idle connections, assembles frames,
/// and feeds ready requests to the worker queue.
fn io_loop<H: ConnectionHandler>(
    listener: TcpListener,
    handler: Arc<H>,
    shared: Arc<Shared<H::Conn>>,
    rearm_rx: Receiver<Conn<H::Conn>>,
    wake: Arc<WakePipe>,
    stop: Arc<AtomicBool>,
    metrics: Arc<FrontendMetrics>,
) {
    let mut conns: HashMap<u64, Conn<H::Conn>> = HashMap::new();
    let mut next_token: u64 = 0;
    let mut fds = Vec::new();
    let mut toks = Vec::new();
    let mut pollset = PollSet::new();
    let mut ready_toks = Vec::new();
    // The poll timeout is a liveness backstop only (stop flags and
    // re-arms arrive via the wake pipe); idle servers sit in poll.
    const POLL_MS: i32 = 250;

    while !stop.load(Ordering::SeqCst) {
        fds.clear();
        toks.clear();
        fds.push(wake.read_fd());
        fds.push(listener.as_raw_fd());
        for (&tok, c) in conns.iter() {
            fds.push(c.stream.as_raw_fd());
            toks.push(tok);
        }
        let ready = match pollset.wait_readable(&fds, POLL_MS) {
            Ok(r) => r,
            Err(_) => {
                // A persistent poll error (EBADF after an fd race, etc.)
                // must not busy-spin the loop at 100% CPU.
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            break;
        }

        let mut accept_ready = false;
        ready_toks.clear();
        for &idx in ready {
            match idx {
                0 => wake.drain(),
                1 => accept_ready = true,
                n => ready_toks.push(toks[n - 2]),
            }
        }

        // Reclaim connections whose request a worker just finished. Any
        // bytes the client pipelined meanwhile are still in the kernel
        // buffer and will show up in the next poll.
        while let Ok(conn) = rearm_rx.try_recv() {
            conns.insert(next_token, conn);
            next_token += 1;
        }

        if accept_ready {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = stream.set_nonblocking(true);
                        let _ = stream.set_nodelay(true);
                        metrics.conn_opened();
                        conns.insert(
                            next_token,
                            Conn {
                                stream,
                                reader: FrameReader::new(),
                                state: handler.on_connect(),
                                metrics: Arc::clone(&metrics),
                            },
                        );
                        next_token += 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    // Per-connection transients (peer reset before we
                    // accepted): skip that connection, keep accepting.
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::ConnectionAborted
                                | std::io::ErrorKind::ConnectionReset
                                | std::io::ErrorKind::Interrupted
                        ) =>
                    {
                        continue;
                    }
                    Err(_) => {
                        // Resource exhaustion (EMFILE/ENFILE): the
                        // pending connection stays in the backlog, so
                        // level-triggered poll would report the listener
                        // ready again immediately. Back off instead of
                        // spinning until an fd frees.
                        std::thread::sleep(Duration::from_millis(10));
                        break;
                    }
                }
            }
        }

        for &tok in &ready_toks {
            let mut outcome = None;
            if let Some(conn) = conns.get_mut(&tok) {
                outcome = Some(conn.reader.poll_frame(&mut conn.stream));
            }
            match outcome {
                Some(Ok(FrameProgress::Frame(head, payload))) => {
                    let conn = conns.remove(&tok).expect("conn present");
                    enqueue(&shared, &stop, conn, head, payload);
                }
                // Mid-frame stall: the connection keeps waiting here in
                // the event loop — no worker is occupied.
                Some(Ok(FrameProgress::Pending)) => {}
                // Disconnect or protocol-level framing error (oversized/
                // zero frame, EOF mid-frame): reap the connection.
                Some(Ok(FrameProgress::Closed)) | Some(Err(_)) => {
                    conns.remove(&tok);
                }
                None => {}
            }
        }
    }
    // Shutdown: dropping the map actively closes every idle connection;
    // queued/in-flight requests are drained by FrontendServer::shutdown.
    drop(conns);
    drop(listener);
}

/// Push a ready request onto the bounded queue, applying backpressure
/// (bounded wait) when the pool is saturated.
fn enqueue<S>(
    shared: &Arc<Shared<S>>,
    stop: &Arc<AtomicBool>,
    conn: Conn<S>,
    head: u8,
    payload: Vec<u8>,
) {
    let mut q = shared.queue.lock().unwrap();
    while q.len() >= shared.capacity {
        if stop.load(Ordering::SeqCst) {
            return; // shutting down: drop the request, closing the conn
        }
        let (guard, _timeout) =
            shared.space_ready.wait_timeout(q, Duration::from_millis(100)).unwrap();
        q = guard;
    }
    q.push_back(Job { conn, head, payload, enqueued: Instant::now() });
    shared.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
    drop(q);
    shared.job_ready.notify_one();
}

/// Worker: pop a ready request, run the handler, write the response,
/// return the connection to the event loop.
fn worker_loop<H: ConnectionHandler>(
    handler: Arc<H>,
    shared: Arc<Shared<H::Conn>>,
    rearm_tx: Sender<Conn<H::Conn>>,
    wake: Arc<WakePipe>,
) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    // Under the same lock as the pop: Shared::pending()
                    // (queue len + active_jobs, read under this lock)
                    // must never transiently miss an in-flight job, or
                    // shutdown could skip its drain.
                    shared.active_jobs.fetch_add(1, Ordering::SeqCst);
                    break Some(j);
                }
                if shared.worker_stop.load(Ordering::SeqCst) {
                    break None;
                }
                let (guard, _timeout) =
                    shared.job_ready.wait_timeout(q, Duration::from_millis(200)).unwrap();
                q = guard;
            }
        };
        let Some(mut job) = job else { break };
        shared.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        shared.space_ready.notify_one();
        shared.metrics.queue_wait.record(job.enqueued.elapsed().as_micros() as u64);
        shared.metrics.requests.fetch_add(1, Ordering::Relaxed);

        let mut out = Vec::new();
        // A panicking handler must not shrink the pool: treat it as a
        // connection-fatal error and keep the worker alive.
        let keep = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            handler.handle(&mut job.conn.state, job.head, &job.payload, &mut out)
        }))
        .unwrap_or(false);
        let sent = write_response(&mut job.conn.stream, &out, &shared);

        shared.active_jobs.fetch_sub(1, Ordering::SeqCst);
        if keep && sent {
            // Hand the connection back; if the event loop is gone
            // (shutdown) the send fails and the connection just closes.
            if rearm_tx.send(job.conn).is_ok() {
                wake.wake();
            }
        }
    }
}

/// Write the full response to a non-blocking socket, parking in
/// `poll(2)` on `WouldBlock`. Bounded by a hard cap and the shutdown
/// force-abort flag so a dead peer cannot wedge a worker forever.
///
/// Known limit: the no-worker-pinning guarantee covers the *read* side
/// only. A client that sends requests but stops reading large responses
/// can hold a worker here for up to `WRITE_CAP`; parking half-written
/// responses back in the event loop (a write-side state machine) is the
/// ROADMAP follow-on that closes this.
fn write_response<S>(stream: &mut TcpStream, buf: &[u8], shared: &Shared<S>) -> bool {
    const WRITE_CAP: Duration = Duration::from_secs(30);
    let deadline = Instant::now() + WRITE_CAP;
    let mut off = 0;
    while off < buf.len() {
        match stream.write(&buf[off..]) {
            Ok(0) => return false,
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if shared.force_abort.load(Ordering::SeqCst) || Instant::now() >= deadline {
                    return false;
                }
                if netpoll::wait_writable(stream.as_raw_fd(), 100).is_err() {
                    return false;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::framing::{read_response, write_err, write_ok, write_request, Method, Status};
    use crate::wire::messages::{EmptyResponse, GetStudyRequest};
    use std::io::BufReader;

    /// Echo-style handler: replies OK to `Ping`, errors-and-closes on
    /// anything else. Counts per-connection requests in its state.
    struct PingHandler;

    impl ConnectionHandler for PingHandler {
        type Conn = u64;
        fn on_connect(&self) -> u64 {
            0
        }
        fn handle(&self, served: &mut u64, head: u8, _payload: &[u8], out: &mut Vec<u8>) -> bool {
            *served += 1;
            if head == Method::Ping as u8 {
                let _ = write_ok(out, &EmptyResponse::default());
                true
            } else {
                let _ = write_err(out, Status::InvalidArgument, "bad method");
                false
            }
        }
    }

    fn ping(stream: &mut TcpStream) {
        write_request(stream, Method::Ping, &EmptyResponse::default()).unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let _: EmptyResponse = read_response(&mut r).unwrap();
    }

    #[test]
    fn serves_many_connections_with_two_workers() {
        let server = FrontendServer::start(
            PingHandler,
            "127.0.0.1:0",
            FrontendOptions { name: "fe-test", workers: 2, ..Default::default() },
        )
        .unwrap();
        let addr = server.local_addr();
        let mut conns: Vec<TcpStream> =
            (0..32).map(|_| TcpStream::connect(addr).unwrap()).collect();
        for c in conns.iter_mut() {
            ping(c);
            ping(c); // sequential requests on one connection
        }
        assert_eq!(server.metrics().requests(), 64);
        assert_eq!(server.metrics().active_connections(), 32);
        assert_eq!(server.metrics().connections_total(), 32);
        server.shutdown();
    }

    #[test]
    fn handler_close_and_gauge_decrement() {
        let server = FrontendServer::start(
            PingHandler,
            "127.0.0.1:0",
            FrontendOptions { name: "fe-test2", workers: 1, ..Default::default() },
        )
        .unwrap();
        let addr = server.local_addr();
        let mut good = TcpStream::connect(addr).unwrap();
        ping(&mut good);
        let mut bad = TcpStream::connect(addr).unwrap();
        write_request(&mut bad, Method::GetStudy, &GetStudyRequest::default()).unwrap();
        let mut r = BufReader::new(bad.try_clone().unwrap());
        let err = read_response::<_, EmptyResponse>(&mut r).unwrap_err();
        assert!(matches!(
            err,
            crate::wire::framing::FrameError::Rpc { status: Status::InvalidArgument, .. }
        ));
        // The handler returned false: the server closes `bad` and the
        // gauge drops back to 1.
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.metrics().active_connections() != 1 {
            assert!(Instant::now() < deadline, "gauge never decremented");
            std::thread::sleep(Duration::from_millis(5));
        }
        ping(&mut good); // the survivor still works
        server.shutdown();
    }
}
