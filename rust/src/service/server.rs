//! TCP front-end: accepts connections and dispatches framed RPCs to the
//! [`VizierService`] (the Rust analogue of Code Block 4's
//! `grpc.server(ThreadPoolExecutor(...))` setup).

use super::api::VizierService;
use crate::util::time::Stopwatch;
use crate::wire::codec::decode;
use crate::wire::framing::{read_request, write_err, write_ok, FrameError, Method, Status};
use crate::wire::messages::EmptyResponse;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// A running TCP server.
pub struct VizierServer {
    addr: std::net::SocketAddr,
    service: Arc<VizierService>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    pub connections: Arc<AtomicU64>,
}

impl VizierServer {
    /// Bind and start serving. `addr` like `"127.0.0.1:6006"`; use port 0
    /// for an ephemeral port (tests).
    pub fn start(service: Arc<VizierService>, addr: &str) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicU64::new(0));
        let svc = Arc::clone(&service);
        let stop2 = Arc::clone(&stop);
        let conns = Arc::clone(&connections);
        let accept_thread = std::thread::Builder::new()
            .name("vizier-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match stream {
                        Ok(stream) => {
                            conns.fetch_add(1, Ordering::Relaxed);
                            let svc = Arc::clone(&svc);
                            // Connection-per-thread: each worker connection
                            // is long-lived and serves sequential requests.
                            let _ = std::thread::Builder::new()
                                .name("vizier-conn".into())
                                .spawn(move || {
                                    let _ = serve_connection(&svc, stream);
                                });
                        }
                        Err(_) => continue,
                    }
                }
            })?;
        Ok(Self {
            addr: local,
            service,
            stop,
            accept_thread: Some(accept_thread),
            connections,
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn service(&self) -> &Arc<VizierService> {
        &self.service
    }

    /// Stop accepting new connections (existing connections drain on their
    /// own when clients disconnect).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        self.service.shutdown();
    }
}

impl Drop for VizierServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Serve one connection: a loop of request -> dispatch -> response.
fn serve_connection(service: &Arc<VizierService>, stream: TcpStream) -> Result<(), FrameError> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let (method, payload) = match read_request(&mut reader) {
            Ok(x) => x,
            Err(FrameError::Io(_)) => return Ok(()), // client disconnected
            Err(e) => return Err(e),
        };
        let sw = Stopwatch::start();
        let result = dispatch(service, method, &payload, &mut writer);
        service
            .metrics
            .record(&format!("{method:?}"), sw.elapsed_micros());
        result?;
    }
}

/// Decode, call, encode for a single method.
pub fn dispatch<W: Write>(
    service: &Arc<VizierService>,
    method: Method,
    payload: &[u8],
    out: &mut W,
) -> Result<(), FrameError> {
    macro_rules! call {
        ($fn:ident) => {{
            match decode(payload) {
                Ok(req) => match service.$fn(req) {
                    Ok(resp) => write_ok(out, &resp),
                    Err(e) => {
                        service.metrics.record_error();
                        write_err(out, e.status, &e.message)
                    }
                },
                Err(e) => write_err(out, Status::InvalidArgument, &format!("bad request: {e}")),
            }
        }};
    }
    match method {
        Method::CreateStudy => call!(create_study),
        Method::GetStudy => call!(get_study),
        Method::ListStudies => call!(list_studies),
        Method::DeleteStudy => call!(delete_study),
        Method::LookupStudy => call!(lookup_study),
        Method::SuggestTrials => call!(suggest_trials),
        Method::GetOperation => call!(get_operation),
        Method::AddMeasurement => call!(add_measurement),
        Method::CompleteTrial => call!(complete_trial),
        Method::ListTrials => call!(list_trials),
        Method::GetTrial => call!(get_trial),
        Method::DeleteTrial => call!(delete_trial),
        Method::CheckEarlyStopping => call!(check_early_stopping),
        Method::StopTrial => call!(stop_trial),
        Method::ListOptimalTrials => call!(list_optimal_trials),
        Method::UpdateMetadata => call!(update_metadata),
        Method::Ping => write_ok(out, &EmptyResponse::default()),
    }
}

/// Read side of `dispatch` for in-process transports: handles one raw
/// frame pair over byte buffers.
pub fn dispatch_buf(service: &Arc<VizierService>, method: Method, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let _ = dispatch(service, method, payload, &mut out);
    out
}

