//! TCP front-end: accepts connections and dispatches framed RPCs to the
//! [`VizierService`] (the Rust analogue of Code Block 4's
//! `grpc.server(ThreadPoolExecutor(...))` setup).
//!
//! Two connection-handling models:
//!
//! * **Worker pool** (default): the event loop + bounded worker pool of
//!   [`crate::service::frontend`]. Thousands of mostly-idle worker
//!   clients — the normal Vizier fleet shape — cost no threads; the
//!   server runs exactly `workers + 1` threads (`vizier-fe-w*` plus
//!   `vizier-fe-io`).
//! * **Legacy thread-per-connection** ([`ServerOptions::legacy_threads`],
//!   CLI `--legacy-threads`): one `vizier-conn` OS thread per client.
//!   Kept as the comparison baseline for the `C-FRONTEND` bench. Its
//!   historical shutdown leak is fixed: live connection sockets are
//!   actively shut down and their threads joined.

use super::api::{effective_wait_ms, OpStream, OpWaiter, VizierService, WatchResult};
use super::frontend::{
    ConnectionHandler, FrontendOptions, FrontendServer, HandleOutcome, MuxSink, RequestContext,
};
use super::metrics::FrontendMetrics;
use crate::util::time::Stopwatch;
use crate::util::trace;
use crate::wire::codec::decode;
use crate::wire::framing::{read_request, write_err, write_ok, FrameError, Method, Status};
use crate::wire::messages::{
    extract_trace_context, EmptyResponse, GetOperationRequest, OperationProto, OperationResponse,
    WaitOperationRequest,
};
use crate::util::sync::{classes, Mutex};
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Front-end configuration for [`VizierServer::start_with`].
pub struct ServerOptions {
    /// Worker-pool threads. 0 = the CPU count
    /// ([`crate::service::frontend::default_workers`]).
    pub workers: usize,
    /// Use the legacy thread-per-connection front-end instead of the
    /// worker pool (baseline for benchmarks).
    pub legacy_threads: bool,
    /// Shutdown drain deadline for queued + in-flight requests.
    pub drain: Duration,
    /// Evict connections idle longer than this (pool mode only; `None`
    /// = never). CLI: `--idle-timeout-secs`.
    pub idle_timeout: Option<Duration>,
    /// Refuse connections beyond this many (pool mode only; 0 =
    /// unlimited). CLI: `--max-connections`.
    pub max_connections: usize,
    /// Event-loop readiness backend (pool mode only). Defaults to the
    /// `OSSVIZIER_POLLER` env knob, falling back to epoll; the
    /// rebuilt-each-wakeup poll(2) baseline stays available as
    /// `--poller=poll`.
    pub poller: crate::util::netpoll::PollerKind,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            workers: 0,
            legacy_threads: false,
            drain: Duration::from_secs(5),
            idle_timeout: None,
            max_connections: 0,
            poller: crate::util::netpoll::PollerKind::from_env(),
        }
    }
}

/// A running TCP server.
pub struct VizierServer {
    addr: std::net::SocketAddr,
    service: Arc<VizierService>,
    frontend_metrics: Arc<FrontendMetrics>,
    inner: Inner,
}

enum Inner {
    Pool(FrontendServer),
    Legacy(LegacyServer),
}

impl VizierServer {
    /// Bind and start serving with default options (worker pool sized to
    /// the CPU count). `addr` like `"127.0.0.1:6006"`; use port 0 for an
    /// ephemeral port (tests).
    pub fn start(service: Arc<VizierService>, addr: &str) -> std::io::Result<Self> {
        Self::start_with(service, addr, ServerOptions::default())
    }

    /// Bind and start serving with explicit front-end options.
    pub fn start_with(
        service: Arc<VizierService>,
        addr: &str,
        opts: ServerOptions,
    ) -> std::io::Result<Self> {
        let fe_metrics = Arc::new(FrontendMetrics::default());
        service.metrics.set_frontend(Arc::clone(&fe_metrics));
        if opts.legacy_threads {
            let legacy = LegacyServer::start(
                Arc::clone(&service),
                addr,
                Arc::clone(&fe_metrics),
            )?;
            Ok(Self {
                addr: legacy.addr,
                service,
                frontend_metrics: fe_metrics,
                inner: Inner::Legacy(legacy),
            })
        } else {
            let frontend = FrontendServer::start(
                VizierHandler { service: Arc::clone(&service) },
                addr,
                FrontendOptions {
                    name: "vizier-fe",
                    workers: opts.workers,
                    drain: opts.drain,
                    idle_timeout: opts.idle_timeout,
                    max_connections: opts.max_connections,
                    poller: opts.poller,
                    metrics: Some(Arc::clone(&fe_metrics)),
                    ..Default::default()
                },
            )?;
            Ok(Self {
                addr: frontend.local_addr(),
                service,
                frontend_metrics: fe_metrics,
                inner: Inner::Pool(frontend),
            })
        }
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn service(&self) -> &Arc<VizierService> {
        &self.service
    }

    /// Front-end metrics: `active_connections` gauge, queue depth,
    /// queue-wait histogram.
    pub fn frontend_metrics(&self) -> &Arc<FrontendMetrics> {
        &self.frontend_metrics
    }

    /// Graceful shutdown: stop accepting, actively close live
    /// connections, drain in-flight requests (with a deadline in pool
    /// mode), join every front-end thread, then stop the service's
    /// policy workers. No `vizier-fe-*` / `vizier-conn` threads survive
    /// this call.
    pub fn shutdown(self) {
        let VizierServer { service, inner, .. } = self;
        // Unpark blocking WaitOperation handlers first: a legacy
        // connection thread sitting in a long-poll would otherwise
        // delay its join by up to the wait timeout.
        service.begin_drain();
        match inner {
            Inner::Pool(frontend) => frontend.shutdown(),
            // LegacyServer closes live connections and joins their
            // threads in Drop.
            Inner::Legacy(legacy) => drop(legacy),
        }
        service.shutdown();
    }
}

/// Pool-mode protocol logic: decode the method byte and dispatch to the
/// service. Stateless per connection. `WaitOperation` is served without
/// blocking: the handler arms an operation watcher and defers the
/// response, so a worker is occupied only for the dispatch itself —
/// thousands of long-polling clients cost parked connections, not
/// threads.
struct VizierHandler {
    service: Arc<VizierService>,
}

impl VizierHandler {
    fn handle_wait(
        &self,
        payload: &[u8],
        out: &mut Vec<u8>,
        cx: &RequestContext<'_>,
    ) -> HandleOutcome {
        let req: WaitOperationRequest = match decode(payload) {
            Ok(req) => req,
            Err(e) => {
                let _ = write_err(out, Status::InvalidArgument, &format!("bad request: {e}"));
                return HandleOutcome::Reply;
            }
        };
        // Snapshot the current state: it answers immediately when the
        // operation is already done, and becomes the timeout frame (a
        // WaitOperation timeout reports the pending state, it is not an
        // error) when the long-poll deadline passes first. This read is
        // deliberately separate from the one inside watch_operation:
        // the timeout frame must exist before defer() so the waiter
        // closure can capture the ResponseHandle, and watch_operation's
        // own read must happen under the registry lock for the
        // race-freedom argument — neither can serve the other.
        let current = match self.service.get_operation(GetOperationRequest {
            name: req.name.clone(),
        }) {
            Ok(resp) => resp.operation,
            Err(e) => {
                self.service.metrics.record_error();
                let _ = write_err(out, e.status, &e.message);
                return HandleOutcome::Reply;
            }
        };
        if current.done {
            let _ = write_ok(out, &OperationResponse { operation: current });
            return HandleOutcome::Reply;
        }
        let deadline =
            Instant::now() + Duration::from_millis(effective_wait_ms(req.timeout_ms));
        let mut timeout_frame = Vec::new();
        let _ = write_ok(&mut timeout_frame, &OperationResponse { operation: current });
        let handle = cx.defer(Some(deadline), timeout_frame);
        let armed = Instant::now();
        let metrics = Arc::clone(&self.service.metrics);
        let waiter: OpWaiter = Box::new(move |op: &OperationProto| {
            let mut frame = Vec::new();
            let _ = write_ok(&mut frame, &OperationResponse { operation: op.clone() });
            // Only a delivered wakeup counts: a waiter whose long-poll
            // chunk already timed out finds a dead ticket and must not
            // skew the latency histogram.
            if handle.complete(frame) {
                metrics.record_wait_wakeup(armed.elapsed().as_micros() as u64);
            }
        });
        match self.service.watch_operation(&req.name, waiter) {
            // Completed in the race window; the unused waiter (and with
            // it the deferred ticket) was dropped by watch_operation.
            Ok(WatchResult::Done(op)) => {
                let _ = write_ok(out, &OperationResponse { operation: op });
                HandleOutcome::Reply
            }
            Ok(WatchResult::Parked(_)) => HandleOutcome::Pending,
            Err(e) => {
                self.service.metrics.record_error();
                let _ = write_err(out, e.status, &e.message);
                HandleOutcome::Reply
            }
        }
    }

    /// Wire-v2 `WaitOperation`: a watch stream. The registration
    /// snapshot goes out as the first `STREAM_ITEM`, every subsequent
    /// state change as another, and the final `done` state is followed
    /// by `STREAM_END` — no re-arm round trips, no `GetOperation`
    /// polling. The stream ignores `timeout_ms`: a v2 client that stops
    /// caring sends `CANCEL` (or drops the connection), which disarms
    /// the watcher through the sink's cancel hook.
    fn handle_wait_mux(&self, payload: &[u8], sink: MuxSink) {
        let req: WaitOperationRequest = match decode(payload) {
            Ok(req) => req,
            Err(e) => {
                sink.error(Status::InvalidArgument, &format!("bad request: {e}"));
                return;
            }
        };
        let sink = Arc::new(sink);
        let armed = Instant::now();
        let metrics = Arc::clone(&self.service.metrics);
        let stream_sink = Arc::clone(&sink);
        // Only a wait that actually parked counts as a wakeup — the
        // registration snapshot of an already-done operation answers
        // synchronously, like the v1 fast path.
        let mut parked = false;
        let cb: OpStream = Box::new(move |op: &OperationProto| {
            stream_sink.stream_item(&OperationResponse {
                operation: op.clone(),
            });
            if op.done {
                stream_sink.stream_end();
                if parked {
                    metrics.record_wait_wakeup(armed.elapsed().as_micros() as u64);
                }
                return false;
            }
            parked = true;
            !stream_sink.canceled()
        });
        match self.service.watch_operation_stream(&req.name, cb) {
            Ok(Some(id)) => {
                // Client CANCEL / connection teardown must disarm the
                // watcher, or slow operations would accumulate dead
                // streams (and leak the watch_streams gauge).
                let service = Arc::clone(&self.service);
                let name = req.name.clone();
                sink.on_cancel(Box::new(move || service.unwatch_stream(&name, id)));
            }
            Ok(None) => {} // the callback already closed the stream
            Err(e) => {
                self.service.metrics.record_error();
                sink.error(e.status, &e.message);
            }
        }
    }
}

impl ConnectionHandler for VizierHandler {
    type Conn = ();

    fn on_connect(&self) {}

    fn handle(
        &self,
        _state: &mut (),
        head: u8,
        payload: &[u8],
        out: &mut Vec<u8>,
        cx: &RequestContext<'_>,
    ) -> HandleOutcome {
        match Method::from_u8(head) {
            Some(Method::WaitOperation) => {
                let sw = Stopwatch::start();
                let outcome = self.handle_wait(payload, out, cx);
                // Records the dispatch cost, not the park time — the
                // whole point is that no thread measures the wait.
                self.service.metrics.record("WaitOperation", sw.elapsed_micros());
                outcome
            }
            Some(method) => {
                let sw = Stopwatch::start();
                let result = dispatch(&self.service, method, payload, out);
                self.service.metrics.record(&format!("{method:?}"), sw.elapsed_micros());
                if result.is_ok() {
                    HandleOutcome::Reply
                } else {
                    HandleOutcome::Close
                }
            }
            None => {
                // Garbage method byte: answer with an error frame and
                // drop only this connection — never the server.
                let _ = write_err(
                    out,
                    Status::InvalidArgument,
                    &format!("unknown method id {head}; closing connection"),
                );
                HandleOutcome::Close
            }
        }
    }

    fn handle_mux(&self, method: u8, payload: &[u8], sink: MuxSink) {
        match Method::from_u8(method) {
            Some(Method::WaitOperation) => {
                let sw = Stopwatch::start();
                self.handle_wait_mux(payload, sink);
                // Records the dispatch cost, not the stream lifetime.
                self.service.metrics.record("WaitOperation", sw.elapsed_micros());
            }
            Some(method) => {
                let sw = Stopwatch::start();
                let frame = dispatch_buf(&self.service, method, payload);
                self.service.metrics.record(&format!("{method:?}"), sw.elapsed_micros());
                sink.respond_v1_frame(&frame);
            }
            None => {
                // On a multiplexed connection a garbage method only
                // fails its own correlation id — the connection (and
                // its other in-flight requests) stays healthy.
                sink.error(Status::InvalidArgument, &format!("unknown method id {method}"));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Legacy thread-per-connection front-end (benchmark baseline)
// ---------------------------------------------------------------------------

struct LegacyServer {
    addr: std::net::SocketAddr,
    /// Kept so the Drop path can `begin_drain` before joining:
    /// connection threads may sit in the blocking `wait_operation`,
    /// which only a drain flag (not a socket shutdown) unparks.
    service: Arc<VizierService>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// Live connections: a socket handle (to force-close on shutdown) and
    /// the serving thread (to join). Finished entries are pruned on the
    /// next accept only — under churn-then-idle traffic, dead entries
    /// (one cloned fd + JoinHandle each) linger until another client
    /// connects or shutdown runs. Acceptable for a benchmark baseline;
    /// the pool front-end reaps connections eagerly and is the mode
    /// production deployments use.
    conns: Arc<Mutex<Vec<(TcpStream, JoinHandle<()>)>>>,
}

impl LegacyServer {
    fn start(
        service: Arc<VizierService>,
        addr: &str,
        metrics: Arc<FrontendMetrics>,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<(TcpStream, JoinHandle<()>)>>> =
            Arc::new(Mutex::new(&classes::LEGACY_CONNS, Vec::new()));
        let stop2 = Arc::clone(&stop);
        let conns2 = Arc::clone(&conns);
        let service_handle = Arc::clone(&service);
        let accept_thread = std::thread::Builder::new()
            .name("vizier-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match stream {
                        Ok(s) => s,
                        // Per-connection transients: try the next one.
                        Err(e)
                            if matches!(
                                e.kind(),
                                std::io::ErrorKind::ConnectionAborted
                                    | std::io::ErrorKind::ConnectionReset
                                    | std::io::ErrorKind::Interrupted
                            ) =>
                        {
                            continue;
                        }
                        // EMFILE etc.: back off instead of busy-spinning
                        // the accept loop until an fd frees (same policy
                        // as the pool front-end's accept path).
                        Err(_) => {
                            std::thread::sleep(Duration::from_millis(10));
                            continue;
                        }
                    };
                    metrics.conn_opened();
                    let svc = Arc::clone(&service);
                    let m = Arc::clone(&metrics);
                    let handle_stream = match stream.try_clone() {
                        Ok(s) => s,
                        Err(_) => {
                            metrics.conn_closed();
                            continue;
                        }
                    };
                    // Connection-per-thread: each worker connection is
                    // long-lived and serves sequential requests.
                    let spawned = std::thread::Builder::new()
                        .name("vizier-conn".into())
                        .spawn(move || {
                            let _ = serve_connection(&svc, stream, &m);
                            m.conn_closed();
                        });
                    match spawned {
                        Ok(handle) => {
                            let mut guard = conns2.lock();
                            // Don't let the registry grow with dead
                            // entries on long-lived servers.
                            guard.retain(|(_, h)| !h.is_finished());
                            guard.push((handle_stream, handle));
                        }
                        Err(_) => metrics.conn_closed(),
                    }
                }
            })?;
        Ok(Self {
            addr: local,
            service: service_handle,
            stop,
            accept_thread: Some(accept_thread),
            conns,
        })
    }

    fn shutdown_inner(&mut self) {
        // Unpark connection threads sitting in the blocking
        // wait_operation — shutting their sockets down below does not
        // interrupt a channel wait, and joining one could otherwise
        // stall for the full long-poll timeout.
        self.service.begin_drain();
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // The historical leak: connection threads used to be orphaned
        // here. Force each blocked read to return by shutting the socket
        // down, then join the thread.
        let conns = std::mem::take(&mut *self.conns.lock());
        for (stream, handle) in conns {
            let _ = stream.shutdown(Shutdown::Both);
            let _ = handle.join();
        }
    }
}

impl Drop for LegacyServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Serve one connection (legacy mode): a blocking loop of request ->
/// dispatch -> response. Queue metrics stay zero here — there is no
/// queue in this model — but the request counter is kept so the
/// front-end report stays truthful in either mode.
fn serve_connection(
    service: &Arc<VizierService>,
    stream: TcpStream,
    fe_metrics: &FrontendMetrics,
) -> Result<(), FrameError> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let (method, payload) = match read_request(&mut reader) {
            Ok(x) => x,
            Err(FrameError::Io(_)) => return Ok(()), // client disconnected
            Err(e) => return Err(e),
        };
        fe_metrics.requests.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let sw = Stopwatch::start();
        let result = dispatch(service, method, &payload, &mut writer);
        service
            .metrics
            .record(&format!("{method:?}"), sw.elapsed_micros());
        result?;
    }
}

/// Resolve a trace span name code to text, substituting RPC method
/// names (which `util::trace` cannot see) for the numeric codes.
pub fn span_label(code: u64) -> String {
    for (base, prefix) in [(trace::RPC_BASE, "rpc"), (trace::CLIENT_RPC_BASE, "client-rpc")] {
        if (base..base + 256).contains(&code) {
            if let Some(m) = Method::from_u8((code - base) as u8) {
                return format!("{prefix}:{m:?}");
            }
        }
    }
    trace::span_name(code)
}

/// Decode, call, encode for a single method.
///
/// Every server-side path funnels through here — the legacy
/// thread-per-connection loop, the pool front-end's v1 and mux jobs
/// (via [`dispatch_buf`]), and the in-process `LocalTransport` — so
/// this is also where the request's trace span lives: it continues the
/// trace carried in the payload's trailer (v2 clients), nests under any
/// ambient context (in-process callers), or starts a fresh sampled
/// root (v1 clients). The worker loop's queue-wait note becomes a
/// retroactive `frontend-queue` child, and requests slower than
/// `--trace-slow-ms` dump their span tree to stderr.
pub fn dispatch<W: Write>(
    service: &Arc<VizierService>,
    method: Method,
    payload: &[u8],
    out: &mut W,
) -> Result<(), FrameError> {
    let span = if trace::enabled() {
        trace::rpc_span(trace::RPC_BASE + method as u8 as u64, extract_trace_context(payload))
    } else {
        None
    };
    macro_rules! call {
        ($fn:ident) => {{
            match decode(payload) {
                Ok(req) => match service.$fn(req) {
                    Ok(resp) => write_ok(out, &resp),
                    Err(e) => {
                        service.metrics.record_error();
                        write_err(out, e.status, &e.message)
                    }
                },
                Err(e) => write_err(out, Status::InvalidArgument, &format!("bad request: {e}")),
            }
        }};
    }
    let result = match method {
        Method::CreateStudy => call!(create_study),
        Method::GetStudy => call!(get_study),
        Method::ListStudies => call!(list_studies),
        Method::DeleteStudy => call!(delete_study),
        Method::LookupStudy => call!(lookup_study),
        Method::SuggestTrials => call!(suggest_trials),
        Method::GetOperation => call!(get_operation),
        Method::AddMeasurement => call!(add_measurement),
        Method::CompleteTrial => call!(complete_trial),
        Method::ListTrials => call!(list_trials),
        Method::GetTrial => call!(get_trial),
        Method::DeleteTrial => call!(delete_trial),
        Method::CheckEarlyStopping => call!(check_early_stopping),
        Method::StopTrial => call!(stop_trial),
        Method::ListOptimalTrials => call!(list_optimal_trials),
        Method::UpdateMetadata => call!(update_metadata),
        // Blocking long-poll: fine for the in-process transport and the
        // legacy thread-per-connection model (one thread per client by
        // construction). The pool front-end intercepts this method in
        // VizierHandler and serves it with a deferred response instead.
        Method::WaitOperation => call!(wait_operation),
        Method::GetServiceMetrics => call!(get_service_metrics),
        Method::GetTraces => call!(get_traces),
        Method::Ping => write_ok(out, &EmptyResponse::default()),
    };
    if let Some(span) = span {
        let rec = span.finish();
        if let Some(threshold) = trace::slow_threshold_us() {
            // GetTraces itself is exempt: a slow trace *fetch* dumping
            // its own tree is noise, not signal.
            if rec.dur_us >= threshold && method != Method::GetTraces {
                let spans = trace::snapshot();
                let rows: Vec<(u64, u64, String, u64, u64)> = spans
                    .iter()
                    .filter(|s| s.trace_id == rec.trace_id)
                    .map(|s| (s.span_id, s.parent_id, span_label(s.name_code), s.start_us, s.dur_us))
                    .collect();
                eprintln!(
                    "trace: slow request {method:?} took {:.1} ms (trace {:016x}):\n{}",
                    rec.dur_us as f64 / 1000.0,
                    rec.trace_id,
                    trace::render_spans(&rows)
                );
            }
        }
    }
    result
}

/// Read side of `dispatch` for in-process transports: handles one raw
/// frame pair over byte buffers.
pub fn dispatch_buf(service: &Arc<VizierService>, method: Method, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let _ = dispatch(service, method, payload, &mut out);
    out
}
