//! Separate-process Pythia deployment (paper Figure 2: "Note that Pythia
//! may run as a separate service from the API service"; §2.1: "OSS
//! Vizier's algorithms may run in a separate service and communicate via
//! RPCs with the API server, which performs database operations").
//!
//! Topology:
//! * The **Pythia server** ([`PythiaServer`]) hosts the policy registry in
//!   its own process. For datastore reads it talks *back* to the API
//!   server through a [`RemoteSupporter`] (ListTrials / GetStudy /
//!   UpdateMetadata RPCs) — the API service remains the only process that
//!   touches the database.
//! * The **API server** is configured with a [`RemotePythia`] endpoint
//!   that forwards suggest/early-stop work to the Pythia server.

use crate::client::transport::{call, TcpTransport, Transport};
use crate::datastore::query::TrialFilter;
use crate::pythia::policy::{
    EarlyStopDecision, EarlyStopRequest, MetadataDelta, PolicyError, SuggestDecision,
    SuggestRequest, SuggestWant, SuggestionGroup,
};
use crate::pythia::runner::{PolicyRegistry, PythiaEndpoint};
use crate::pythia::supporter::PolicySupporter;
use crate::pyvizier::{converters, Metadata, StudyConfig, Trial, TrialSuggestion};
use crate::service::frontend::{
    ConnectionHandler, FrontendOptions, FrontendServer, HandleOutcome, RequestContext,
};
use crate::service::metrics::FrontendMetrics;
use crate::wire::codec::{Reader, WireError, WireMessage, Writer};
use crate::wire::framing::{write_err, write_ok, FrameError, Method, Status};
use crate::wire::messages::*;
use crate::util::sync::{classes, Mutex};
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

// ---------------------------------------------------------------------------
// Pythia wire protocol (rides on the same framing; distinct method ids)
// ---------------------------------------------------------------------------

const M_SUGGEST: u8 = 101;
const M_EARLY_STOP: u8 = 102;

/// One want on the wire: `(client_id, count)`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SuggestWantProto {
    pub client_id: String,
    pub count: u64,
}

impl WireMessage for SuggestWantProto {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.client_id);
        w.u64(2, self.count);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut m = Self::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => m.client_id = v.as_string()?,
                2 => m.count = v.as_u64()?,
                _ => {}
            }
        }
        Ok(m)
    }
}

/// Request the Pythia service to produce suggestions for a batch of
/// coalesced wants (Pythia v2).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PythiaSuggestRequest {
    pub study_name: String,
    pub display_name: String,
    pub spec: StudySpecProto,
    pub wants: Vec<SuggestWantProto>,
}

impl WireMessage for PythiaSuggestRequest {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.study_name);
        w.str(2, &self.display_name);
        w.msg(3, &self.spec);
        w.msgs(4, &self.wants);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut m = Self::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => m.study_name = v.as_string()?,
                2 => m.display_name = v.as_string()?,
                3 => m.spec = v.as_msg()?,
                4 => m.wants.push(v.as_msg()?),
                _ => {}
            }
        }
        Ok(m)
    }
}

/// One want's answer: the suggestions (as bare trials) for `client_id`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SuggestionGroupProto {
    pub client_id: String,
    pub suggestions: Vec<TrialProto>,
}

impl WireMessage for SuggestionGroupProto {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.client_id);
        w.msgs(2, &self.suggestions);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut m = Self::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => m.client_id = v.as_string()?,
                2 => m.suggestions.push(v.as_msg()?),
                _ => {}
            }
        }
        Ok(m)
    }
}

/// Pythia's reply: one group per want + the unified metadata delta
/// (`trial_id == 0` entries target the study table).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PythiaSuggestResponse {
    pub groups: Vec<SuggestionGroupProto>,
    pub metadata_delta: Vec<UnitMetadataUpdate>,
}

impl WireMessage for PythiaSuggestResponse {
    fn encode_fields(&self, w: &mut Writer) {
        w.msgs(1, &self.groups);
        w.msgs(2, &self.metadata_delta);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut m = Self::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => m.groups.push(v.as_msg()?),
                2 => m.metadata_delta.push(v.as_msg()?),
                _ => {}
            }
        }
        Ok(m)
    }
}

#[derive(Debug, Clone, PartialEq, Default)]
pub struct PythiaEarlyStopRequest {
    pub study_name: String,
    pub display_name: String,
    pub spec: StudySpecProto,
    /// Trials to judge. The API service resolves an empty client request
    /// to the ACTIVE set *before* forwarding, so this list is never empty
    /// on the shipped path; a policy receiving an empty list judges
    /// nothing (the default implementation returns no decisions).
    pub trial_ids: Vec<u64>,
}

impl WireMessage for PythiaEarlyStopRequest {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.study_name);
        w.str(2, &self.display_name);
        w.msg(3, &self.spec);
        for id in &self.trial_ids {
            w.u64(4, *id);
        }
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut m = Self::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => m.study_name = v.as_string()?,
                2 => m.display_name = v.as_string()?,
                3 => m.spec = v.as_msg()?,
                4 => m.trial_ids.push(v.as_u64()?),
                _ => {}
            }
        }
        Ok(m)
    }
}

/// Per-trial verdicts (Pythia v2).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PythiaEarlyStopResponse {
    pub decisions: Vec<TrialStopDecision>,
}

impl WireMessage for PythiaEarlyStopResponse {
    fn encode_fields(&self, w: &mut Writer) {
        w.msgs(1, &self.decisions);
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut m = Self::default();
        while let Some((f, v)) = r.next_field()? {
            if f == 1 {
                m.decisions.push(v.as_msg()?);
            }
        }
        Ok(m)
    }
}

// ---------------------------------------------------------------------------
// RemoteSupporter: datastore reads through the API server
// ---------------------------------------------------------------------------

/// Default read timeout for datastore RPCs back to the API server: an
/// API server that vanished mid-read must not stall a policy run (and
/// with it a `pythia-fe` worker) past any reasonable drain deadline
/// (ROADMAP front-end follow-on (d)).
pub const SUPPORTER_READ_TIMEOUT: Duration = Duration::from_secs(30);

/// PolicySupporter backed by API-server RPCs (used inside the Pythia
/// process — it has no datastore of its own).
pub struct RemoteSupporter {
    transport: Mutex<Box<dyn Transport>>,
}

impl RemoteSupporter {
    pub fn connect(api_addr: &str) -> Result<Self, FrameError> {
        Self::connect_with_read_timeout(api_addr, Some(SUPPORTER_READ_TIMEOUT))
    }

    /// Connect with an explicit read timeout (`None` = block forever,
    /// the pre-timeout behaviour).
    pub fn connect_with_read_timeout(
        api_addr: &str,
        read_timeout: Option<Duration>,
    ) -> Result<Self, FrameError> {
        Ok(Self {
            transport: Mutex::new(
                &classes::RP_TRANSPORT,
                Box::new(TcpTransport::connect_with_read_timeout(api_addr, read_timeout)?),
            ),
        })
    }

    fn rpc<Req: WireMessage, Resp: WireMessage>(
        &self,
        method: Method,
        req: &Req,
    ) -> Result<Resp, PolicyError> {
        let mut t = self.transport.lock();
        call(t.as_mut(), method, req).map_err(|e| PolicyError::Datastore(e.to_string()))
    }
}

impl PolicySupporter for RemoteSupporter {
    fn study_config(&self, study_name: &str) -> Result<StudyConfig, PolicyError> {
        let resp: StudyResponse = self.rpc(
            Method::GetStudy,
            &GetStudyRequest {
                name: study_name.to_string(),
            },
        )?;
        Ok(converters::study_config_from_proto(
            &resp.study.display_name,
            &resp.study.spec,
        ))
    }

    fn trials(&self, study_name: &str, filter: &TrialFilter) -> Result<Vec<Trial>, PolicyError> {
        let resp: ListTrialsResponse = self.rpc(
            Method::ListTrials,
            &ListTrialsRequest {
                study_name: study_name.to_string(),
                ..Default::default()
            },
        )?;
        Ok(filter
            .apply(resp.trials)
            .iter()
            .map(converters::trial_from_proto)
            .collect())
    }

    fn list_study_names(&self) -> Result<Vec<String>, PolicyError> {
        let resp: ListStudiesResponse =
            self.rpc(Method::ListStudies, &ListStudiesRequest::default())?;
        Ok(resp.studies.into_iter().map(|s| s.name).collect())
    }

    fn update_study_metadata(&self, study_name: &str, md: &Metadata) -> Result<(), PolicyError> {
        let updates = md
            .iter()
            .map(|(ns, k, v)| UnitMetadataUpdate {
                trial_id: 0,
                new_trial_index: 0,
                item: Some(MetadataItem {
                    namespace: ns.to_string(),
                    key: k.to_string(),
                    value: v.to_vec(),
                }),
            })
            .collect();
        let _: EmptyResponse = self.rpc(
            Method::UpdateMetadata,
            &UpdateMetadataRequest {
                study_name: study_name.to_string(),
                updates,
            },
        )?;
        Ok(())
    }

    fn update_trial_metadata(
        &self,
        study_name: &str,
        trial_id: u64,
        md: &Metadata,
    ) -> Result<(), PolicyError> {
        let updates = md
            .iter()
            .map(|(ns, k, v)| UnitMetadataUpdate {
                trial_id,
                new_trial_index: 0,
                item: Some(MetadataItem {
                    namespace: ns.to_string(),
                    key: k.to_string(),
                    value: v.to_vec(),
                }),
            })
            .collect();
        let _: EmptyResponse = self.rpc(
            Method::UpdateMetadata,
            &UpdateMetadataRequest {
                study_name: study_name.to_string(),
                updates,
            },
        )?;
        Ok(())
    }

    fn trial_count(&self, study_name: &str) -> Result<usize, PolicyError> {
        let resp: ListTrialsResponse = self.rpc(
            Method::ListTrials,
            &ListTrialsRequest {
                study_name: study_name.to_string(),
                ..Default::default()
            },
        )?;
        Ok(resp.trials.len())
    }
}

// ---------------------------------------------------------------------------
// PythiaServer: hosts policies in its own process
// ---------------------------------------------------------------------------

/// The standalone Pythia service, served by the same event-loop +
/// bounded worker-pool front-end as the API server
/// ([`crate::service::frontend`]): a fleet of API servers (or one API
/// server with many in-flight studies) holding idle Pythia connections
/// costs no threads here; policy computations occupy the `pythia-fe-w*`
/// pool only while they run.
pub struct PythiaServer {
    addr: std::net::SocketAddr,
    frontend: FrontendServer,
}

impl PythiaServer {
    /// Start serving policy work on `addr` with a default-sized worker
    /// pool; datastore reads go to `api_addr` (the API server).
    pub fn start(registry: PolicyRegistry, api_addr: &str, addr: &str) -> std::io::Result<Self> {
        Self::start_with(registry, api_addr, addr, 0)
    }

    /// Start with an explicit worker-pool size (0 = CPU count). The
    /// policy compute pool is sized the same way — handler workers only
    /// decode and enqueue; the compute pool runs the policies.
    pub fn start_with(
        registry: PolicyRegistry,
        api_addr: &str,
        addr: &str,
        workers: usize,
    ) -> std::io::Result<Self> {
        let compute_threads = if workers == 0 {
            crate::service::frontend::default_workers()
        } else {
            workers
        };
        let handler = PythiaHandler {
            inner: Arc::new(PythiaShared {
                registry,
                api_addr: api_addr.to_string(),
                supporters: Mutex::new(&classes::RP_SUPPORTERS, Vec::new()),
                compute: crate::util::threadpool::ThreadPool::new(compute_threads.max(1)),
            }),
        };
        let frontend = FrontendServer::start(
            handler,
            addr,
            FrontendOptions {
                name: "pythia-fe",
                workers,
                // Policy runs (GP fits) are slow; give in-flight work a
                // generous drain window on shutdown.
                drain: Duration::from_secs(10),
                ..Default::default()
            },
        )?;
        Ok(Self { addr: frontend.local_addr(), frontend })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Front-end metrics (`active_connections` gauge, queue depth/wait).
    pub fn frontend_metrics(&self) -> &Arc<FrontendMetrics> {
        self.frontend.metrics()
    }

    /// Graceful shutdown: close idle connections, drain in-flight policy
    /// work (bounded), join the pool. No `pythia-fe-*` threads survive.
    pub fn shutdown(self) {
        self.frontend.shutdown();
    }
}

/// Pool-mode protocol logic for the Pythia wire protocol. A handler
/// worker only decodes the frame and enqueues the policy computation on
/// the shared compute pool — the response is completed from there via
/// the deferred-response machinery (v1) or the mux sink (v2), so policy
/// compute never blocks a `pythia-fe-w*` thread (ROADMAP Pythia v2
/// follow-on; the same `HandleOutcome::Pending` path the API server
/// uses for `WaitOperation`).
struct PythiaHandler {
    inner: Arc<PythiaShared>,
}

/// State shared with the compute pool: the policy registry plus a pool
/// of API-server connections. A supporter is popped (or dialed — from a
/// compute thread, never the event loop) for the duration of one policy
/// run and pushed back afterwards, so concurrent runs never serialize on
/// one API connection.
struct PythiaShared {
    registry: PolicyRegistry,
    api_addr: String,
    supporters: Mutex<Vec<RemoteSupporter>>,
    compute: crate::util::threadpool::ThreadPool,
}

impl PythiaShared {
    /// Run one policy computation and return the v1 response frame.
    fn run(&self, method: u8, payload: &[u8]) -> Vec<u8> {
        // Continue the API server's trace (carried as a payload trailer
        // by `RemotePythia::roundtrip`) across the process boundary;
        // supporter datastore reads made during the run nest under this
        // span via their own client transport.
        let _span = if crate::util::trace::enabled() {
            crate::wire::messages::extract_trace_context(payload).and_then(|ctx| {
                crate::util::trace::root_span_in(ctx, crate::util::trace::PYTHIA_SERVE)
            })
        } else {
            None
        };
        let mut out = Vec::new();
        let supporter = match self.supporters.lock().pop() {
            Some(s) => Ok(s),
            None => RemoteSupporter::connect(&self.api_addr),
        };
        match supporter {
            Ok(sup) => {
                let _ = if method == M_SUGGEST {
                    handle_suggest(&self.registry, &sup, payload, &mut out)
                } else {
                    handle_early_stop(&self.registry, &sup, payload, &mut out)
                };
                self.supporters.lock().push(sup);
            }
            Err(e) => {
                let _ = write_err(&mut out, Status::Internal, &format!("api server connect: {e}"));
            }
        }
        if out.is_empty() {
            let _ = write_err(&mut out, Status::Internal, "policy handler produced no frame");
        }
        out
    }
}

impl PythiaHandler {
    /// Enqueue one policy run on the compute pool; `complete` receives
    /// the finished v1 response frame on a compute thread.
    fn spawn_policy(
        &self,
        method: u8,
        payload: Vec<u8>,
        complete: impl FnOnce(Vec<u8>) + Send + 'static,
    ) {
        let shared = Arc::clone(&self.inner);
        self.inner.compute.execute(move || {
            let frame = shared.run(method, &payload);
            complete(frame);
        });
    }
}

impl ConnectionHandler for PythiaHandler {
    type Conn = ();

    fn on_connect(&self) {}

    fn handle(
        &self,
        _state: &mut (),
        head: u8,
        payload: &[u8],
        out: &mut Vec<u8>,
        cx: &RequestContext<'_>,
    ) -> HandleOutcome {
        match head {
            M_SUGGEST | M_EARLY_STOP => {
                // No deadline: a policy run is bounded by the supporter
                // read timeouts, and an aborted ticket (connection gone)
                // makes the completion a no-op.
                let handle = cx.defer(None, Vec::new());
                self.spawn_policy(head, payload.to_vec(), move |frame| {
                    let _ = handle.complete(frame);
                });
                HandleOutcome::Pending
            }
            other => {
                let _ = write_err(out, Status::Unimplemented, &format!("method {other}"));
                HandleOutcome::Reply
            }
        }
    }

    fn handle_mux(&self, method: u8, payload: &[u8], sink: crate::service::frontend::MuxSink) {
        match method {
            M_SUGGEST | M_EARLY_STOP => {
                self.spawn_policy(method, payload.to_vec(), move |frame| {
                    sink.respond_v1_frame(&frame);
                });
            }
            other => sink.error(Status::Unimplemented, &format!("method {other}")),
        }
    }
}

fn handle_suggest(
    registry: &PolicyRegistry,
    supporter: &RemoteSupporter,
    payload: &[u8],
    out: &mut Vec<u8>,
) -> Result<(), FrameError> {
    let result: Result<PythiaSuggestResponse, String> = (|| {
        let req: PythiaSuggestRequest =
            crate::wire::codec::decode(payload).map_err(|e| e.to_string())?;
        let config = converters::study_config_from_proto(&req.display_name, &req.spec);
        let mut policy = registry.create(&config).map_err(|e| e.to_string())?;
        let decision = policy
            .suggest(
                &SuggestRequest {
                    study_name: req.study_name,
                    study_config: config,
                    wants: req
                        .wants
                        .into_iter()
                        .map(|w| SuggestWant {
                            client_id: w.client_id,
                            count: w.count as usize,
                        })
                        .collect(),
                },
                supporter,
            )
            .map_err(|e| e.to_string())?;
        Ok(PythiaSuggestResponse {
            groups: decision
                .groups
                .iter()
                .map(|g| SuggestionGroupProto {
                    client_id: g.client_id.clone(),
                    suggestions: g.suggestions.iter().map(suggestion_to_proto).collect(),
                })
                .collect(),
            metadata_delta: decision.metadata_delta.to_updates(),
        })
    })();
    match result {
        Ok(resp) => write_ok(out, &resp),
        Err(e) => write_err(out, Status::Internal, &e),
    }
}

fn handle_early_stop(
    registry: &PolicyRegistry,
    supporter: &RemoteSupporter,
    payload: &[u8],
    out: &mut Vec<u8>,
) -> Result<(), FrameError> {
    let result: Result<PythiaEarlyStopResponse, String> = (|| {
        let req: PythiaEarlyStopRequest =
            crate::wire::codec::decode(payload).map_err(|e| e.to_string())?;
        let config = converters::study_config_from_proto(&req.display_name, &req.spec);
        let mut policy = registry.create(&config).map_err(|e| e.to_string())?;
        let decisions = policy
            .early_stop(
                &EarlyStopRequest {
                    study_name: req.study_name,
                    study_config: config,
                    trial_ids: req.trial_ids,
                },
                supporter,
            )
            .map_err(|e| e.to_string())?;
        Ok(PythiaEarlyStopResponse {
            decisions: decisions.into_iter().map(TrialStopDecision::from).collect(),
        })
    })();
    match result {
        Ok(resp) => write_ok(out, &resp),
        Err(e) => write_err(out, Status::Internal, &e),
    }
}

fn suggestion_to_proto(s: &TrialSuggestion) -> TrialProto {
    TrialProto {
        parameters: s
            .parameters
            .iter()
            .map(|(k, v)| TrialParameter {
                parameter_id: k.clone(),
                value: converters::value_to_proto(v),
            })
            .collect(),
        metadata: converters::metadata_to_proto(&s.metadata),
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// RemotePythia: the API server's endpoint that forwards to PythiaServer
// ---------------------------------------------------------------------------

/// Default read timeout for policy RPCs to the Pythia server: generous
/// enough for a slow GP fit, but bounded — a Pythia process that
/// vanished mid-run must not pin an API-server policy job forever
/// (ROADMAP front-end follow-on (d)). Override with
/// [`RemotePythia::with_read_timeout`] for policies that legitimately
/// run longer.
pub const PYTHIA_READ_TIMEOUT: Duration = Duration::from_secs(120);

/// PythiaEndpoint that forwards operations to a remote Pythia server.
pub struct RemotePythia {
    addr: String,
    read_timeout: Option<Duration>,
    conn: Mutex<Option<(BufReader<TcpStream>, BufWriter<TcpStream>)>>,
}

impl RemotePythia {
    pub fn new(pythia_addr: &str) -> Self {
        Self {
            addr: pythia_addr.to_string(),
            read_timeout: Some(PYTHIA_READ_TIMEOUT),
            conn: Mutex::new(&classes::RP_CONN, None),
        }
    }

    /// Override the per-RPC read timeout (`None` = block forever).
    pub fn with_read_timeout(mut self, read_timeout: Option<Duration>) -> Self {
        self.read_timeout = read_timeout;
        self
    }

    fn roundtrip<Req: WireMessage, Resp: WireMessage>(
        &self,
        method_id: u8,
        req: &Req,
    ) -> Result<Resp, PolicyError> {
        let io_err = |e: std::io::Error| PolicyError::Internal(format!("pythia rpc io: {e}"));
        let mut guard = self.conn.lock();
        for attempt in 0..2 {
            if guard.is_none() {
                let stream = TcpStream::connect(&self.addr).map_err(io_err)?;
                stream.set_nodelay(true).ok();
                stream.set_read_timeout(self.read_timeout).map_err(io_err)?;
                let r = BufReader::new(stream.try_clone().map_err(io_err)?);
                *guard = Some((r, BufWriter::new(stream)));
            }
            let Some((reader, writer)) = guard.as_mut() else {
                return Err(PolicyError::Internal("pythia connection unavailable".into()));
            };
            let result = (|| -> Result<Resp, FrameError> {
                let mut payload = crate::wire::codec::encode(req);
                // One hop span per attempt (a retry is a second hop);
                // the remote Pythia server parents its serve span under
                // this one via the trailer.
                let hop = crate::util::trace::child_span(crate::util::trace::PYTHIA_HOP);
                if let Some(span) = &hop {
                    crate::wire::messages::append_trace_context(&mut payload, span.ctx());
                }
                let total = (1 + payload.len()) as u32;
                use std::io::Write;
                writer.write_all(&total.to_le_bytes())?;
                writer.write_all(&[method_id])?;
                writer.write_all(&payload)?;
                writer.flush()?;
                crate::wire::framing::read_response(reader)
            })();
            match result {
                Ok(resp) => return Ok(resp),
                // A read *timeout* must not retry: the request was
                // delivered and resending would run the policy twice.
                // Drop the connection (a late response would desync the
                // stream) and fail the job instead.
                Err(FrameError::Io(e))
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    *guard = None;
                    return Err(PolicyError::Internal(format!(
                        "pythia rpc timed out after {:?}: {e}",
                        self.read_timeout
                    )));
                }
                Err(FrameError::Io(_)) if attempt == 0 => {
                    *guard = None;
                    continue;
                }
                Err(FrameError::Rpc { message, .. }) => {
                    return Err(PolicyError::Internal(message))
                }
                Err(e) => return Err(PolicyError::Internal(e.to_string())),
            }
        }
        unreachable!()
    }
}

impl PythiaEndpoint for RemotePythia {
    fn run_suggest(&self, req: &SuggestRequest) -> Result<SuggestDecision, PolicyError> {
        let wire_req = PythiaSuggestRequest {
            study_name: req.study_name.clone(),
            display_name: req.study_config.display_name.clone(),
            spec: converters::study_config_to_proto(&req.study_config),
            wants: req
                .wants
                .iter()
                .map(|w| SuggestWantProto {
                    client_id: w.client_id.clone(),
                    count: w.count as u64,
                })
                .collect(),
        };
        let resp: PythiaSuggestResponse = self.roundtrip(M_SUGGEST, &wire_req)?;
        Ok(SuggestDecision {
            groups: resp
                .groups
                .into_iter()
                .map(|g| SuggestionGroup {
                    client_id: g.client_id,
                    suggestions: g
                        .suggestions
                        .iter()
                        .map(|t| {
                            let trial = converters::trial_from_proto(t);
                            TrialSuggestion {
                                parameters: trial.parameters,
                                metadata: trial.metadata,
                            }
                        })
                        .collect(),
                })
                .collect(),
            metadata_delta: MetadataDelta::from_updates(&resp.metadata_delta),
        })
    }

    fn run_early_stop(
        &self,
        req: &EarlyStopRequest,
    ) -> Result<Vec<EarlyStopDecision>, PolicyError> {
        let wire_req = PythiaEarlyStopRequest {
            study_name: req.study_name.clone(),
            display_name: req.study_config.display_name.clone(),
            spec: converters::study_config_to_proto(&req.study_config),
            trial_ids: req.trial_ids.clone(),
        };
        let resp: PythiaEarlyStopResponse = self.roundtrip(M_EARLY_STOP, &wire_req)?;
        Ok(resp.decisions.into_iter().map(EarlyStopDecision::from).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::codec::{decode, encode};

    #[test]
    fn pythia_messages_roundtrip() {
        let req = PythiaSuggestRequest {
            study_name: "studies/1".into(),
            display_name: "exp".into(),
            spec: StudySpecProto {
                algorithm: "RANDOM_SEARCH".into(),
                ..Default::default()
            },
            wants: vec![
                SuggestWantProto {
                    client_id: "w0".into(),
                    count: 3,
                },
                SuggestWantProto {
                    client_id: "w1".into(),
                    count: 1,
                },
            ],
        };
        let back: PythiaSuggestRequest = decode(&encode(&req)).unwrap();
        assert_eq!(back, req);

        let resp = PythiaSuggestResponse {
            groups: vec![
                SuggestionGroupProto {
                    client_id: "w0".into(),
                    suggestions: vec![TrialProto::default(), TrialProto::default()],
                },
                SuggestionGroupProto {
                    client_id: "w1".into(),
                    suggestions: vec![TrialProto::default()],
                },
            ],
            metadata_delta: vec![
                UnitMetadataUpdate {
                    trial_id: 0,
                    new_trial_index: 0,
                    item: Some(MetadataItem {
                        namespace: "d".into(),
                        key: "k".into(),
                        value: vec![1],
                    }),
                },
                UnitMetadataUpdate {
                    trial_id: 5,
                    new_trial_index: 0,
                    item: Some(MetadataItem {
                        namespace: "d".into(),
                        key: "t".into(),
                        value: vec![2],
                    }),
                },
            ],
        };
        let back: PythiaSuggestResponse = decode(&encode(&resp)).unwrap();
        assert_eq!(back, resp);

        let es = PythiaEarlyStopRequest {
            study_name: "s".into(),
            display_name: "d".into(),
            spec: StudySpecProto::default(),
            trial_ids: vec![7, 9],
        };
        let back: PythiaEarlyStopRequest = decode(&encode(&es)).unwrap();
        assert_eq!(back, es);

        let esr = PythiaEarlyStopResponse {
            decisions: vec![TrialStopDecision {
                trial_id: 7,
                should_stop: true,
                reason: "plateau".into(),
            }],
        };
        let back: PythiaEarlyStopResponse = decode(&encode(&esr)).unwrap();
        assert_eq!(back, esr);
    }
}
