//! The distributed OSS Vizier service (paper §3): API server, durable
//! long-running operations, TCP front-end, remote Pythia deployment, and
//! service metrics.

pub mod api;
pub mod metrics;
pub mod remote_pythia;
pub mod server;

pub use api::{ApiError, VizierService};
pub use server::VizierServer;

use crate::datastore::Datastore;
use crate::pythia::runner::{default_registry, LocalPythia, PolicyRegistry};
use crate::pythia::supporter::DatastoreSupporter;
use std::sync::Arc;

/// Build a standard service: datastore + in-process Pythia with the
/// built-in policy registry (+ any extra registrations).
pub fn build_service(
    ds: Arc<dyn Datastore>,
    extra_policies: impl FnOnce(&mut PolicyRegistry),
    workers: usize,
) -> Arc<VizierService> {
    let mut registry = default_registry();
    extra_policies(&mut registry);
    let supporter = Arc::new(DatastoreSupporter::new(Arc::clone(&ds)));
    let pythia = Arc::new(LocalPythia::new(registry, supporter));
    VizierService::new(ds, pythia, workers)
}

/// In-memory service for tests/benchmarks/local studies.
pub fn in_memory_service(workers: usize) -> Arc<VizierService> {
    build_service(
        Arc::new(crate::datastore::memory::InMemoryDatastore::new()),
        |_| {},
        workers,
    )
}
