//! The distributed OSS Vizier service (paper §3): API server, durable
//! long-running operations, TCP front-end, remote Pythia deployment, and
//! service metrics.
//!
//! Every lock in this layer is registered with the crate-wide hierarchy
//! in [`crate::util::sync::classes`] and checked under lockdep; the
//! hierarchy table, the poller registration-state rules, and the WAL
//! ordering this layer depends on are consolidated in
//! `rust/docs/INVARIANTS.md`. The wire protocols the front-end speaks —
//! blocking v1 and the multiplexed/streaming v2 (`HELLO` handshake,
//! correlation-id demux, `WaitOperation` watch streams, `CANCEL`) — are
//! specified in `rust/docs/WIRE.md`.
//!
//! # Front-end architecture: event loop + bounded worker pool
//!
//! The paper's reference server multiplexes thousands of tuning workers
//! behind `grpc.server(ThreadPoolExecutor(max_workers=100))` (Code Block
//! 4). Both TCP front-ends here — [`VizierServer`] (API service) and
//! [`remote_pythia::PythiaServer`] (standalone policy service) — share
//! that shape via [`frontend::FrontendServer`]:
//!
//! * A single **event-loop thread** (`vizier-fe-io` / `pythia-fe-io`)
//!   blocks in a [`crate::util::netpoll::Poller`] (raw POSIX, no crate
//!   dependencies) over the listener, a wake pipe, and every idle
//!   connection. The default backend is `epoll(7)` with **incremental
//!   registration**: fds are added/modified/removed only on connection
//!   state changes (accept, worker hand-off, re-park, close), so a
//!   wakeup costs O(ready fds), not O(total connections). The original
//!   rebuilt-every-iteration `poll(2)` set survives behind
//!   `--poller=poll` as the C-FRONTEND-EPOLL benchmark baseline. The
//!   loop upholds one **registration-state invariant**: an fd is
//!   registered with the poller exactly while the loop owns it — it is
//!   deregistered *before* being handed to a worker or closed, and
//!   registered again when ownership returns (see
//!   [`crate::util::netpoll`] for the full invariant list). Idle
//!   clients — the dominant state of a Vizier worker fleet, which
//!   spends its time evaluating trials, not talking — cost zero
//!   threads. Partial frames accumulate per connection in a resumable
//!   [`crate::wire::framing::FrameReader`], so slow or malicious
//!   clients park in the loop instead of pinning a worker.
//! * **N worker threads** (`vizier-fe-w<i>`, `--workers`, default = CPU
//!   count) execute complete framed requests from a bounded queue and
//!   write the response. One frame = one job; a connection is owned by
//!   one thread at a time, keeping per-connection requests sequential.
//! * **Graceful shutdown** closes idle connections, drains queued and
//!   in-flight requests up to a deadline, and joins every front-end
//!   thread — the pre-pool server leaked its per-connection threads.
//!
//! The legacy thread-per-connection model survives behind
//! `--legacy-threads` ([`server::ServerOptions`]) as the benchmark
//! baseline; `benches/bench_frontend.rs` (C-FRONTEND) drives 1000+
//! mostly-idle connections against both and asserts the pool holds its
//! `workers + 2` thread budget at no loss of hot-path throughput. Its
//! C-FRONTEND-EPOLL section parks a 5000+ connection fleet against both
//! poller backends and pins the per-wakeup scan cost: `poll(2)` must
//! pay O(fleet), epoll must stay O(ready).
//! [`metrics::FrontendMetrics`] exposes the `active_connections` gauge,
//! queue depth, and queue-wait histogram for either mode.
//!
//! # Operation lifecycle: the completion-driven async core
//!
//! The paper's central reliability mechanism is the durable long-running
//! operation (§3.2). End to end, one suggest operation moves through a
//! small state machine with **no thread ever blocked on another layer's
//! progress**:
//!
//! ```text
//!              SuggestTrials RPC
//!                     |
//!                     v            persisted first (durability), then
//!   [PENDING] --- created in ds ---+--> study queue  [QUEUED]
//!                                          |
//!                 batch runner claims the whole queue (one GP fit
//!                 serves K queued operations — Pythia v2 coalescing)
//!                                          |
//!                                          v
//!                                      [CLAIMED] --- policy runs
//!                                          |
//!           decision + metadata delta persisted, trials registered
//!                                          |
//!                                          v
//!        [DONE] --- complete_operation: update ds, drop in-flight
//!                   gauge, fire OpWaiters watchers
//! ```
//!
//! * **Dispatch never blocks.** `suggest_trials` returns after the
//!   `[PENDING]`->`[QUEUED]` step; the front-end worker that carried the
//!   RPC is free immediately. The policy pool (`--policy-workers`)
//!   bounds concurrent *policy executions*, not accepted operations —
//!   one process holds arbitrarily many `[QUEUED]` operations.
//! * **Completion is push, not poll.** `WaitOperation` long-polls
//!   server-side: the pool front-end defers the response
//!   ([`frontend::HandleOutcome::Pending`]), parks the connection, and
//!   the `complete_operation` watcher wakeup re-queues it through the
//!   event loop's self-pipe — one round-trip per completion instead of
//!   a `GetOperation` busy-poll stream. Clients fall back to polling
//!   with capped backoff on servers that predate the RPC.
//! * **Crash-resume re-arms the same path.** After a restart,
//!   `resume_pending_operations` pushes interrupted operations back to
//!   `[QUEUED]`; they complete through `complete_operation` like live
//!   ones, so a client re-attaching with `WaitOperation` wakes exactly
//!   as if the crash had not happened.
//! * **Writes park too.** A response that hits `WouldBlock` (slow
//!   reader, including a large `ListTrials` page) parks back in the
//!   event loop for *writability* instead of pinning a worker in a
//!   write loop. `parked_responses` gauges both forms of parking.
//!
//! `benches/bench_async_dispatch.rs` (C-ASYNC-DISPATCH) holds `> 3x`
//! the policy-worker count of in-flight suggest operations on one
//! server, with every waiting client parked and the front-end at its
//! `workers + 2` thread budget, then asserts each client completes in a
//! single `WaitOperation` round-trip with zero `GetOperation` traffic.

pub mod api;
pub mod frontend;
pub mod metrics;
pub mod remote_pythia;
pub mod server;

pub use api::{ApiError, VizierService};
pub use server::{ServerOptions, VizierServer};

use crate::datastore::Datastore;
use crate::pythia::runner::{default_registry, LocalPythia, PolicyRegistry};
use crate::pythia::supporter::DatastoreSupporter;
use std::sync::Arc;

/// Build a standard service: datastore + in-process Pythia with the
/// built-in policy registry (+ any extra registrations).
pub fn build_service(
    ds: Arc<dyn Datastore>,
    extra_policies: impl FnOnce(&mut PolicyRegistry),
    workers: usize,
) -> Arc<VizierService> {
    let mut registry = default_registry();
    extra_policies(&mut registry);
    let supporter = Arc::new(DatastoreSupporter::new(Arc::clone(&ds)));
    let pythia = Arc::new(LocalPythia::new(registry, supporter));
    VizierService::new(ds, pythia, workers)
}

/// In-memory service for tests/benchmarks/local studies.
pub fn in_memory_service(workers: usize) -> Arc<VizierService> {
    build_service(
        Arc::new(crate::datastore::memory::InMemoryDatastore::new()),
        |_| {},
        workers,
    )
}
