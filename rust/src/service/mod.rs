//! The distributed OSS Vizier service (paper §3): API server, durable
//! long-running operations, TCP front-end, remote Pythia deployment, and
//! service metrics.
//!
//! The end-to-end picture — how a request moves accept → frame → queue
//! → coalesce → policy → WAL commit → completion, and how the modules
//! compose — lives in `rust/docs/ARCHITECTURE.md`; this module doc only
//! states the two contracts everything in this layer is built on.
//!
//! **Front end** ([`frontend::FrontendServer`], shared by
//! [`VizierServer`] and [`remote_pythia::PythiaServer`]): one event-loop
//! thread owns every idle connection through a
//! [`crate::util::netpoll::Poller`]; `--workers` worker threads execute
//! complete framed requests from a bounded queue. Idle clients — the
//! dominant state of a tuning fleet — cost zero threads, and slow
//! readers/writers park in the loop instead of pinning a worker. The
//! thread-per-connection baseline survives behind `--legacy-threads`
//! and is held to account by `benches/bench_frontend.rs` (C-FRONTEND,
//! C-FRONTEND-EPOLL).
//!
//! **Async operation core** (§3.2): `suggest_trials` persists the
//! operation, queues it per-study, and returns — the policy pool
//! (`--policy-workers`) bounds concurrent policy *executions*, not
//! accepted operations, and one coalesced policy run serves every
//! operation queued on the study. Completion is push, not poll:
//! `WaitOperation` parks the connection (v1) or a watch stream (v2)
//! until `complete_operation` fires the watcher; crash-resume re-queues
//! interrupted operations through the same path.
//! `benches/bench_async_dispatch.rs` (C-ASYNC-DISPATCH) pins both
//! properties.
//!
//! Every lock in this layer is registered with the crate-wide hierarchy
//! in [`crate::util::sync::classes`] and checked under lockdep; the
//! hierarchy table, the poller registration-state rules, and the WAL
//! ordering this layer depends on are consolidated in
//! `rust/docs/INVARIANTS.md`. The wire protocols the front-end speaks —
//! blocking v1 and the multiplexed/streaming v2 — are specified in
//! `rust/docs/WIRE.md`; the operator-facing knobs and the full metrics
//! catalog are in `rust/docs/OPERATIONS.md`.

pub mod api;
pub mod frontend;
pub mod metrics;
pub mod remote_pythia;
pub mod server;

pub use api::{ApiError, VizierService};
pub use server::{ServerOptions, VizierServer};

use crate::datastore::Datastore;
use crate::pythia::runner::{default_registry, LocalPythia, PolicyRegistry};
use crate::pythia::supporter::DatastoreSupporter;
use std::sync::Arc;

/// Build a standard service: datastore + in-process Pythia with the
/// built-in policy registry (+ any extra registrations).
pub fn build_service(
    ds: Arc<dyn Datastore>,
    extra_policies: impl FnOnce(&mut PolicyRegistry),
    workers: usize,
) -> Arc<VizierService> {
    let mut registry = default_registry();
    extra_policies(&mut registry);
    let supporter = Arc::new(DatastoreSupporter::new(Arc::clone(&ds)));
    let pythia = Arc::new(LocalPythia::new(registry, supporter));
    VizierService::new(ds, pythia, workers)
}

/// In-memory service for tests/benchmarks/local studies.
pub fn in_memory_service(workers: usize) -> Arc<VizierService> {
    build_service(
        Arc::new(crate::datastore::memory::InMemoryDatastore::new()),
        |_| {},
        workers,
    )
}
