//! Client transports: TCP (distributed, the normal deployment) and
//! in-process ("the server may be launched in the same local process as
//! the client, in cases where distributed computing is not needed and
//! function evaluation is cheap" — paper §3.2).
//!
//! The TCP transport speaks both wire protocols (see `docs/WIRE.md`): a
//! one-round `HELLO` probe on the first connection selects v2 when the
//! server supports it — all calls then multiplex over one shared
//! connection, demultiplexed by correlation id — and latches v1 forever
//! when the peer answers with a v1 status byte or hangs up. Old servers
//! never see a second HELLO.

use crate::service::api::VizierService;
use crate::service::server::dispatch_buf;
use crate::util::sync::{classes, Mutex};
use crate::wire::codec::{decode, encode, WireMessage};
use crate::wire::framing::{
    encode_v2, is_v2_head, parse_v2, read_frame, read_response, write_request, write_v2,
    FrameError, FrameKind, Method, Status, WIRE_VERSION_MAX,
};
use crate::wire::messages::HelloProto;
use std::collections::HashMap;
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// A bidirectional request/response channel to a Vizier service.
pub trait Transport: Send {
    fn call_raw(&mut self, method: Method, request: &[u8]) -> Result<Vec<u8>, FrameError>;

    /// Open a server-push stream for `method` (wire v2 only). `Ok(None)`
    /// means this transport — or the protocol it negotiated — cannot
    /// stream, and the caller must fall back to unary calls.
    /// Implementations without streaming inherit this default.
    fn call_stream(
        &mut self,
        method: Method,
        request: &[u8],
    ) -> Result<Option<ServerStream>, FrameError> {
        let _ = (method, request);
        Ok(None)
    }
}

/// Typed call helper shared by all transports.
pub fn call<T: Transport + ?Sized, Req: WireMessage, Resp: WireMessage>(
    t: &mut T,
    method: Method,
    req: &Req,
) -> Result<Resp, FrameError> {
    let raw = t.call_raw(method, &encode(req))?;
    let mut cursor = std::io::Cursor::new(raw);
    read_response(&mut cursor)
}

/// `OSSVIZIER_WIRE=v1` forces the legacy protocol (the CI matrix leg and
/// an emergency escape hatch). Any other value — including `v2`, the
/// default — lets the `HELLO` probe negotiate.
fn wire_v2_disabled() -> bool {
    std::env::var("OSSVIZIER_WIRE").map(|v| v == "v1").unwrap_or(false)
}

/// Negotiated protocol state of one [`TcpTransport`].
enum Wire {
    /// Not yet negotiated: the first call probes with `HELLO`.
    Unprobed,
    /// v1 peer, latched for the life of this transport (the probe is
    /// never repeated against an endpoint that answered it with v1).
    V1,
    /// v2 negotiated: every call multiplexes over this shared connection.
    V2(Arc<MuxClient>),
}

/// TCP transport with automatic reconnect on broken connections.
pub struct TcpTransport {
    addr: String,
    conn: Option<(BufReader<TcpStream>, BufWriter<TcpStream>)>,
    wire: Wire,
    pub connect_timeout: Duration,
    /// Per-response read timeout (`None` = block forever, the default —
    /// user clients legitimately wait on long evaluations). A timed-out
    /// call fails *without* the resend retry: the request was already
    /// delivered and replaying a non-idempotent RPC (CompleteTrial)
    /// would be worse than the error. Over v2 the same timeout bounds
    /// the wait for each call's terminal frame; on expiry the client
    /// sends `CANCEL` and abandons the correlation id.
    pub read_timeout: Option<Duration>,
}

impl TcpTransport {
    pub fn connect(addr: &str) -> Result<Self, FrameError> {
        Self::connect_with_read_timeout(addr, None)
    }

    /// Connect with a bound on how long one RPC may wait for its
    /// response (used by `RemoteSupporter` so a vanished API server
    /// cannot stall a policy run indefinitely).
    pub fn connect_with_read_timeout(
        addr: &str,
        read_timeout: Option<Duration>,
    ) -> Result<Self, FrameError> {
        let mut t = Self {
            addr: addr.to_string(),
            conn: None,
            wire: Wire::Unprobed,
            connect_timeout: Duration::from_secs(5),
            read_timeout,
        };
        t.ensure_wire()?;
        if matches!(t.wire, Wire::V1) {
            t.ensure_connected()?;
        }
        Ok(t)
    }

    /// The negotiated wire version: 2 after a successful `HELLO`
    /// handshake, 1 on a latched v1 peer, 0 before the first probe.
    pub fn wire_version(&self) -> u64 {
        match self.wire {
            Wire::Unprobed => 0,
            Wire::V1 => 1,
            Wire::V2(_) => 2,
        }
    }

    /// Pin this transport to the legacy v1 protocol. Equivalent to
    /// `OSSVIZIER_WIRE=v1` but scoped to one transport — tests use it to
    /// cover the v1 path without mutating process-global environment.
    pub fn force_v1(&mut self) {
        self.conn = None;
        self.wire = Wire::V1;
    }

    /// A second handle over the same multiplexed connection (wire v2
    /// only): both transports then issue RPCs concurrently over one
    /// socket, demultiplexed by correlation id. `None` on a v1 peer or
    /// before the first call negotiated a protocol.
    pub fn try_share(&self) -> Option<TcpTransport> {
        match &self.wire {
            Wire::V2(client) => Some(TcpTransport {
                addr: self.addr.clone(),
                conn: None,
                wire: Wire::V2(Arc::clone(client)),
                connect_timeout: self.connect_timeout,
                read_timeout: self.read_timeout,
            }),
            _ => None,
        }
    }

    fn dial(&self) -> Result<TcpStream, FrameError> {
        let sock_addr: std::net::SocketAddr = self
            .addr
            .parse()
            .map_err(|_| FrameError::Io(std::io::Error::other(format!("bad addr {}", self.addr))))?;
        let stream = TcpStream::connect_timeout(&sock_addr, self.connect_timeout)?;
        stream.set_nodelay(true).ok();
        Ok(stream)
    }

    /// Make sure a protocol has been negotiated. A dead v2 connection
    /// (server restart) resets to `Unprobed` so the next connection
    /// renegotiates — the replacement server may be older or newer.
    fn ensure_wire(&mut self) -> Result<(), FrameError> {
        if let Wire::V2(client) = &self.wire {
            if client.is_dead() {
                self.wire = Wire::Unprobed;
            } else {
                return Ok(());
            }
        }
        if matches!(self.wire, Wire::V1) {
            return Ok(());
        }
        self.probe()
    }

    /// One-round version handshake on a fresh connection. Every outcome
    /// other than a v2 `HELLO` echo — a v1 error status byte, EOF,
    /// garbage, or a handshake timeout — latches v1: the probe
    /// connection is spent either way (a v1 server answered it with an
    /// error and closed), so the v1 path reconnects fresh and this
    /// transport never sends `HELLO` to that endpoint again.
    fn probe(&mut self) -> Result<(), FrameError> {
        if wire_v2_disabled() {
            self.wire = Wire::V1;
            return Ok(());
        }
        let stream = self.dial()?;
        // Bound the handshake: a peer that accepts the connection but
        // never answers should degrade, not hang the first call.
        stream.set_read_timeout(Some(self.connect_timeout))?;
        let hello = HelloProto { version: WIRE_VERSION_MAX, max_inflight: 0 };
        if write_v2(&mut &stream, FrameKind::Hello, 0, &encode(&hello)).is_err() {
            // Could not even send: fall back and let `ensure_connected`
            // surface the real connection problem on the v1 path.
            self.wire = Wire::V1;
            return Ok(());
        }
        match read_frame(&mut &stream) {
            Ok((head, payload)) if is_v2_head(head) => {
                let negotiated = parse_v2(head, payload)
                    .ok()
                    .filter(|f| f.kind == FrameKind::Hello)
                    .and_then(|f| decode::<HelloProto>(&f.body).ok())
                    .map_or(0, |h| h.version);
                if negotiated >= 2 {
                    // The reader thread blocks between frames; response
                    // timeouts are enforced per call on the receiving
                    // channel, not on the socket.
                    stream.set_read_timeout(None)?;
                    self.wire = Wire::V2(Arc::new(MuxClient::start(stream)?));
                } else {
                    // Negotiated down by a future server. The probe
                    // connection is v2-tainted; reconnect fresh as v1.
                    self.wire = Wire::V1;
                }
            }
            _ => self.wire = Wire::V1,
        }
        Ok(())
    }

    fn ensure_connected(&mut self) -> Result<(), FrameError> {
        if self.conn.is_none() {
            let stream = self.dial()?;
            stream.set_read_timeout(self.read_timeout)?;
            let reader = BufReader::new(stream.try_clone()?);
            let writer = BufWriter::new(stream);
            self.conn = Some((reader, writer));
        }
        Ok(())
    }
}

impl Transport for TcpTransport {
    fn call_raw(&mut self, method: Method, request: &[u8]) -> Result<Vec<u8>, FrameError> {
        // One reconnect attempt on a broken pipe (server restart).
        for attempt in 0..2 {
            self.ensure_wire()?;
            if let Wire::V2(client) = &self.wire {
                let client = Arc::clone(client);
                match client.call(method, request, self.read_timeout) {
                    Ok(frame) => return Ok(frame),
                    // Timed out: the id was canceled, do NOT resend.
                    Err(FrameError::Io(e))
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) =>
                    {
                        return Err(FrameError::Io(e));
                    }
                    Err(FrameError::Io(_)) if attempt == 0 => {
                        // The shared connection died: renegotiate on a
                        // fresh one and retry once.
                        self.wire = Wire::Unprobed;
                        continue;
                    }
                    Err(e) => return Err(e),
                }
            }
            self.ensure_connected()?;
            let (reader, writer) = self.conn.as_mut().unwrap();
            let result = (|| -> Result<Vec<u8>, FrameError> {
                // Re-frame the raw request payload under our method byte.
                raw_write(writer, method, request)?;
                raw_read(reader)
            })();
            match result {
                Ok(resp) => return Ok(resp),
                // Read timeout: the connection is desynced (the
                // response may still arrive later) — drop it, but do
                // NOT resend.
                Err(FrameError::Io(e))
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    self.conn = None;
                    return Err(FrameError::Io(e));
                }
                Err(FrameError::Io(e)) if attempt == 0 => {
                    let _ = e;
                    self.conn = None; // drop and retry once
                }
                Err(e) => return Err(e),
            }
        }
        unreachable!()
    }

    fn call_stream(
        &mut self,
        method: Method,
        request: &[u8],
    ) -> Result<Option<ServerStream>, FrameError> {
        self.ensure_wire()?;
        match &self.wire {
            Wire::V2(client) => MuxClient::open_stream(client, method, request).map(Some),
            _ => Ok(None),
        }
    }
}

// ---------------------------------------------------------------------------
// Multiplexed v2 client
// ---------------------------------------------------------------------------

/// Demux events delivered to one correlation id's waiter.
enum MuxEvent {
    /// Terminal unary answer, re-framed as v1 response bytes
    /// (`[u32 len][status][payload]`) so the shared `read_response`
    /// path parses both protocols identically.
    Terminal(Vec<u8>),
    /// One `STREAM_ITEM` body.
    Item(Vec<u8>),
    /// Normal `STREAM_END`.
    End,
    /// The shared connection died before this call finished.
    Closed,
}

struct MuxShared {
    /// In-flight correlation ids → the caller waiting on each.
    pending: Mutex<HashMap<u32, mpsc::Sender<MuxEvent>>>,
    /// Set (before `pending` is drained) once the reader exits; checked
    /// under the `pending` lock on registration so no call can slip in
    /// between the flag and the drain.
    dead: AtomicBool,
}

/// One multiplexed wire-v2 connection: many concurrent RPCs share one
/// socket, each tagged with a correlation id, and a background reader
/// routes every inbound frame to its caller. Shared via `Arc` —
/// [`TcpTransport::try_share`] hands out extra handles over the same
/// connection.
pub struct MuxClient {
    shared: Arc<MuxShared>,
    /// Write half (a dup of the reader's socket). Whole frames only, so
    /// concurrent callers never interleave partial frames.
    writer: Mutex<TcpStream>,
    next_corr: AtomicU32,
    reader: Option<std::thread::JoinHandle<()>>,
}

impl MuxClient {
    fn start(stream: TcpStream) -> Result<MuxClient, FrameError> {
        let wstream = stream.try_clone()?;
        let shared = Arc::new(MuxShared {
            pending: Mutex::new(&classes::CL_MUX_PENDING, HashMap::new()),
            dead: AtomicBool::new(false),
        });
        let thread_shared = Arc::clone(&shared);
        let reader = std::thread::Builder::new()
            .name("mux-client-reader".into())
            .spawn(move || reader_loop(stream, thread_shared))
            .map_err(FrameError::Io)?;
        Ok(MuxClient {
            shared,
            writer: Mutex::new(&classes::CL_MUX_WRITER, wstream),
            next_corr: AtomicU32::new(1),
            reader: Some(reader),
        })
    }

    fn is_dead(&self) -> bool {
        self.shared.dead.load(Ordering::Acquire)
    }

    /// Claim a fresh correlation id and park a receiver for it.
    fn register(&self) -> Result<(u32, mpsc::Receiver<MuxEvent>), FrameError> {
        let corr = loop {
            let c = self.next_corr.fetch_add(1, Ordering::Relaxed);
            if c != 0 {
                break c; // 0 is the HELLO correlation id
            }
        };
        let (tx, rx) = mpsc::channel();
        let mut pending = self.shared.pending.lock();
        if self.shared.dead.load(Ordering::Acquire) {
            return Err(closed_err());
        }
        pending.insert(corr, tx);
        Ok((corr, rx))
    }

    /// Abandon a correlation id: a late answer routed to it is dropped.
    fn forget(&self, corr: u32) {
        self.shared.pending.lock().remove(&corr);
    }

    fn send(&self, kind: FrameKind, corr: u32, body: &[u8]) -> Result<(), FrameError> {
        let frame = encode_v2(kind, corr, body)?;
        use std::io::Write as _;
        let mut w = self.writer.lock();
        w.write_all(&frame).map_err(FrameError::Io)
    }

    fn recv(
        rx: &mpsc::Receiver<MuxEvent>,
        timeout: Option<Duration>,
    ) -> Result<MuxEvent, FrameError> {
        match timeout {
            Some(t) => match rx.recv_timeout(t) {
                Ok(ev) => Ok(ev),
                Err(mpsc::RecvTimeoutError::Timeout) => Err(FrameError::Io(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "response timed out",
                ))),
                Err(mpsc::RecvTimeoutError::Disconnected) => Ok(MuxEvent::Closed),
            },
            None => Ok(rx.recv().unwrap_or(MuxEvent::Closed)),
        }
    }

    /// One unary call over the shared connection. Returns v1-shaped
    /// response bytes (ok or error) for `read_response`.
    fn call(
        &self,
        method: Method,
        request: &[u8],
        timeout: Option<Duration>,
    ) -> Result<Vec<u8>, FrameError> {
        // Client-side RPC span: a child when a trace is already active
        // on this thread (e.g. a Pythia supporter read made while
        // serving a traced request), a fresh sampled root otherwise.
        // The context rides as a TLV trailer after the request bytes —
        // v2 only, so v1 frames stay byte-identical.
        let span = if crate::util::trace::enabled() {
            let code = crate::util::trace::CLIENT_RPC_BASE + method as u8 as u64;
            crate::util::trace::child_span(code).or_else(|| crate::util::trace::root_span(code))
        } else {
            None
        };
        let (corr, rx) = self.register()?;
        let mut body = Vec::with_capacity(1 + request.len());
        body.push(method as u8);
        body.extend_from_slice(request);
        if let Some(span) = &span {
            crate::wire::messages::append_trace_context(&mut body, span.ctx());
        }
        if let Err(e) = self.send(FrameKind::Request, corr, &body) {
            self.forget(corr);
            return Err(e);
        }
        // Unary calls normally get one RESPONSE or ERROR frame. A server
        // that answers with a stream (WaitOperation issued through
        // `call_raw`) degrades gracefully: the last item before
        // STREAM_END is the unary answer.
        let mut last_item: Option<Vec<u8>> = None;
        loop {
            let ev = match Self::recv(&rx, timeout) {
                Ok(ev) => ev,
                Err(e) => {
                    // Timed out: abandon the id so a late answer is not
                    // mistaken for another call's, and tell the server
                    // to drop any pending work for it.
                    self.forget(corr);
                    let _ = self.send(FrameKind::Cancel, corr, &[]);
                    return Err(e);
                }
            };
            match ev {
                MuxEvent::Terminal(frame) => return Ok(frame),
                MuxEvent::Item(item) => last_item = Some(item),
                MuxEvent::End => return Ok(reframe_ok(&last_item.unwrap_or_default())),
                MuxEvent::Closed => return Err(closed_err()),
            }
        }
    }

    /// Open a server-push stream. The handle owns the correlation id:
    /// dropping it early sends `CANCEL`.
    fn open_stream(
        client: &Arc<MuxClient>,
        method: Method,
        request: &[u8],
    ) -> Result<ServerStream, FrameError> {
        let (corr, rx) = client.register()?;
        let mut body = Vec::with_capacity(1 + request.len());
        body.push(method as u8);
        body.extend_from_slice(request);
        if let Err(e) = client.send(FrameKind::Request, corr, &body) {
            client.forget(corr);
            return Err(e);
        }
        Ok(ServerStream { client: Arc::clone(client), corr, rx, done: false })
    }
}

impl Drop for MuxClient {
    fn drop(&mut self) {
        self.shared.dead.store(true, Ordering::Release);
        {
            // Unblock the parked reader: its next read returns 0 and the
            // thread drains any stragglers before exiting.
            let w = self.writer.lock();
            let _ = w.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// Background demux loop: route every inbound frame to the caller parked
/// on its correlation id. Exits on EOF, an unreadable frame, or a
/// protocol violation; every parked caller then observes `Closed`.
fn reader_loop(stream: TcpStream, shared: Arc<MuxShared>) {
    let mut reader = BufReader::new(stream);
    loop {
        let (head, payload) = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => break,
        };
        let frame = match parse_v2(head, payload) {
            Ok(f) => f,
            Err(_) => break,
        };
        match frame.kind {
            // Duplicate HELLO echo: harmless, ignore.
            FrameKind::Hello => {}
            FrameKind::Response => {
                if let Some(tx) = shared.pending.lock().remove(&frame.corr) {
                    let _ = tx.send(MuxEvent::Terminal(reframe_ok(&frame.body)));
                }
            }
            FrameKind::Error => {
                if let Some(tx) = shared.pending.lock().remove(&frame.corr) {
                    let _ = tx.send(MuxEvent::Terminal(reframe_err(&frame.body)));
                }
            }
            FrameKind::StreamItem => {
                let mut pending = shared.pending.lock();
                // A missing entry is a canceled id racing a late item:
                // drop it silently. A closed receiver means the handle
                // vanished without cancelling — stop routing to it.
                let receiver_gone = match pending.get(&frame.corr) {
                    Some(tx) => tx.send(MuxEvent::Item(frame.body)).is_err(),
                    None => false,
                };
                if receiver_gone {
                    pending.remove(&frame.corr);
                }
            }
            FrameKind::StreamEnd => {
                if let Some(tx) = shared.pending.lock().remove(&frame.corr) {
                    let _ = tx.send(MuxEvent::End);
                }
            }
            // The server never originates requests or cancels; the
            // connection state is unknowable — tear it down.
            FrameKind::Request | FrameKind::Cancel => break,
        }
    }
    shared.dead.store(true, Ordering::Release);
    let waiters: Vec<_> = shared.pending.lock().drain().map(|(_, tx)| tx).collect();
    for tx in waiters {
        let _ = tx.send(MuxEvent::Closed);
    }
}

/// A server-push stream over a multiplexed v2 connection (one
/// `WaitOperation` watch). Yields raw `STREAM_ITEM` payloads; dropping
/// the handle before the end sends `CANCEL` so the server releases its
/// watcher immediately.
pub struct ServerStream {
    client: Arc<MuxClient>,
    corr: u32,
    rx: mpsc::Receiver<MuxEvent>,
    done: bool,
}

impl ServerStream {
    /// The next item; `Ok(None)` at normal end of stream. A timeout
    /// error leaves the stream usable — call again to keep waiting, or
    /// drop the handle to cancel.
    pub fn next(&mut self, timeout: Option<Duration>) -> Result<Option<Vec<u8>>, FrameError> {
        if self.done {
            return Ok(None);
        }
        let ev = MuxClient::recv(&self.rx, timeout)?;
        match ev {
            MuxEvent::Item(body) => Ok(Some(body)),
            MuxEvent::End => {
                self.done = true;
                Ok(None)
            }
            MuxEvent::Terminal(frame) => {
                self.done = true;
                // A unary answer on a stream id: a v2 server that chose
                // not to stream this method. Surface a success as the
                // final item, an error as the error it is.
                let (status, payload) = split_v1_frame(&frame);
                if status == Status::Ok {
                    Ok(Some(payload.to_vec()))
                } else {
                    Err(FrameError::Rpc {
                        status,
                        message: String::from_utf8_lossy(payload).into_owned(),
                    })
                }
            }
            MuxEvent::Closed => {
                self.done = true;
                Err(closed_err())
            }
        }
    }
}

impl Drop for ServerStream {
    fn drop(&mut self) {
        if !self.done {
            self.client.forget(self.corr);
            let _ = self.client.send(FrameKind::Cancel, self.corr, &[]);
        }
    }
}

/// Re-frame a v2 RESPONSE body as v1 response bytes
/// (`[u32 len][status][payload]`).
fn reframe_ok(body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(5 + body.len());
    out.extend_from_slice(&((1 + body.len()) as u32).to_le_bytes());
    out.push(Status::Ok as u8);
    out.extend_from_slice(body);
    out
}

/// Re-frame a v2 ERROR body (`[status][utf-8 message]`) the same way.
fn reframe_err(body: &[u8]) -> Vec<u8> {
    let (status, msg) = match body.split_first() {
        Some((&s, rest)) => (s, rest),
        None => (Status::Internal as u8, &[][..]),
    };
    let mut out = Vec::with_capacity(5 + msg.len());
    out.extend_from_slice(&((1 + msg.len()) as u32).to_le_bytes());
    out.push(status);
    out.extend_from_slice(msg);
    out
}

/// Split v1-shaped response bytes back into `(status, payload)`.
fn split_v1_frame(frame: &[u8]) -> (Status, &[u8]) {
    match frame.get(4) {
        Some(&s) => (Status::from_u8(s), &frame[5..]),
        None => (Status::Internal, &[][..]),
    }
}

fn closed_err() -> FrameError {
    FrameError::Io(std::io::Error::new(
        std::io::ErrorKind::ConnectionAborted,
        "multiplexed connection closed",
    ))
}

/// In-process transport: calls the service directly, no sockets. The
/// encode/decode round-trip is kept so local and remote behaviour are
/// byte-identical.
pub struct LocalTransport {
    service: Arc<VizierService>,
}

impl LocalTransport {
    pub fn new(service: Arc<VizierService>) -> Self {
        Self { service }
    }
}

impl Transport for LocalTransport {
    fn call_raw(&mut self, method: Method, request: &[u8]) -> Result<Vec<u8>, FrameError> {
        // No socket, so no trailer: the client span's context flows to
        // the dispatch span thread-locally instead.
        let _span = if crate::util::trace::enabled() {
            let code = crate::util::trace::CLIENT_RPC_BASE + method as u8 as u64;
            crate::util::trace::child_span(code).or_else(|| crate::util::trace::root_span(code))
        } else {
            None
        };
        Ok(dispatch_buf(&self.service, method, request))
    }
}

fn raw_write<W: std::io::Write>(w: &mut W, method: Method, payload: &[u8]) -> Result<(), FrameError> {
    // write_request over a pre-encoded payload.
    struct Pre<'a>(&'a [u8]);
    impl WireMessage for Pre<'_> {
        fn encode_fields(&self, out: &mut crate::wire::codec::Writer) {
            out.raw_append(self.0);
        }
        fn decode_fields(_: &mut crate::wire::codec::Reader) -> Result<Self, crate::wire::codec::WireError> {
            unreachable!("Pre is write-only")
        }
    }
    write_request(w, method, &Pre(payload))
}

fn raw_read<R: std::io::Read>(r: &mut R) -> Result<Vec<u8>, FrameError> {
    // Return the whole response frame (head + payload) re-framed so
    // `read_response` can parse it from a cursor.
    let (head, payload) = read_frame(r)?;
    let mut out = Vec::with_capacity(5 + payload.len());
    out.extend_from_slice(&((1 + payload.len()) as u32).to_le_bytes());
    out.push(head);
    out.extend_from_slice(&payload);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::memory::InMemoryDatastore;
    use crate::pythia::runner::{default_registry, LocalPythia};
    use crate::pythia::supporter::DatastoreSupporter;
    use crate::wire::messages::{EmptyResponse, ListStudiesRequest, ListStudiesResponse};

    fn service() -> Arc<VizierService> {
        let ds = Arc::new(InMemoryDatastore::new());
        let supporter = Arc::new(DatastoreSupporter::new(
            Arc::clone(&ds) as Arc<dyn crate::datastore::Datastore>
        ));
        let pythia = Arc::new(LocalPythia::new(default_registry(), supporter));
        VizierService::new(ds, pythia, 2)
    }

    #[test]
    fn local_transport_roundtrip() {
        let svc = service();
        let mut t = LocalTransport::new(svc);
        let resp: ListStudiesResponse =
            call(&mut t, Method::ListStudies, &ListStudiesRequest::default()).unwrap();
        assert!(resp.studies.is_empty());
        let _: EmptyResponse = call(&mut t, Method::Ping, &EmptyResponse::default()).unwrap();
    }

    #[test]
    fn tcp_transport_v1_roundtrip_and_reconnect() {
        let svc = service();
        let server = crate::service::server::VizierServer::start(svc, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let mut t = TcpTransport::connect(&addr).unwrap();
        // Pin to v1 to exercise the legacy framing against a server that
        // would otherwise negotiate v2.
        t.force_v1();
        let _: EmptyResponse = call(&mut t, Method::Ping, &EmptyResponse::default()).unwrap();
        // Simulate a dropped connection: the transport must reconnect.
        t.conn = None;
        let _: EmptyResponse = call(&mut t, Method::Ping, &EmptyResponse::default()).unwrap();
        server.shutdown();
    }

    #[test]
    fn tcp_transport_negotiates_v2_and_shares_one_connection() {
        let svc = service();
        let server = crate::service::server::VizierServer::start(svc, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let mut t = TcpTransport::connect(&addr).unwrap();
        if wire_v2_disabled() {
            assert_eq!(t.wire_version(), 1);
            server.shutdown();
            return;
        }
        assert_eq!(t.wire_version(), 2, "HELLO probe must negotiate v2");
        let _: EmptyResponse = call(&mut t, Method::Ping, &EmptyResponse::default()).unwrap();

        // A shared handle multiplexes over the same socket: calls from
        // both handles (and from a second thread) complete.
        let mut shared = t.try_share().expect("v2 transport must share");
        let worker = std::thread::spawn(move || {
            for _ in 0..4 {
                let _: EmptyResponse =
                    call(&mut shared, Method::Ping, &EmptyResponse::default()).unwrap();
            }
        });
        for _ in 0..4 {
            let _: EmptyResponse = call(&mut t, Method::Ping, &EmptyResponse::default()).unwrap();
        }
        worker.join().unwrap();
        server.shutdown();
    }
}
