//! Client transports: TCP (distributed, the normal deployment) and
//! in-process ("the server may be launched in the same local process as
//! the client, in cases where distributed computing is not needed and
//! function evaluation is cheap" — paper §3.2).

use crate::service::api::VizierService;
use crate::service::server::dispatch_buf;
use crate::wire::codec::{encode, WireMessage};
use crate::wire::framing::{read_response, write_request, FrameError, Method};
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

/// A bidirectional request/response channel to a Vizier service.
pub trait Transport: Send {
    fn call_raw(&mut self, method: Method, request: &[u8]) -> Result<Vec<u8>, FrameError>;
}

/// Typed call helper shared by all transports.
pub fn call<T: Transport + ?Sized, Req: WireMessage, Resp: WireMessage>(
    t: &mut T,
    method: Method,
    req: &Req,
) -> Result<Resp, FrameError> {
    let raw = t.call_raw(method, &encode(req))?;
    let mut cursor = std::io::Cursor::new(raw);
    read_response(&mut cursor)
}

/// TCP transport with automatic reconnect on broken connections.
pub struct TcpTransport {
    addr: String,
    conn: Option<(BufReader<TcpStream>, BufWriter<TcpStream>)>,
    pub connect_timeout: Duration,
    /// Per-response read timeout (`None` = block forever, the default —
    /// user clients legitimately wait on long evaluations). A timed-out
    /// call fails *without* the resend retry: the request was already
    /// delivered and replaying a non-idempotent RPC (CompleteTrial)
    /// would be worse than the error.
    pub read_timeout: Option<Duration>,
}

impl TcpTransport {
    pub fn connect(addr: &str) -> Result<Self, FrameError> {
        Self::connect_with_read_timeout(addr, None)
    }

    /// Connect with a bound on how long one RPC may wait for its
    /// response (used by `RemoteSupporter` so a vanished API server
    /// cannot stall a policy run indefinitely).
    pub fn connect_with_read_timeout(
        addr: &str,
        read_timeout: Option<Duration>,
    ) -> Result<Self, FrameError> {
        let mut t = Self {
            addr: addr.to_string(),
            conn: None,
            connect_timeout: Duration::from_secs(5),
            read_timeout,
        };
        t.ensure_connected()?;
        Ok(t)
    }

    fn ensure_connected(&mut self) -> Result<(), FrameError> {
        if self.conn.is_none() {
            let sock_addr: std::net::SocketAddr = self
                .addr
                .parse()
                .map_err(|_| FrameError::Io(std::io::Error::other(format!("bad addr {}", self.addr))))?;
            let stream = TcpStream::connect_timeout(&sock_addr, self.connect_timeout)?;
            stream.set_nodelay(true).ok();
            stream.set_read_timeout(self.read_timeout)?;
            let reader = BufReader::new(stream.try_clone()?);
            let writer = BufWriter::new(stream);
            self.conn = Some((reader, writer));
        }
        Ok(())
    }
}

impl Transport for TcpTransport {
    fn call_raw(&mut self, method: Method, request: &[u8]) -> Result<Vec<u8>, FrameError> {
        // One reconnect attempt on a broken pipe (server restart).
        for attempt in 0..2 {
            self.ensure_connected()?;
            let (reader, writer) = self.conn.as_mut().unwrap();
            let result = (|| -> Result<Vec<u8>, FrameError> {
                // Re-frame the raw request payload under our method byte.
                raw_write(writer, method, request)?;
                raw_read(reader)
            })();
            match result {
                Ok(resp) => return Ok(resp),
                // Read timeout: the connection is desynced (the
                // response may still arrive later) — drop it, but do
                // NOT resend.
                Err(FrameError::Io(e))
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    self.conn = None;
                    return Err(FrameError::Io(e));
                }
                Err(FrameError::Io(e)) if attempt == 0 => {
                    let _ = e;
                    self.conn = None; // drop and retry once
                }
                Err(e) => return Err(e),
            }
        }
        unreachable!()
    }
}

fn raw_write<W: std::io::Write>(w: &mut W, method: Method, payload: &[u8]) -> Result<(), FrameError> {
    // write_request over a pre-encoded payload.
    struct Pre<'a>(&'a [u8]);
    impl WireMessage for Pre<'_> {
        fn encode_fields(&self, out: &mut crate::wire::codec::Writer) {
            out.raw_append(self.0);
        }
        fn decode_fields(_: &mut crate::wire::codec::Reader) -> Result<Self, crate::wire::codec::WireError> {
            unreachable!("Pre is write-only")
        }
    }
    write_request(w, method, &Pre(payload))
}

fn raw_read<R: std::io::Read>(r: &mut R) -> Result<Vec<u8>, FrameError> {
    // Return the whole response frame (head + payload) re-framed so
    // `read_response` can parse it from a cursor.
    let (head, payload) = crate::wire::framing::read_frame(r)?;
    let mut out = Vec::with_capacity(5 + payload.len());
    out.extend_from_slice(&((1 + payload.len()) as u32).to_le_bytes());
    out.push(head);
    out.extend_from_slice(&payload);
    Ok(out)
}

/// In-process transport: calls the service directly, no sockets. The
/// encode/decode round-trip is kept so local and remote behaviour are
/// byte-identical.
pub struct LocalTransport {
    service: Arc<VizierService>,
}

impl LocalTransport {
    pub fn new(service: Arc<VizierService>) -> Self {
        Self { service }
    }
}

impl Transport for LocalTransport {
    fn call_raw(&mut self, method: Method, request: &[u8]) -> Result<Vec<u8>, FrameError> {
        Ok(dispatch_buf(&self.service, method, request))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datastore::memory::InMemoryDatastore;
    use crate::pythia::runner::{default_registry, LocalPythia};
    use crate::pythia::supporter::DatastoreSupporter;
    use crate::wire::messages::{EmptyResponse, ListStudiesRequest, ListStudiesResponse};

    fn service() -> Arc<VizierService> {
        let ds = Arc::new(InMemoryDatastore::new());
        let supporter = Arc::new(DatastoreSupporter::new(
            Arc::clone(&ds) as Arc<dyn crate::datastore::Datastore>
        ));
        let pythia = Arc::new(LocalPythia::new(default_registry(), supporter));
        VizierService::new(ds, pythia, 2)
    }

    #[test]
    fn local_transport_roundtrip() {
        let svc = service();
        let mut t = LocalTransport::new(svc);
        let resp: ListStudiesResponse =
            call(&mut t, Method::ListStudies, &ListStudiesRequest::default()).unwrap();
        assert!(resp.studies.is_empty());
        let _: EmptyResponse = call(&mut t, Method::Ping, &EmptyResponse::default()).unwrap();
    }

    #[test]
    fn tcp_transport_roundtrip_and_reconnect() {
        let svc = service();
        let server = crate::service::server::VizierServer::start(svc, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let mut t = TcpTransport::connect(&addr).unwrap();
        let _: EmptyResponse = call(&mut t, Method::Ping, &EmptyResponse::default()).unwrap();
        // Simulate a dropped connection: the transport must reconnect.
        t.conn = None;
        let _: EmptyResponse = call(&mut t, Method::Ping, &EmptyResponse::default()).unwrap();
        server.shutdown();
    }
}
