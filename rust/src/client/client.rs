//! VizierClient — the user API of Code Block 1:
//!
//! ```text
//! client = VizierClient.load_or_create_study('cifar10', config, client_id)
//! while suggestions := client.get_suggestions(count=1):
//!     for trial in suggestions:
//!         metrics = _evaluate_trial(trial.parameters)
//!         client.complete_trial(metrics, trial_id=trial.id)
//! ```

use super::transport::{call, Transport};
use crate::pyvizier::{converters, Measurement, StudyConfig, Trial};
use crate::util::backoff::Backoff;
use crate::wire::codec::{decode, encode};
use crate::wire::framing::{FrameError, Method, Status};
use crate::wire::messages::*;
use std::time::{Duration, Instant};

/// Client-side errors.
#[derive(Debug)]
pub enum ClientError {
    Transport(String),
    Rpc { status: Status, message: String },
    OperationFailed(String, String),
    OperationTimeout(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Transport(msg) => write!(f, "transport failure: {msg}"),
            ClientError::Rpc { status, message } => write!(f, "rpc {status:?}: {message}"),
            ClientError::OperationFailed(op, msg) => {
                write!(f, "operation {op} failed on the server: {msg}")
            }
            ClientError::OperationTimeout(op) => {
                write!(f, "timed out waiting for operation {op}")
            }
        }
    }
}

impl std::error::Error for ClientError {}

impl From<FrameError> for ClientError {
    fn from(e: FrameError) -> Self {
        match e {
            FrameError::Rpc { status, message } => ClientError::Rpc { status, message },
            other => ClientError::Transport(other.to_string()),
        }
    }
}

/// Does this error mean the server predates `WaitOperation`? Old
/// servers answer an unknown method id with `InvalidArgument: unknown
/// method id ...` and close the connection (the transport reconnects on
/// the next call); an intermediary might also say `Unimplemented`.
fn wait_operation_unsupported(e: &ClientError) -> bool {
    match e {
        ClientError::Rpc { status: Status::Unimplemented, .. } => true,
        ClientError::Rpc { status: Status::InvalidArgument, message } => {
            message.contains("unknown method")
        }
        _ => false,
    }
}

/// One `WaitOperation` long-poll chunk: under the server's 60 s cap,
/// long enough that a multi-minute GP fit costs a handful of idle
/// round-trips instead of a busy-poll stream.
const WAIT_CHUNK_MS: u64 = 25_000;

/// A connected Vizier client bound to one study and one `client_id`.
pub struct VizierClient {
    transport: Box<dyn Transport>,
    pub study_name: String,
    pub client_id: String,
    /// Max time to wait for one suggestion operation.
    pub operation_timeout: Duration,
    /// Whether the server supports `WaitOperation` (assumed until it
    /// answers "unknown method"; then this client permanently falls
    /// back to `GetOperation` polling with capped backoff).
    server_waits: bool,
}

impl VizierClient {
    /// Load the study named `display_name`, creating it from `config` if it
    /// does not exist (the first replica creates; the rest load — §5).
    pub fn load_or_create_study(
        mut transport: Box<dyn Transport>,
        display_name: &str,
        config: &StudyConfig,
        client_id: &str,
    ) -> Result<Self, ClientError> {
        let lookup: Result<StudyResponse, FrameError> = call(
            transport.as_mut(),
            Method::LookupStudy,
            &LookupStudyRequest {
                display_name: display_name.to_string(),
            },
        );
        let study = match lookup {
            Ok(resp) => resp.study,
            Err(FrameError::Rpc {
                status: Status::NotFound,
                ..
            }) => {
                let create = CreateStudyRequest {
                    study: StudyProto {
                        display_name: display_name.to_string(),
                        spec: converters::study_config_to_proto(config),
                        ..Default::default()
                    },
                };
                match call::<_, _, StudyResponse>(transport.as_mut(), Method::CreateStudy, &create)
                {
                    Ok(resp) => resp.study,
                    // A parallel replica won the race: load theirs.
                    Err(FrameError::Rpc {
                        status: Status::FailedPrecondition,
                        ..
                    }) => {
                        call::<_, _, StudyResponse>(
                            transport.as_mut(),
                            Method::LookupStudy,
                            &LookupStudyRequest {
                                display_name: display_name.to_string(),
                            },
                        )?
                        .study
                    }
                    Err(e) => return Err(e.into()),
                }
            }
            Err(e) => return Err(e.into()),
        };
        Ok(Self {
            transport,
            study_name: study.name,
            client_id: client_id.to_string(),
            operation_timeout: Duration::from_secs(300),
            server_waits: true,
        })
    }

    /// Connect to an existing study by resource name.
    pub fn for_study(transport: Box<dyn Transport>, study_name: &str, client_id: &str) -> Self {
        Self {
            transport,
            study_name: study_name.to_string(),
            client_id: client_id.to_string(),
            operation_timeout: Duration::from_secs(300),
            server_waits: true,
        }
    }

    fn rpc<Req: crate::wire::codec::WireMessage, Resp: crate::wire::codec::WireMessage>(
        &mut self,
        method: Method,
        req: &Req,
    ) -> Result<Resp, ClientError> {
        Ok(call(self.transport.as_mut(), method, req)?)
    }

    /// Request `count` suggestions: issues SuggestTrials then polls
    /// GetOperation with backoff until done (the workflow of §3.2).
    /// Returns an empty vector only when the server reports a completed
    /// operation with no trials (e.g. exhausted grid).
    pub fn get_suggestions(&mut self, count: usize) -> Result<Vec<Trial>, ClientError> {
        let resp: OperationResponse = self.rpc(
            Method::SuggestTrials,
            &SuggestTrialsRequest {
                study_name: self.study_name.clone(),
                count: count as u64,
                client_id: self.client_id.clone(),
            },
        )?;
        let op = self.wait_operation(resp.operation)?;
        Ok(op.trials.iter().map(converters::trial_from_proto).collect())
    }

    /// Wait for an operation, best protocol first:
    ///
    /// 1. Wire v2: one `WaitOperation` watch stream — the server pushes
    ///    a snapshot on every state change and ends the stream at
    ///    completion. Every transition is observed with zero
    ///    `GetOperation` calls and no polling traffic at all.
    /// 2. Wire v1: `WaitOperation` long-polls server-side (the server
    ///    parks this request and answers the instant the policy result
    ///    lands), chunked under the server's per-call cap.
    /// 3. Old servers that do not know the method get the classic
    ///    `GetOperation` loop with capped backoff.
    fn wait_operation(&mut self, mut op: OperationProto) -> Result<OperationProto, ClientError> {
        let deadline = Instant::now() + self.operation_timeout;
        if !op.done {
            match self.wait_via_stream(&op, deadline)? {
                Some(finished) => op = finished,
                // Streaming unavailable (v1 peer) or the connection
                // dropped mid-stream: the unary loop below reconnects
                // and finishes the wait.
                None => {}
            }
        }
        let mut backoff = Backoff::polling();
        while !op.done {
            let now = Instant::now();
            if now > deadline {
                return Err(ClientError::OperationTimeout(op.name));
            }
            if self.server_waits {
                let remaining_ms = deadline.saturating_duration_since(now).as_millis() as u64;
                let result: Result<OperationResponse, ClientError> = self.rpc(
                    Method::WaitOperation,
                    &WaitOperationRequest {
                        name: op.name.clone(),
                        timeout_ms: remaining_ms.clamp(1, WAIT_CHUNK_MS),
                    },
                );
                match result {
                    Ok(resp) => {
                        // A not-done answer is the chunk deadline
                        // passing (or a draining server answering
                        // early); the brief pause keeps the loop from
                        // spinning in the latter case and costs one
                        // capped delay per ~25 s chunk otherwise.
                        op = resp.operation;
                        if !op.done {
                            std::thread::sleep(backoff.next_delay());
                        }
                        continue;
                    }
                    Err(e) if wait_operation_unsupported(&e) => {
                        self.server_waits = false;
                        continue;
                    }
                    Err(e) => return Err(e),
                }
            }
            std::thread::sleep(backoff.next_delay());
            let resp: OperationResponse = self.rpc(
                Method::GetOperation,
                &GetOperationRequest {
                    name: op.name.clone(),
                },
            )?;
            op = resp.operation;
        }
        if !op.error.is_empty() {
            return Err(ClientError::OperationFailed(op.name, op.error));
        }
        Ok(op)
    }

    /// Consume a v2 `WaitOperation` watch stream to completion.
    /// `Ok(None)` means streaming is unavailable — the transport is v1,
    /// or the connection failed before/while streaming — and the caller
    /// should fall back to unary waits (which reconnect on their own).
    fn wait_via_stream(
        &mut self,
        op: &OperationProto,
        deadline: Instant,
    ) -> Result<Option<OperationProto>, ClientError> {
        let req = WaitOperationRequest { name: op.name.clone(), timeout_ms: 0 };
        let mut stream = match self.transport.call_stream(Method::WaitOperation, &encode(&req)) {
            Ok(Some(s)) => s,
            Ok(None) => return Ok(None),
            Err(_) => return Ok(None),
        };
        let mut latest = op.clone();
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                // Dropping the handle sends CANCEL: the server releases
                // its watcher instead of pushing to a vanished reader.
                return Err(ClientError::OperationTimeout(latest.name));
            }
            match stream.next(Some(remaining)) {
                Ok(Some(item)) => {
                    let resp: OperationResponse =
                        decode(&item).map_err(|e| ClientError::Transport(e.to_string()))?;
                    latest = resp.operation;
                    if latest.done {
                        return Ok(Some(latest));
                    }
                }
                // Stream ended without a done snapshot (server
                // draining): finish on the unary path.
                Ok(None) => return Ok(None),
                Err(FrameError::Io(e))
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return Err(ClientError::OperationTimeout(latest.name));
                }
                Err(FrameError::Rpc { status, message }) => {
                    return Err(ClientError::Rpc { status, message });
                }
                // Connection died mid-stream: reconnect via unary waits.
                Err(_) => return Ok(None),
            }
        }
    }

    /// Report an intermediate measurement (learning-curve point).
    pub fn add_measurement(
        &mut self,
        trial_id: u64,
        measurement: &Measurement,
    ) -> Result<(), ClientError> {
        let _: TrialResponse = self.rpc(
            Method::AddMeasurement,
            &AddMeasurementRequest {
                study_name: self.study_name.clone(),
                trial_id,
                measurement: converters::measurement_to_proto(measurement),
            },
        )?;
        Ok(())
    }

    /// Complete a trial with a final measurement.
    pub fn complete_trial(
        &mut self,
        trial_id: u64,
        final_measurement: Option<&Measurement>,
    ) -> Result<Trial, ClientError> {
        let resp: TrialResponse = self.rpc(
            Method::CompleteTrial,
            &CompleteTrialRequest {
                study_name: self.study_name.clone(),
                trial_id,
                final_measurement: final_measurement.map(converters::measurement_to_proto),
                infeasible: false,
                infeasibility_reason: String::new(),
            },
        )?;
        Ok(converters::trial_from_proto(&resp.trial))
    }

    /// Report a trial as infeasible (persistent failure — not retried).
    pub fn report_infeasible(&mut self, trial_id: u64, reason: &str) -> Result<(), ClientError> {
        let _: TrialResponse = self.rpc(
            Method::CompleteTrial,
            &CompleteTrialRequest {
                study_name: self.study_name.clone(),
                trial_id,
                final_measurement: None,
                infeasible: true,
                infeasibility_reason: reason.to_string(),
            },
        )?;
        Ok(())
    }

    /// Batched early stopping (Pythia v2): one operation judges many
    /// trials and returns a per-trial verdict. An empty `trial_ids` asks
    /// about every ACTIVE trial of the study — useful for a worker that
    /// owns several running trials and wants one RPC per wave instead of
    /// one per trial.
    pub fn check_early_stopping(
        &mut self,
        trial_ids: &[u64],
    ) -> Result<Vec<TrialStopDecision>, ClientError> {
        let resp: OperationResponse = self.rpc(
            Method::CheckEarlyStopping,
            &CheckEarlyStoppingRequest {
                study_name: self.study_name.clone(),
                trial_ids: trial_ids.to_vec(),
            },
        )?;
        let op = self.wait_operation(resp.operation)?;
        Ok(op.stop_decisions)
    }

    /// Ask whether a running trial should stop (Code Block 3): the
    /// single-trial convenience over [`Self::check_early_stopping`].
    pub fn should_trial_stop(&mut self, trial_id: u64) -> Result<bool, ClientError> {
        Ok(self
            .check_early_stopping(&[trial_id])?
            .iter()
            .find(|d| d.trial_id == trial_id)
            .map(|d| d.should_stop)
            .unwrap_or(false))
    }

    /// All trials of the study (one unpaginated response; prefer
    /// [`Self::list_trials_page`] for large studies).
    pub fn list_trials(&mut self) -> Result<Vec<Trial>, ClientError> {
        let resp: ListTrialsResponse = self.rpc(
            Method::ListTrials,
            &ListTrialsRequest {
                study_name: self.study_name.clone(),
                ..Default::default()
            },
        )?;
        Ok(resp.trials.iter().map(converters::trial_from_proto).collect())
    }

    /// One page of the study's trials: at most `page_size` trials after
    /// the position encoded by `page_token` (`""` starts from the top).
    /// The returned token is empty once the listing is exhausted.
    pub fn list_trials_page(
        &mut self,
        page_size: usize,
        page_token: &str,
    ) -> Result<(Vec<Trial>, String), ClientError> {
        let resp: ListTrialsResponse = self.rpc(
            Method::ListTrials,
            &ListTrialsRequest {
                study_name: self.study_name.clone(),
                page_size: page_size as u64,
                page_token: page_token.to_string(),
            },
        )?;
        Ok((
            resp.trials.iter().map(converters::trial_from_proto).collect(),
            resp.next_page_token,
        ))
    }

    /// Service + front-end counter snapshot (coalescing ratio, in-flight
    /// policy jobs, parked responses). The plain-text `report` is
    /// rendered here on the client from the typed counters, gauges, and
    /// histograms; old servers that predate the structured fields ship
    /// their own server-rendered text, which passes through untouched.
    pub fn service_metrics(&mut self) -> Result<ServiceMetricsResponse, ClientError> {
        let mut resp: ServiceMetricsResponse =
            self.rpc(Method::GetServiceMetrics, &GetServiceMetricsRequest::default())?;
        if resp.report.is_empty() {
            resp.report = render_metrics_report(&resp);
        }
        Ok(resp)
    }

    /// The Pareto-optimal (or single-objective best) trials.
    pub fn list_optimal_trials(&mut self) -> Result<Vec<Trial>, ClientError> {
        let resp: ListTrialsResponse = self.rpc(
            Method::ListOptimalTrials,
            &ListOptimalTrialsRequest {
                study_name: self.study_name.clone(),
            },
        )?;
        Ok(resp.trials.iter().map(converters::trial_from_proto).collect())
    }

    /// The study's current configuration (including stored metadata).
    pub fn get_study_config(&mut self) -> Result<StudyConfig, ClientError> {
        let resp: StudyResponse = self.rpc(
            Method::GetStudy,
            &GetStudyRequest {
                name: self.study_name.clone(),
            },
        )?;
        Ok(converters::study_config_from_proto(
            &resp.study.display_name,
            &resp.study.spec,
        ))
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        let _: EmptyResponse = self.rpc(Method::Ping, &EmptyResponse::default())?;
        Ok(())
    }

    /// The server's slowest recent request traces, rendered as one span
    /// tree per trace (slowest first). `limit` of 0 means the server
    /// default (10); `include_infra` appends the background pseudo-trace
    /// (fsync batches, segment rotations). Empty output means tracing
    /// is disabled server-side (`--trace-sample-rate` / `OSSVIZIER_TRACE`).
    pub fn traces(&mut self, limit: u64, include_infra: bool) -> Result<String, ClientError> {
        let resp: GetTracesResponse =
            self.rpc(Method::GetTraces, &GetTracesRequest { limit, include_infra })?;
        Ok(render_traces_report(&resp))
    }
}

/// Render `GetTraces` into plain text: one header line per trace
/// followed by its indented span tree. Span names arrive resolved from
/// the server, so this stays correct across name-code additions.
fn render_traces_report(resp: &GetTracesResponse) -> String {
    let mut out = String::new();
    for t in &resp.traces {
        if t.trace_id == 0 {
            out.push_str(&format!(
                "infra (background) [{} spans]\n",
                t.spans.len()
            ));
        } else {
            out.push_str(&format!(
                "trace {:016x} [{:.1} ms, {} spans]\n",
                t.trace_id,
                t.duration_us as f64 / 1000.0,
                t.spans.len()
            ));
        }
        let rows: Vec<(u64, u64, String, u64, u64)> = t
            .spans
            .iter()
            .map(|s| (s.span_id, s.parent_id, s.name.clone(), s.start_us, s.duration_us))
            .collect();
        out.push_str(&crate::util::trace::render_spans(&rows));
    }
    if resp.traces.is_empty() {
        out.push_str("no traces recorded (is tracing enabled on the server?)\n");
    }
    out
}

/// Render the structured `GetServiceMetrics` fields into the classic
/// plain-text report — byte-identical to what `ServiceMetrics::report`
/// produces server-side, so `vizier metrics` output is unchanged by the
/// move to typed metrics. The front-end and WAL sections appear exactly
/// when the server exported any point under their name prefix (i.e. the
/// corresponding subsystem is linked), mirroring the server rendering.
fn render_metrics_report(resp: &ServiceMetricsResponse) -> String {
    let counter = |name: &str| {
        resp.counters.iter().find(|p| p.name == name).map_or(0, |p| p.value)
    };
    let gauge = |name: &str| {
        resp.gauges.iter().find(|p| p.name == name).map_or(0, |p| p.value)
    };
    let hist = |name: &str| resp.histograms.iter().find(|h| h.name == name);
    let has_section = |prefix: &str| {
        resp.counters.iter().any(|p| p.name.starts_with(prefix))
            || resp.gauges.iter().any(|p| p.name.starts_with(prefix))
            || resp.histograms.iter().any(|h| h.name.starts_with(prefix))
    };

    let mut out = String::from("method                     count    mean_us    p50_us    p99_us\n");
    let mut methods: Vec<_> = resp
        .histograms
        .iter()
        .filter(|h| h.name.starts_with("method."))
        .collect();
    // The server exports them in BTreeMap order already; sort anyway so
    // the table is stable whatever the server did.
    methods.sort_by(|a, b| a.name.cmp(&b.name));
    for h in methods {
        let name = &h.name["method.".len()..];
        out.push_str(&format!(
            "{name:<25} {:>7} {:>10.1} {:>9} {:>9}\n",
            h.count,
            h.mean_us(),
            h.p50_us,
            h.p99_us,
        ));
    }
    out.push_str(&format!("errors: {}\n", counter("errors")));
    out.push_str(&format!(
        "policy runs: {} (serving {} suggest ops), {} in flight\n",
        counter("policy_runs"),
        counter("suggest_ops_served"),
        gauge("in_flight_policy_jobs"),
    ));
    let ww = hist("wait_wakeup");
    out.push_str(&format!(
        "wait wakeups: {} (mean {:.1} us, p99 {} us)\n",
        ww.map_or(0, |h| h.count),
        ww.map_or(0.0, |h| h.mean_us()),
        ww.map_or(0, |h| h.p99_us),
    ));
    if has_section("frontend.") {
        let qw = hist("frontend.queue_wait");
        out.push_str(&format!(
            "frontend: {} active / {} total connections ({} refused, {} evicted), \
             queue depth {}, {} parked responses, \
             {} requests (queue wait mean {:.1} us, p99 {} us), \
             {} loop wakeups ({} scan cost)\n",
            gauge("frontend.active_connections"),
            counter("frontend.connections_total"),
            counter("frontend.connections_refused"),
            counter("frontend.idle_evictions"),
            gauge("frontend.queue_depth"),
            gauge("frontend.parked_responses"),
            counter("frontend.requests"),
            qw.map_or(0.0, |h| h.mean_us()),
            qw.map_or(0, |h| h.p99_us),
            counter("frontend.loop_wakeups"),
            counter("frontend.loop_scan_cost"),
        ));
    }
    if has_section("wal.") {
        let comp = hist("wal.compaction");
        let cw = hist("wal.commit_wait");
        out.push_str(&format!(
            "wal: {} segment file(s), {} rotations, {} compactions \
             (mean {:.1} us, {} bytes reclaimed), \
             commit wait mean {:.1} us p99 {} us max {} us\n",
            gauge("wal.segments"),
            counter("wal.rotations"),
            counter("wal.compactions"),
            comp.map_or(0.0, |h| h.mean_us()),
            counter("wal.reclaimed_bytes"),
            cw.map_or(0.0, |h| h.mean_us()),
            cw.map_or(0, |h| h.p99_us),
            gauge("wal.commit_stall_max_us"),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::codec::decode;
    use crate::wire::framing::{write_err, write_ok};
    use crate::wire::messages::{
        OperationProto, OperationResponse, SuggestTrialsRequest, TrialProto, TrialState,
    };

    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    /// A server that predates `WaitOperation`: SuggestTrials returns a
    /// pending operation, WaitOperation gets the historical
    /// "unknown method id" error, GetOperation completes on the Nth
    /// poll. Counts calls per method.
    struct OldServerTransport {
        get_ops_until_done: u32,
        get_op_calls: Arc<AtomicU32>,
        wait_op_calls: Arc<AtomicU32>,
    }

    impl OldServerTransport {
        fn op(&self, done: bool) -> OperationProto {
            OperationProto {
                name: "operations/1".into(),
                done,
                trials: if done {
                    vec![TrialProto { id: 1, state: TrialState::Active, ..Default::default() }]
                } else {
                    Vec::new()
                },
                ..Default::default()
            }
        }
    }

    impl Transport for OldServerTransport {
        fn call_raw(&mut self, method: Method, request: &[u8]) -> Result<Vec<u8>, FrameError> {
            let mut out = Vec::new();
            match method {
                Method::SuggestTrials => {
                    let _req: SuggestTrialsRequest = decode(request)?;
                    write_ok(&mut out, &OperationResponse { operation: self.op(false) })?;
                }
                Method::WaitOperation => {
                    self.wait_op_calls.fetch_add(1, Ordering::SeqCst);
                    write_err(
                        &mut out,
                        Status::InvalidArgument,
                        "unknown method id 18; closing connection",
                    )?;
                }
                Method::GetOperation => {
                    let polls = self.get_op_calls.fetch_add(1, Ordering::SeqCst) + 1;
                    let done = polls >= self.get_ops_until_done;
                    write_ok(&mut out, &OperationResponse { operation: self.op(done) })?;
                }
                other => panic!("unexpected method {other:?}"),
            }
            Ok(out)
        }
    }

    #[test]
    fn wait_falls_back_to_polling_on_old_servers() {
        let get_op_calls = Arc::new(AtomicU32::new(0));
        let wait_op_calls = Arc::new(AtomicU32::new(0));
        let mut client = VizierClient::for_study(
            Box::new(OldServerTransport {
                get_ops_until_done: 3,
                get_op_calls: Arc::clone(&get_op_calls),
                wait_op_calls: Arc::clone(&wait_op_calls),
            }),
            "studies/1",
            "c0",
        );
        let trials = client.get_suggestions(1).unwrap();
        assert_eq!(trials.len(), 1);
        assert!(!client.server_waits, "fallback must latch");

        // The next wait goes straight to polling: WaitOperation is
        // tried exactly once per client, ever.
        let trials = client.get_suggestions(1).unwrap();
        assert_eq!(trials.len(), 1);
        assert_eq!(wait_op_calls.load(Ordering::SeqCst), 1);
        assert!(get_op_calls.load(Ordering::SeqCst) >= 4);
    }

    /// The client-side rendering of the structured metrics must
    /// reproduce the legacy server-side text byte for byte — `vizier
    /// metrics` output is a compatibility surface.
    #[test]
    fn rendered_report_matches_server_text() {
        use crate::datastore::memory::InMemoryDatastore;
        use crate::pythia::runner::{default_registry, LocalPythia};
        use crate::pythia::supporter::DatastoreSupporter;
        use crate::wire::messages::GetServiceMetricsRequest;

        let ds = Arc::new(InMemoryDatastore::new());
        let supporter = Arc::new(DatastoreSupporter::new(
            Arc::clone(&ds) as Arc<dyn crate::datastore::Datastore>
        ));
        let pythia = Arc::new(LocalPythia::new(default_registry(), supporter));
        let svc = crate::service::api::VizierService::new(ds, pythia, 2);
        svc.metrics.record("SuggestTrials", 1500);
        svc.metrics.record("SuggestTrials", 2500);
        svc.metrics.record("CompleteTrial", 300);
        svc.metrics.record_error();
        svc.metrics.record_wait_wakeup(120);

        let resp = svc.get_service_metrics(GetServiceMetricsRequest::default()).unwrap();
        assert!(resp.report.is_empty(), "v2 servers leave rendering to the client");
        assert_eq!(super::render_metrics_report(&resp), svc.metrics.report());
    }
}

/// Convenience driver for the Code Block 1 loop: repeatedly fetch
/// suggestions, evaluate with `f`, and complete, for `budget` trials.
pub struct SuggestionLoop<'a> {
    pub client: &'a mut VizierClient,
    pub batch: usize,
}

impl<'a> SuggestionLoop<'a> {
    /// Runs the loop; `f` maps parameters to a final measurement, or Err
    /// for an infeasible evaluation.
    pub fn run<F>(&mut self, budget: usize, mut f: F) -> Result<usize, ClientError>
    where
        F: FnMut(&Trial) -> Result<Measurement, String>,
    {
        let mut completed = 0;
        while completed < budget {
            let want = self.batch.min(budget - completed);
            let suggestions = self.client.get_suggestions(want)?;
            if suggestions.is_empty() {
                break;
            }
            for trial in &suggestions {
                match f(trial) {
                    Ok(m) => {
                        self.client.complete_trial(trial.id, Some(&m))?;
                    }
                    Err(reason) => {
                        self.client.report_infeasible(trial.id, &reason)?;
                    }
                }
                completed += 1;
            }
        }
        Ok(completed)
    }
}
