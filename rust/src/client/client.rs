//! VizierClient — the user API of Code Block 1:
//!
//! ```text
//! client = VizierClient.load_or_create_study('cifar10', config, client_id)
//! while suggestions := client.get_suggestions(count=1):
//!     for trial in suggestions:
//!         metrics = _evaluate_trial(trial.parameters)
//!         client.complete_trial(metrics, trial_id=trial.id)
//! ```

use super::transport::{call, Transport};
use crate::pyvizier::{converters, Measurement, StudyConfig, Trial};
use crate::util::backoff::Backoff;
use crate::wire::framing::{FrameError, Method, Status};
use crate::wire::messages::*;
use std::time::{Duration, Instant};

/// Client-side errors.
#[derive(Debug)]
pub enum ClientError {
    Transport(String),
    Rpc { status: Status, message: String },
    OperationFailed(String, String),
    OperationTimeout(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Transport(msg) => write!(f, "transport failure: {msg}"),
            ClientError::Rpc { status, message } => write!(f, "rpc {status:?}: {message}"),
            ClientError::OperationFailed(op, msg) => {
                write!(f, "operation {op} failed on the server: {msg}")
            }
            ClientError::OperationTimeout(op) => {
                write!(f, "timed out waiting for operation {op}")
            }
        }
    }
}

impl std::error::Error for ClientError {}

impl From<FrameError> for ClientError {
    fn from(e: FrameError) -> Self {
        match e {
            FrameError::Rpc { status, message } => ClientError::Rpc { status, message },
            other => ClientError::Transport(other.to_string()),
        }
    }
}

/// A connected Vizier client bound to one study and one `client_id`.
pub struct VizierClient {
    transport: Box<dyn Transport>,
    pub study_name: String,
    pub client_id: String,
    /// Max time to wait for one suggestion operation.
    pub operation_timeout: Duration,
}

impl VizierClient {
    /// Load the study named `display_name`, creating it from `config` if it
    /// does not exist (the first replica creates; the rest load — §5).
    pub fn load_or_create_study(
        mut transport: Box<dyn Transport>,
        display_name: &str,
        config: &StudyConfig,
        client_id: &str,
    ) -> Result<Self, ClientError> {
        let lookup: Result<StudyResponse, FrameError> = call(
            transport.as_mut(),
            Method::LookupStudy,
            &LookupStudyRequest {
                display_name: display_name.to_string(),
            },
        );
        let study = match lookup {
            Ok(resp) => resp.study,
            Err(FrameError::Rpc {
                status: Status::NotFound,
                ..
            }) => {
                let create = CreateStudyRequest {
                    study: StudyProto {
                        display_name: display_name.to_string(),
                        spec: converters::study_config_to_proto(config),
                        ..Default::default()
                    },
                };
                match call::<_, _, StudyResponse>(transport.as_mut(), Method::CreateStudy, &create)
                {
                    Ok(resp) => resp.study,
                    // A parallel replica won the race: load theirs.
                    Err(FrameError::Rpc {
                        status: Status::FailedPrecondition,
                        ..
                    }) => {
                        call::<_, _, StudyResponse>(
                            transport.as_mut(),
                            Method::LookupStudy,
                            &LookupStudyRequest {
                                display_name: display_name.to_string(),
                            },
                        )?
                        .study
                    }
                    Err(e) => return Err(e.into()),
                }
            }
            Err(e) => return Err(e.into()),
        };
        Ok(Self {
            transport,
            study_name: study.name,
            client_id: client_id.to_string(),
            operation_timeout: Duration::from_secs(300),
        })
    }

    /// Connect to an existing study by resource name.
    pub fn for_study(transport: Box<dyn Transport>, study_name: &str, client_id: &str) -> Self {
        Self {
            transport,
            study_name: study_name.to_string(),
            client_id: client_id.to_string(),
            operation_timeout: Duration::from_secs(300),
        }
    }

    fn rpc<Req: crate::wire::codec::WireMessage, Resp: crate::wire::codec::WireMessage>(
        &mut self,
        method: Method,
        req: &Req,
    ) -> Result<Resp, ClientError> {
        Ok(call(self.transport.as_mut(), method, req)?)
    }

    /// Request `count` suggestions: issues SuggestTrials then polls
    /// GetOperation with backoff until done (the workflow of §3.2).
    /// Returns an empty vector only when the server reports a completed
    /// operation with no trials (e.g. exhausted grid).
    pub fn get_suggestions(&mut self, count: usize) -> Result<Vec<Trial>, ClientError> {
        let resp: OperationResponse = self.rpc(
            Method::SuggestTrials,
            &SuggestTrialsRequest {
                study_name: self.study_name.clone(),
                count: count as u64,
                client_id: self.client_id.clone(),
            },
        )?;
        let op = self.wait_operation(resp.operation)?;
        Ok(op.trials.iter().map(converters::trial_from_proto).collect())
    }

    fn wait_operation(&mut self, mut op: OperationProto) -> Result<OperationProto, ClientError> {
        let deadline = Instant::now() + self.operation_timeout;
        let mut backoff = Backoff::polling();
        while !op.done {
            if Instant::now() > deadline {
                return Err(ClientError::OperationTimeout(op.name));
            }
            std::thread::sleep(backoff.next_delay());
            let resp: OperationResponse = self.rpc(
                Method::GetOperation,
                &GetOperationRequest {
                    name: op.name.clone(),
                },
            )?;
            op = resp.operation;
        }
        if !op.error.is_empty() {
            return Err(ClientError::OperationFailed(op.name, op.error));
        }
        Ok(op)
    }

    /// Report an intermediate measurement (learning-curve point).
    pub fn add_measurement(
        &mut self,
        trial_id: u64,
        measurement: &Measurement,
    ) -> Result<(), ClientError> {
        let _: TrialResponse = self.rpc(
            Method::AddMeasurement,
            &AddMeasurementRequest {
                study_name: self.study_name.clone(),
                trial_id,
                measurement: converters::measurement_to_proto(measurement),
            },
        )?;
        Ok(())
    }

    /// Complete a trial with a final measurement.
    pub fn complete_trial(
        &mut self,
        trial_id: u64,
        final_measurement: Option<&Measurement>,
    ) -> Result<Trial, ClientError> {
        let resp: TrialResponse = self.rpc(
            Method::CompleteTrial,
            &CompleteTrialRequest {
                study_name: self.study_name.clone(),
                trial_id,
                final_measurement: final_measurement.map(converters::measurement_to_proto),
                infeasible: false,
                infeasibility_reason: String::new(),
            },
        )?;
        Ok(converters::trial_from_proto(&resp.trial))
    }

    /// Report a trial as infeasible (persistent failure — not retried).
    pub fn report_infeasible(&mut self, trial_id: u64, reason: &str) -> Result<(), ClientError> {
        let _: TrialResponse = self.rpc(
            Method::CompleteTrial,
            &CompleteTrialRequest {
                study_name: self.study_name.clone(),
                trial_id,
                final_measurement: None,
                infeasible: true,
                infeasibility_reason: reason.to_string(),
            },
        )?;
        Ok(())
    }

    /// Batched early stopping (Pythia v2): one operation judges many
    /// trials and returns a per-trial verdict. An empty `trial_ids` asks
    /// about every ACTIVE trial of the study — useful for a worker that
    /// owns several running trials and wants one RPC per wave instead of
    /// one per trial.
    pub fn check_early_stopping(
        &mut self,
        trial_ids: &[u64],
    ) -> Result<Vec<TrialStopDecision>, ClientError> {
        let resp: OperationResponse = self.rpc(
            Method::CheckEarlyStopping,
            &CheckEarlyStoppingRequest {
                study_name: self.study_name.clone(),
                trial_ids: trial_ids.to_vec(),
            },
        )?;
        let op = self.wait_operation(resp.operation)?;
        Ok(op.stop_decisions)
    }

    /// Ask whether a running trial should stop (Code Block 3): the
    /// single-trial convenience over [`Self::check_early_stopping`].
    pub fn should_trial_stop(&mut self, trial_id: u64) -> Result<bool, ClientError> {
        Ok(self
            .check_early_stopping(&[trial_id])?
            .iter()
            .find(|d| d.trial_id == trial_id)
            .map(|d| d.should_stop)
            .unwrap_or(false))
    }

    /// All trials of the study.
    pub fn list_trials(&mut self) -> Result<Vec<Trial>, ClientError> {
        let resp: ListTrialsResponse = self.rpc(
            Method::ListTrials,
            &ListTrialsRequest {
                study_name: self.study_name.clone(),
            },
        )?;
        Ok(resp.trials.iter().map(converters::trial_from_proto).collect())
    }

    /// The Pareto-optimal (or single-objective best) trials.
    pub fn list_optimal_trials(&mut self) -> Result<Vec<Trial>, ClientError> {
        let resp: ListTrialsResponse = self.rpc(
            Method::ListOptimalTrials,
            &ListOptimalTrialsRequest {
                study_name: self.study_name.clone(),
            },
        )?;
        Ok(resp.trials.iter().map(converters::trial_from_proto).collect())
    }

    /// The study's current configuration (including stored metadata).
    pub fn get_study_config(&mut self) -> Result<StudyConfig, ClientError> {
        let resp: StudyResponse = self.rpc(
            Method::GetStudy,
            &GetStudyRequest {
                name: self.study_name.clone(),
            },
        )?;
        Ok(converters::study_config_from_proto(
            &resp.study.display_name,
            &resp.study.spec,
        ))
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        let _: EmptyResponse = self.rpc(Method::Ping, &EmptyResponse::default())?;
        Ok(())
    }
}

/// Convenience driver for the Code Block 1 loop: repeatedly fetch
/// suggestions, evaluate with `f`, and complete, for `budget` trials.
pub struct SuggestionLoop<'a> {
    pub client: &'a mut VizierClient,
    pub batch: usize,
}

impl<'a> SuggestionLoop<'a> {
    /// Runs the loop; `f` maps parameters to a final measurement, or Err
    /// for an infeasible evaluation.
    pub fn run<F>(&mut self, budget: usize, mut f: F) -> Result<usize, ClientError>
    where
        F: FnMut(&Trial) -> Result<Measurement, String>,
    {
        let mut completed = 0;
        while completed < budget {
            let want = self.batch.min(budget - completed);
            let suggestions = self.client.get_suggestions(want)?;
            if suggestions.is_empty() {
                break;
            }
            for trial in &suggestions {
                match f(trial) {
                    Ok(m) => {
                        self.client.complete_trial(trial.id, Some(&m))?;
                    }
                    Err(reason) => {
                        self.client.report_infeasible(trial.id, &reason)?;
                    }
                }
                completed += 1;
            }
        }
        Ok(completed)
    }
}
