//! The user-facing client API (paper §5, Code Block 1).

#[allow(clippy::module_inception)]
pub mod client;
pub mod transport;

pub use client::{ClientError, SuggestionLoop, VizierClient};
pub use transport::{LocalTransport, TcpTransport, Transport};
