//! The user-facing client API (paper §5, Code Block 1).

pub mod client;
pub mod transport;

pub use client::{ClientError, SuggestionLoop, VizierClient};
pub use transport::{LocalTransport, TcpTransport, Transport};
