//! Fixed-size worker thread pool.
//!
//! The paper's reference server (Code Block 4) is a gRPC server over a
//! `futures.ThreadPoolExecutor(max_workers=100)`. This module is the Rust
//! equivalent used by [`crate::service::server`]: a bounded pool fed by an
//! MPMC queue (std `mpsc` receiver shared behind a mutex), with graceful
//! shutdown that drains queued jobs.

use crate::util::sync::{classes, Mutex};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size thread pool.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    active: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (>= 1).
    pub fn new(size: usize) -> Self {
        assert!(size > 0, "thread pool needs at least one worker");
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(&classes::TP_RECEIVER, receiver));
        let active = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                let act = Arc::clone(&active);
                std::thread::Builder::new()
                    .name(format!("vizier-worker-{i}"))
                    .spawn(move || loop {
                        // Holding the lock only while receiving keeps the
                        // queue MPMC without a dedicated crate.
                        let job = { rx.lock().recv() };
                        match job {
                            Ok(job) => {
                                act.fetch_add(1, Ordering::SeqCst);
                                // A panicking job must not kill the worker:
                                // catch and continue serving.
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                                act.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // all senders dropped: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            sender: Some(sender),
            workers,
            active,
        }
    }

    /// Submit a job. Never blocks (unbounded queue).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("worker threads gone");
    }

    /// Number of jobs currently executing.
    pub fn active_count(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Drain the queue and join all workers.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;
    use std::time::Duration;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU32::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn jobs_run_concurrently() {
        let pool = ThreadPool::new(4);
        let (tx, rx) = mpsc::channel();
        // Four blocking jobs that each wait for a token; if the pool were
        // serial, the test would deadlock on the barrier below.
        let barrier = Arc::new(std::sync::Barrier::new(5));
        for _ in 0..4 {
            let b = Arc::clone(&barrier);
            let tx = tx.clone();
            pool.execute(move || {
                b.wait();
                tx.send(()).unwrap();
            });
        }
        barrier.wait();
        for _ in 0..4 {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        pool.shutdown();
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(1);
        pool.execute(|| panic!("boom"));
        let done = Arc::new(AtomicU32::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.store(1, Ordering::SeqCst);
        });
        pool.shutdown();
        assert_eq!(done.load(Ordering::SeqCst), 1, "worker kept serving after panic");
    }

    #[test]
    fn shutdown_drains_queue() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU32::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(Duration::from_micros(100));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown(); // must wait for all 50
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }
}
