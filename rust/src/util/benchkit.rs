//! Minimal benchmark harness (criterion substitute; the vendored registry
//! has no criterion — DESIGN.md §3). Used by the `harness = false` bench
//! binaries in rust/benches/.
//!
//! Measures wall time over adaptive iteration counts with warmup and
//! prints criterion-style lines: name, mean, p50, p95, throughput.

use crate::util::time::Stopwatch;
use std::time::Duration;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }
}

/// Measure `f`, choosing an iteration count that fills ~`budget`.
pub fn bench_with_budget(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // Warmup + calibration.
    let sw = Stopwatch::start();
    f();
    let first = sw.elapsed().max(Duration::from_nanos(100));
    let target_iters = (budget.as_secs_f64() / first.as_secs_f64()).clamp(5.0, 10_000.0) as u64;

    let mut samples = Vec::with_capacity(target_iters as usize);
    for _ in 0..target_iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    let result = BenchResult {
        name: name.to_string(),
        iters: target_iters,
        mean,
        p50: samples[samples.len() / 2],
        p95: samples[samples.len() * 95 / 100],
    };
    println!(
        "{:<52} {:>10} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}",
        result.name, result.iters, result.mean, result.p50, result.p95
    );
    result
}

/// Default budget (~0.6 s per case).
pub fn bench(name: &str, f: impl FnMut()) -> BenchResult {
    bench_with_budget(name, Duration::from_millis(600), f)
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a free-form summary line (picked up by EXPERIMENTS.md).
pub fn note(text: &str) {
    println!("    {text}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench_with_budget("noop", Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 5);
        assert!(r.p50 <= r.p95);
        assert!(r.mean_us() < 1e5);
    }
}
