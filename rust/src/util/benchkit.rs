//! Minimal benchmark harness (criterion substitute; the vendored registry
//! has no criterion — DESIGN.md §3). Used by the `harness = false` bench
//! binaries in rust/benches/.
//!
//! Measures wall time over adaptive iteration counts with warmup and
//! prints criterion-style lines: name, mean, p50, p95, throughput.
//!
//! # Machine-readable artifacts
//!
//! Every [`bench`] result, [`note`], and [`check`] verdict is also
//! recorded in a process-global collector; a bench binary ends with
//! [`finish("NAME")`](finish), which writes `BENCH_<NAME>.json` at the
//! repo root (CI uploads these as artifacts so the perf trajectory is
//! visible across runs). [`check`] centralizes the comparison-assertion
//! policy: verdicts are *enforced* (a failure panics, after the JSON is
//! written) unless `OSSVIZIER_BENCH_LAX` is set, which downgrades
//! failures to warnings for noisy shared runners. The nightly soak job
//! runs without the variable so the comparisons stay enforced somewhere.

use crate::util::json::Json;
use crate::util::sync::{classes, Mutex};
use crate::util::time::Stopwatch;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::Duration;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }

    pub fn mean_ns(&self) -> f64 {
        self.mean.as_secs_f64() * 1e9
    }
}

/// Outcome of one [`check`] comparison.
#[derive(Debug, Clone)]
pub struct Verdict {
    pub label: String,
    pub pass: bool,
    /// False when `OSSVIZIER_BENCH_LAX` downgraded this to advisory.
    pub enforced: bool,
    pub detail: String,
}

#[derive(Debug, Default)]
struct Collector {
    results: Vec<BenchResult>,
    notes: Vec<String>,
    verdicts: Vec<Verdict>,
}

fn collector() -> &'static Mutex<Collector> {
    static C: OnceLock<Mutex<Collector>> = OnceLock::new();
    C.get_or_init(|| Mutex::new(&classes::BENCH_COLLECTOR, Collector::default()))
}

/// True when `OSSVIZIER_BENCH_LAX` is set: timing comparisons report
/// without failing (shared CI runners are too noisy to enforce them).
pub fn lax() -> bool {
    std::env::var_os("OSSVIZIER_BENCH_LAX").is_some()
}

/// Measure `f`, choosing an iteration count that fills ~`budget`.
pub fn bench_with_budget(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // Warmup + calibration.
    let sw = Stopwatch::start();
    f();
    let first = sw.elapsed().max(Duration::from_nanos(100));
    let target_iters = (budget.as_secs_f64() / first.as_secs_f64()).clamp(5.0, 10_000.0) as u64;

    let mut samples = Vec::with_capacity(target_iters as usize);
    for _ in 0..target_iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    let result = BenchResult {
        name: name.to_string(),
        iters: target_iters,
        mean,
        p50: samples[samples.len() / 2],
        p95: samples[samples.len() * 95 / 100],
    };
    println!(
        "{:<52} {:>10} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}",
        result.name, result.iters, result.mean, result.p50, result.p95
    );
    collector().lock().results.push(result.clone());
    result
}

/// Default budget (~0.6 s per case).
pub fn bench(name: &str, f: impl FnMut()) -> BenchResult {
    bench_with_budget(name, Duration::from_millis(600), f)
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a free-form summary line (picked up by EXPERIMENTS.md and the
/// JSON artifact).
pub fn note(text: &str) {
    println!("    {text}");
    collector().lock().notes.push(text.to_string());
}

/// Record a comparison verdict (e.g. "pooled >= legacy throughput").
///
/// The verdict lands in the JSON artifact either way. Failures panic at
/// [`finish`] — after the artifact is written — unless
/// `OSSVIZIER_BENCH_LAX` is set.
pub fn check(label: &str, pass: bool, detail: &str) {
    let enforced = !lax();
    collector().lock().verdicts.push(Verdict {
        label: label.to_string(),
        pass,
        enforced,
        detail: detail.to_string(),
    });
    if pass {
        note(&format!("PASS  {label}: {detail}"));
    } else if enforced {
        note(&format!("FAIL  {label}: {detail}"));
    } else {
        note(&format!("WARN  {label}: {detail} (lax mode, not failing)"));
    }
}

/// Like [`check`] but never downgraded by `OSSVIZIER_BENCH_LAX`: for
/// structural assertions (thread budgets, leak checks) that do not
/// depend on runner timing and must hold everywhere.
pub fn check_strict(label: &str, pass: bool, detail: &str) {
    collector().lock().verdicts.push(Verdict {
        label: label.to_string(),
        pass,
        enforced: true,
        detail: detail.to_string(),
    });
    if pass {
        note(&format!("PASS  {label}: {detail}"));
    } else {
        note(&format!("FAIL  {label}: {detail}"));
    }
}

/// Where `BENCH_<name>.json` lands: `OSSVIZIER_BENCH_DIR` if set, else
/// the repo root (the parent of the cargo manifest dir), else cwd.
fn artifact_path(name: &str) -> PathBuf {
    let file = format!("BENCH_{name}.json");
    if let Some(dir) = std::env::var_os("OSSVIZIER_BENCH_DIR") {
        return PathBuf::from(dir).join(file);
    }
    match std::env::var("CARGO_MANIFEST_DIR")
        .ok()
        .or_else(|| option_env!("CARGO_MANIFEST_DIR").map(String::from))
    {
        Some(m) => PathBuf::from(m).join("..").join(file),
        None => PathBuf::from(file),
    }
}

/// Write the collected results/notes/verdicts to `BENCH_<name>.json` and
/// fail the bench (panic) if any enforced verdict did not pass. Call
/// exactly once, at the end of each bench binary's `main`.
pub fn finish(name: &str) {
    let collected = std::mem::take(&mut *collector().lock());
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str(name.to_string()));
    root.insert(
        "generated_unix_ms".to_string(),
        Json::Num(crate::util::time::epoch_millis() as f64),
    );
    root.insert("lax".to_string(), Json::Bool(lax()));
    root.insert(
        "results".to_string(),
        Json::Arr(
            collected
                .results
                .iter()
                .map(|r| {
                    let mut o = BTreeMap::new();
                    o.insert("name".to_string(), Json::Str(r.name.clone()));
                    o.insert("iters".to_string(), Json::Num(r.iters as f64));
                    o.insert("ns_per_op".to_string(), Json::Num(r.mean_ns()));
                    o.insert(
                        "p50_ns".to_string(),
                        Json::Num(r.p50.as_secs_f64() * 1e9),
                    );
                    o.insert(
                        "p95_ns".to_string(),
                        Json::Num(r.p95.as_secs_f64() * 1e9),
                    );
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    root.insert(
        "notes".to_string(),
        Json::Arr(collected.notes.iter().map(|n| Json::Str(n.clone())).collect()),
    );
    root.insert(
        "verdicts".to_string(),
        Json::Arr(
            collected
                .verdicts
                .iter()
                .map(|v| {
                    let mut o = BTreeMap::new();
                    o.insert("label".to_string(), Json::Str(v.label.clone()));
                    o.insert("pass".to_string(), Json::Bool(v.pass));
                    o.insert("enforced".to_string(), Json::Bool(v.enforced));
                    o.insert("detail".to_string(), Json::Str(v.detail.clone()));
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    let path = artifact_path(name);
    match std::fs::write(&path, Json::Obj(root).to_string()) {
        Ok(()) => println!("\nbench artifact: {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }
    let failed: Vec<&Verdict> =
        collected.verdicts.iter().filter(|v| v.enforced && !v.pass).collect();
    if !failed.is_empty() {
        let labels: Vec<&str> = failed.iter().map(|v| v.label.as_str()).collect();
        panic!("{} enforced bench verdict(s) failed: {}", failed.len(), labels.join(", "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench_with_budget("noop", Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 5);
        assert!(r.p50 <= r.p95);
        assert!(r.mean_us() < 1e5);
    }

    #[test]
    fn finish_writes_artifact_and_enforces_verdicts() {
        let dir = std::env::temp_dir()
            .join(format!("ossvizier-benchkit-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("OSSVIZIER_BENCH_DIR", &dir);

        note("a note");
        check("always-true", true, "1 <= 2");
        finish("SELFTEST");
        let raw = std::fs::read_to_string(dir.join("BENCH_SELFTEST.json")).unwrap();
        assert!(raw.contains("\"bench\""), "{raw}");
        assert!(raw.contains("always-true"), "{raw}");
        assert!(raw.contains("a note"), "{raw}");

        // A failing enforced verdict panics at finish — after writing.
        // (Skipped under OSSVIZIER_BENCH_LAX, which downgrades failures.)
        if !lax() {
            check("always-false", false, "2 <= 1");
            let panicked = std::panic::catch_unwind(|| finish("SELFTEST_FAIL")).is_err();
            assert!(panicked, "enforced failure must fail the bench");
            assert!(dir.join("BENCH_SELFTEST_FAIL.json").exists());
        }
        std::env::remove_var("OSSVIZIER_BENCH_DIR");
    }
}
