//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand`, so the service carries its own
//! small, well-tested PRNG stack: [`SplitMix64`] for seeding and
//! [`Pcg32`] (PCG-XSH-RR 64/32, O'Neill 2014) as the workhorse generator.
//! Everything in the library that needs randomness takes a `&mut Pcg32`
//! so studies are exactly reproducible from a `u64` seed.

/// SplitMix64: used to expand a single user seed into stream seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: small state, good statistical quality, trivially
/// seedable per-stream (each (seed, stream) pair is an independent sequence).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed from a single value (stream derived via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = sm.next_u64();
        let stream = sm.next_u64();
        Self::new(s, stream)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire-style rejection).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below bound must be positive");
        // Rejection sampling on the top of the range.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "int_range requires lo <= hi");
        let span = hi as i128 - lo as i128 + 1;
        if span > u64::MAX as i128 {
            // Full 64-bit range: every u64 maps to a valid value.
            return self.next_u64() as i64;
        }
        lo.wrapping_add(self.next_below(span as u64) as i64)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli trial.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Uniformly pick a reference from a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose from empty slice");
        &xs[self.next_below(xs.len() as u64) as usize]
    }

    /// Pick an index with probability proportional to `weights`.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index needs positive total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values from the canonical splitmix64 implementation.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn pcg_deterministic_per_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg32::seeded(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(va, vc);
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg32::new(7, 1);
        let mut b = Pcg32::new(7, 2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x), "{x} out of [0,1)");
        }
    }

    #[test]
    fn f64_mean_is_near_half() {
        let mut rng = Pcg32::seeded(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_bounds() {
        let mut rng = Pcg32::seeded(3);
        for bound in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(rng.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn int_range_inclusive_and_covers() {
        let mut rng = Pcg32::seeded(4);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            let v = rng.int_range(-2, 2);
            assert!((-2..=2).contains(&v));
            seen[(v + 2) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values hit");
    }

    #[test]
    fn int_range_extremes_no_overflow() {
        let mut rng = Pcg32::seeded(5);
        for _ in 0..100 {
            let v = rng.int_range(i64::MIN, i64::MAX);
            let _ = v; // any value valid; must not panic/overflow
        }
        assert_eq!(rng.int_range(7, 7), 7);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(7);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Pcg32::seeded(8);
        let w = [0.0, 10.0, 0.0];
        for _ in 0..100 {
            assert_eq!(rng.weighted_index(&w), 1);
        }
        // Statistical check on a 1:3 split.
        let w = [1.0, 3.0];
        let hits1 = (0..20_000).filter(|_| rng.weighted_index(&w) == 1).count();
        let frac = hits1 as f64 / 20_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::seeded(9);
        for _ in 0..100 {
            let s = rng.sample_indices(20, 10);
            assert_eq!(s.len(), 10);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 10, "indices distinct");
            assert!(s.iter().all(|&i| i < 20));
        }
    }
}
