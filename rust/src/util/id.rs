//! Unique id generation for operations and resource names.

use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(1);

/// Next process-unique monotonically increasing id.
pub fn next_uid() -> u64 {
    COUNTER.fetch_add(1, Ordering::Relaxed)
}

/// A short, human-readable unique token: epoch-millis + process counter.
/// Sufficient for resource names inside one service instance; durable
/// uniqueness across restarts comes from the datastore's max-id recovery.
pub fn unique_token(prefix: &str) -> String {
    format!("{prefix}-{}-{}", crate::util::time::epoch_millis(), next_uid())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn uids_are_unique_across_threads() {
        let handles: Vec<_> = (0..8)
            .map(|_| std::thread::spawn(|| (0..1000).map(|_| next_uid()).collect::<Vec<u64>>()))
            .collect();
        let mut all = HashSet::new();
        for h in handles {
            for id in h.join().unwrap() {
                assert!(all.insert(id), "duplicate uid {id}");
            }
        }
        assert_eq!(all.len(), 8000);
    }

    #[test]
    fn tokens_have_prefix_and_differ() {
        let a = unique_token("op");
        let b = unique_token("op");
        assert!(a.starts_with("op-"));
        assert_ne!(a, b);
    }
}
