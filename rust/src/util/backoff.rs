//! Exponential backoff with decorrelated jitter, used by the client when
//! polling long-running operations and retrying transient RPC failures
//! (paper §3.2: clients poll `GetOperation` until done).

use crate::util::rng::Pcg32;
use std::time::Duration;

/// Exponential backoff policy with jitter.
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    max: Duration,
    factor: f64,
    current: Duration,
    rng: Pcg32,
}

impl Backoff {
    pub fn new(base: Duration, max: Duration) -> Self {
        Self {
            base,
            max,
            factor: 1.7,
            current: base,
            rng: Pcg32::seeded(0x0bac_c0ff),
        }
    }

    /// Default polling policy: 2ms -> 250ms.
    pub fn polling() -> Self {
        Self::new(Duration::from_millis(2), Duration::from_millis(250))
    }

    /// Default retry policy: 10ms -> 2s.
    pub fn retry() -> Self {
        Self::new(Duration::from_millis(10), Duration::from_secs(2))
    }

    /// Next delay: the deterministic ceiling grows exponentially (capped at
    /// `max`); the returned delay is jittered uniformly in
    /// `[ceiling/2, ceiling]` so concurrent pollers desynchronize.
    pub fn next_delay(&mut self) -> Duration {
        let ceiling = (self.current.as_secs_f64() * self.factor).min(self.max.as_secs_f64());
        self.current = Duration::from_secs_f64(ceiling.max(self.base.as_secs_f64()));
        let jittered = self.rng.f64_range(ceiling / 2.0, ceiling);
        Duration::from_secs_f64(jittered)
    }

    /// Reset to the base delay (after a success).
    pub fn reset(&mut self) {
        self.current = self.base;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_and_caps() {
        let mut b = Backoff::new(Duration::from_millis(1), Duration::from_millis(50));
        let mut last = Duration::ZERO;
        for _ in 0..64 {
            last = b.next_delay();
            assert!(last <= Duration::from_millis(50));
            assert!(last >= Duration::from_micros(500));
        }
        // After many iterations we should be near the cap more often than not.
        let mut near_cap = 0;
        for _ in 0..32 {
            if b.next_delay() > Duration::from_millis(25) {
                near_cap += 1;
            }
        }
        assert!(near_cap > 8, "near_cap={near_cap}, last={last:?}");
    }

    #[test]
    fn reset_returns_to_base() {
        let mut b = Backoff::retry();
        for _ in 0..10 {
            b.next_delay();
        }
        b.reset();
        assert!(b.next_delay() <= Duration::from_millis(20));
    }
}
