//! Tiny command-line argument parser (the vendored registry has no `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Used by the `vizier-server` launcher and the examples.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Option keys that take a value (needed to disambiguate `--k v`).
    value_keys: Vec<String>,
}

/// Declarative spec for one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

impl Args {
    /// Parse `argv[1..]` given the set of options that take values.
    pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args, String> {
        let mut args = Args {
            value_keys: specs
                .iter()
                .filter(|s| s.takes_value)
                .map(|s| s.name.to_string())
                .collect(),
            ..Default::default()
        };
        let known: Vec<&str> = specs.iter().map(|s| s.name).collect();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // "--" terminates option parsing.
                    args.positional.extend(it.cloned());
                    break;
                }
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if !known.contains(&key.as_str()) {
                    return Err(format!("unknown option --{key}"));
                }
                if args.value_keys.contains(&key) {
                    let value = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| format!("option --{key} requires a value"))?,
                    };
                    args.options.insert(key, value);
                } else {
                    if inline.is_some() {
                        return Err(format!("flag --{key} does not take a value"));
                    }
                    args.flags.push(key);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} must be an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} must be a number, got {v:?}")),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Render a help string from specs.
pub fn usage(bin: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("usage: {bin} [options]\n\noptions:\n");
    for spec in specs {
        let arg = if spec.takes_value { " <value>" } else { "" };
        s.push_str(&format!("  --{}{:<12} {}\n", spec.name, arg, spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "host", takes_value: true, help: "host" },
            OptSpec { name: "port", takes_value: true, help: "port" },
            OptSpec { name: "verbose", takes_value: false, help: "verbose" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::parse(&sv(&["--host", "h", "--port=99", "--verbose", "pos1"]), &specs()).unwrap();
        assert_eq!(a.get("host"), Some("h"));
        assert_eq!(a.get_u64("port", 0).unwrap(), 99);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]), &specs()).unwrap();
        assert_eq!(a.get_or("host", "localhost"), "localhost");
        assert_eq!(a.get_u64("port", 6006).unwrap(), 6006);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(Args::parse(&sv(&["--bogus"]), &specs()).is_err());
        assert!(Args::parse(&sv(&["--port"]), &specs()).is_err());
        assert!(Args::parse(&sv(&["--verbose=1"]), &specs()).is_err());
        assert!(Args::parse(&sv(&["--port", "abc"]), &specs())
            .unwrap()
            .get_u64("port", 0)
            .is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = Args::parse(&sv(&["--", "--host", "x"]), &specs()).unwrap();
        assert_eq!(a.positional, vec!["--host", "x"]);
    }
}
