//! Time helpers: epoch timestamps and a monotonic stopwatch.

use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Milliseconds since the Unix epoch.
pub fn epoch_millis() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Microseconds since the Unix epoch.
pub fn epoch_micros() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// Monotonic stopwatch for latency measurement.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_micros(&self) -> u64 {
        self.elapsed().as_micros() as u64
    }

    pub fn elapsed_millis_f64(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_is_sane() {
        let ms = epoch_millis();
        // After 2020-01-01 and before 2100.
        assert!(ms > 1_577_836_800_000);
        assert!(ms < 4_102_444_800_000);
        assert!(epoch_micros() >= ms * 1000 - 1_000_000);
    }

    #[test]
    fn stopwatch_monotonic() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        let e1 = sw.restart();
        assert!(e1 >= Duration::from_millis(4));
        let e2 = sw.elapsed();
        assert!(e2 < e1 + Duration::from_secs(1));
    }
}
