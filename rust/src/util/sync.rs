//! Crate-local synchronization primitives with a lockdep-style runtime
//! lock-order detector.
//!
//! Every `Mutex`/`RwLock`/`Condvar` in this crate goes through these
//! wrappers instead of `std::sync` directly (`vizier-lint` enforces
//! that). Each lock is constructed against a static [`LockClass`] — a
//! name plus a rank in the crate-wide lock hierarchy declared in
//! [`classes`] — so the acquisition order that the module docs used to
//! describe in prose is machine-checked every time a debug build or an
//! `OSSVIZIER_LOCKDEP=1` process takes a lock.
//!
//! # What the detector checks
//!
//! A thread-local stack records the classes of every lock the current
//! thread holds. On each acquisition of a lock of class `B` while
//! holding a lock of class `A`:
//!
//! * **Declared hierarchy**: `B.rank` must be strictly greater than
//!   `A.rank` — locks are only ever taken "downward" along the ranks
//!   declared in [`classes`]. Taking two locks of the same class (equal
//!   rank) nested is also a violation: no code path in this crate
//!   legally holds two shards, two lanes, etc. at once.
//! * **Observed-order graph**: the edge `A -> B` is recorded in a
//!   process-global order graph. If a path `B -> ... -> A` was observed
//!   before — i.e. this acquisition closes a cycle — the detector
//!   panics naming both classes, even if the ranks were somehow
//!   consistent. This is the classic lockdep invariant: a deadlock only
//!   needs the *potential* for inversion, not the unlucky interleaving,
//!   so one single-threaded pass through both orders is enough to catch
//!   it.
//!
//! Violations panic with both class names; the panic message is stable
//! enough for `tests/lockdep.rs` to assert on.
//!
//! # Cost model
//!
//! Release builds without `OSSVIZIER_LOCKDEP=1` pay one load of a
//! lazily-initialized boolean and a predictable branch per acquisition
//! — no thread-local traffic, no allocation, no global lock. The
//! C-DS-MT / C-FRONTEND benches gate this: the shim must be
//! indistinguishable from raw `std::sync` when the detector is off.
//! Debug builds (`cfg(debug_assertions)`) run the detector by default;
//! `OSSVIZIER_LOCKDEP=0` force-disables it there.
//!
//! # Poisoning
//!
//! The wrappers do not propagate `std::sync` poisoning (the same choice
//! `parking_lot` makes): a panicking holder does not wedge every later
//! acquisition behind a `PoisonError`. The crate's cross-thread failure
//! paths have explicit protocols instead — the WAL committer's sticky
//! error, the service's drain flag, the front-end's shutdown drain —
//! and the worker pools already `catch_unwind` their jobs.
//!
//! The full hierarchy, with the code paths that pin each edge, is
//! documented in `rust/docs/INVARIANTS.md`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::sync::{Condvar as StdCondvar, Mutex as StdMutex, RwLock as StdRwLock};
use std::sync::{
    MutexGuard as StdMutexGuard, OnceLock, PoisonError, RwLockReadGuard as StdRwLockReadGuard,
    RwLockWriteGuard as StdRwLockWriteGuard,
};
use std::time::Duration;

pub use std::sync::WaitTimeoutResult;

// ---------------------------------------------------------------------------
// Lock classes
// ---------------------------------------------------------------------------

/// A static identity for every lock of one kind: a stable name (used in
/// violation panics) and a rank in the crate-wide hierarchy. Locks of
/// the same class share ordering constraints; locks of different
/// classes may only be nested in strictly increasing rank order.
#[derive(Debug)]
pub struct LockClass {
    pub name: &'static str,
    pub rank: u32,
}

impl LockClass {
    pub const fn new(name: &'static str, rank: u32) -> Self {
        Self { name, rank }
    }

    /// Identity key: the address of the static. Classes are compared by
    /// identity, not name, so two classes can share a rank band without
    /// colliding in the order graph.
    fn key(&'static self) -> usize {
        self as *const LockClass as usize
    }
}

/// The crate-wide lock hierarchy — every lock in the tree registers
/// against one of these classes, so the whole acquisition order is
/// declared (and reviewable) in this single table. Rank gaps are left
/// for future layers. See `rust/docs/INVARIANTS.md` for the observed
/// edges that pin each relation.
pub mod classes {
    use super::LockClass;

    // --- Service layer (outermost: held while calling into the
    // datastore, never the reverse) -------------------------------------
    /// Per-study suggest coalescing queues ([`crate::service::api`]).
    /// Claims are taken and dropped before any datastore call.
    pub static SVC_COALESCE: LockClass = LockClass::new("service.coalesce", 100);
    /// `WaitOperation` watcher registry. Held across a datastore *read*
    /// (`watch_operation`'s race-free check-then-arm), hence ranked
    /// above nothing and below every datastore lock.
    pub static SVC_WAITERS: LockClass = LockClass::new("service.op_waiters", 110);
    /// The policy worker pool handle ([`crate::service::api`]).
    pub static SVC_WORKERS: LockClass = LockClass::new("service.worker_pool", 120);

    // --- Front-end (event loop + worker pool) ---------------------------
    /// Parked-connection registry (deferred responses / write parking).
    /// Ranked before the job queue: completion hooks may hold a slot
    /// entry while re-queueing its connection.
    pub static FE_SLOTS: LockClass = LockClass::new("frontend.park_slots", 130);
    /// Bounded ready-request queue feeding the worker pool.
    pub static FE_QUEUE: LockClass = LockClass::new("frontend.job_queue", 140);
    /// Per-connection v2 correlation table (in-flight ids, cancel hooks,
    /// in-flight count). Taken by workers/completers around a terminal
    /// send and by the event loop on `CANCEL`; nests *inside* the service
    /// watcher registry (streaming watchers send under `SVC_WAITERS`) and
    /// *outside* the connection's out-buffer.
    pub static FE_MUX_CORR: LockClass = LockClass::new("frontend.mux_corrs", 150);
    /// Per-connection v2 shared out-buffer + write half. Innermost
    /// front-end lock: nothing is acquired while holding it.
    pub static FE_MUX_OUT: LockClass = LockClass::new("frontend.mux_out", 160);

    // --- Durable store (WAL) --------------------------------------------
    /// Commit gate: writers share it for read around apply + enqueue;
    /// the single-file `compact()` takes it for write. Outermost lock of
    /// the commit path.
    pub static WAL_COMMIT_GATE: LockClass = LockClass::new("wal.commit_gate", 200);
    /// Committer work/durability state (`pending`/`durable`/`error`).
    /// `compact_single_file` holds it while polling the lanes for
    /// drained-ness, so it ranks above the gate and below the lanes.
    pub static WAL_WORK: LockClass = LockClass::new("wal.commit_work", 210);
    /// Per-shard commit lanes. The lane lock spans the in-memory apply,
    /// so it ranks below the datastore locks the apply takes.
    pub static WAL_LANE: LockClass = LockClass::new("wal.commit_lane", 220);
    /// The active log segment writer. The serial commit path applies
    /// under it, and the single-file compactor snapshots under it, so
    /// like the lane it ranks above the in-memory datastore locks.
    pub static WAL_LOG: LockClass = LockClass::new("wal.log_writer", 230);

    // --- In-memory datastore (innermost data locks) ---------------------
    /// Display-name directory. Always taken before the shard it is
    /// protecting an insert into (`create_study`, `apply_put_study`).
    pub static DS_DIRECTORY: LockClass = LockClass::new("datastore.directory", 240);
    /// One state shard. Never nested with another shard; cross-shard
    /// scans take them one at a time.
    pub static DS_SHARD: LockClass = LockClass::new("datastore.shard", 250);
    /// Graveyard of retired copy-on-write shard images awaiting
    /// reclamation. Taken by a publishing writer *under* the shard write
    /// lock (and by `ImageCell::drop`), never the other way around.
    pub static DS_IMAGE: LockClass = LockClass::new("datastore.image_retire", 255);

    // --- Background compaction ------------------------------------------
    /// Compactor request/completion state. Requested from the serial
    /// commit path while the gate is still held (`maybe_auto_compact`),
    /// never held while touching the log or the shards.
    pub static WAL_COMPACTOR: LockClass = LockClass::new("wal.compactor", 260);

    // --- Leaf locks (instrumentation, transports, pools) ----------------
    /// Per-method histogram registry; held while linking the front-end
    /// and WAL metric blocks into a report.
    pub static MET_METHODS: LockClass = LockClass::new("metrics.methods", 300);
    /// Link to the front-end metrics block.
    pub static MET_FRONTEND: LockClass = LockClass::new("metrics.frontend_link", 310);
    /// Link to the datastore (snapshot/contention) metrics block.
    pub static MET_DATASTORE: LockClass = LockClass::new("metrics.datastore_link", 315);
    /// Link to the WAL metrics block.
    pub static MET_WAL: LockClass = LockClass::new("metrics.wal_link", 320);
    /// PythiaServer's pooled API-server connections (popped before a
    /// policy run, pushed back after; never held across the run).
    pub static RP_SUPPORTERS: LockClass = LockClass::new("pythia.supporter_pool", 325);
    /// RemoteSupporter's transport (one in-flight round trip at a time).
    pub static RP_TRANSPORT: LockClass = LockClass::new("pythia.remote_transport", 330);
    /// Client-side wire-v2 demux table (correlation id → waiting
    /// receiver). Ranked above `RP_TRANSPORT` because `RemoteSupporter`
    /// holds its transport lock across `call_raw`, which reaches the mux
    /// when the API server negotiated v2.
    pub static CL_MUX_PENDING: LockClass = LockClass::new("client.mux_pending", 332);
    /// Client-side wire-v2 shared write half (whole frames only, so
    /// concurrent callers never interleave partial frames).
    pub static CL_MUX_WRITER: LockClass = LockClass::new("client.mux_writer", 334);
    /// RemotePythia's lazily-connected stream pair.
    pub static RP_CONN: LockClass = LockClass::new("pythia.remote_conn", 340);
    /// Legacy thread-per-connection registry ([`crate::service::server`]).
    pub static LEGACY_CONNS: LockClass = LockClass::new("frontend.legacy_conns", 350);
    /// Worker-pool MPMC receiver ([`crate::util::threadpool`]).
    pub static TP_RECEIVER: LockClass = LockClass::new("threadpool.receiver", 360);
    /// PJRT worker job channel ([`crate::runtime::registry`]).
    pub static RT_PJRT: LockClass = LockClass::new("runtime.pjrt_sender", 370);
    /// Benchmark result collector ([`crate::util::benchkit`]).
    pub static BENCH_COLLECTOR: LockClass = LockClass::new("benchkit.collector", 380);
    /// Trace ring registry ([`crate::util::trace`]): taken when a thread
    /// records its first span — which can happen under any lock above
    /// (WAL lanes, shards, mux out-buffers) — so it is a leaf.
    pub static TRACE_REGISTRY: LockClass = LockClass::new("trace.registry", 390);
}

// ---------------------------------------------------------------------------
// Detector state
// ---------------------------------------------------------------------------

/// Whether the detector is active for this process. Decided once: the
/// `OSSVIZIER_LOCKDEP` variable wins when set (`0`/empty disables, any
/// other value enables); otherwise debug builds are on and release
/// builds are off.
pub fn lockdep_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| match std::env::var("OSSVIZIER_LOCKDEP") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => cfg!(debug_assertions),
    })
}

/// The global observed-order graph: `edges[a]` holds every class `b`
/// that was acquired while `a` was held, plus the class table for
/// rendering panics. Guarded by a raw `std::sync` mutex — this is the
/// one lock in the crate that cannot go through the shim, and nothing
/// is ever acquired while it is held.
struct OrderGraph {
    edges: HashMap<usize, Vec<usize>>,
    names: HashMap<usize, &'static LockClass>,
}

fn graph() -> &'static StdMutex<OrderGraph> {
    static G: OnceLock<StdMutex<OrderGraph>> = OnceLock::new();
    G.get_or_init(|| {
        StdMutex::new(OrderGraph {
            edges: HashMap::new(),
            names: HashMap::new(),
        })
    })
}

thread_local! {
    /// Classes of the locks this thread currently holds, in acquisition
    /// order.
    static HELD: RefCell<Vec<&'static LockClass>> = const { RefCell::new(Vec::new()) };
}

/// Is there a path `from -> ... -> to` in the observed-order graph?
fn has_path(g: &OrderGraph, from: usize, to: usize) -> bool {
    let mut stack = vec![from];
    let mut seen: Vec<usize> = Vec::new();
    while let Some(n) = stack.pop() {
        if n == to {
            return true;
        }
        if seen.contains(&n) {
            continue;
        }
        seen.push(n);
        if let Some(next) = g.edges.get(&n) {
            stack.extend(next.iter().copied());
        }
    }
    false
}

/// Validate one acquisition against the held stack and the global
/// graph, record the new edges, and push the class. Returns true when
/// the acquisition was tracked (so the matching release knows to pop).
fn lockdep_acquire(class: &'static LockClass) -> bool {
    if !lockdep_enabled() {
        return false;
    }
    let held: Vec<&'static LockClass> = HELD.with(|h| h.borrow().clone());
    if !held.is_empty() {
        let mut g = graph().lock().unwrap_or_else(PoisonError::into_inner);
        g.names.entry(class.key()).or_insert(class);
        for prev in &held {
            g.names.entry(prev.key()).or_insert(prev);
            // Cycle check first so inversions of an *observed* order get
            // the more informative message.
            if has_path(&g, class.key(), prev.key()) {
                drop(g);
                panic!(
                    "lockdep: lock order inversion: acquiring '{}' (rank {}) while holding \
                     '{}' (rank {}), but the opposite order '{}' -> '{}' was previously \
                     observed — this cycle in the lock-order graph can deadlock",
                    class.name, class.rank, prev.name, prev.rank, class.name, prev.name
                );
            }
            if class.rank <= prev.rank {
                drop(g);
                panic!(
                    "lockdep: declared-hierarchy violation: acquiring '{}' (rank {}) while \
                     holding '{}' (rank {}); locks must be taken in strictly increasing \
                     rank order (see util::sync::classes)",
                    class.name, class.rank, prev.name, prev.rank
                );
            }
            let e = g.edges.entry(prev.key()).or_default();
            if !e.contains(&class.key()) {
                e.push(class.key());
            }
        }
    }
    HELD.with(|h| h.borrow_mut().push(class));
    true
}

/// Pop the most recent acquisition of `class` from the held stack.
/// Guards may be dropped out of declaration order (`drop(ws)` before a
/// later guard), so this removes the last matching entry, not
/// necessarily the top.
fn lockdep_release(class: &'static LockClass) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(pos) = held.iter().rposition(|c| std::ptr::eq(*c, class)) {
            held.remove(pos);
        }
    });
}

// ---------------------------------------------------------------------------
// Mutex
// ---------------------------------------------------------------------------

/// A mutex registered with a [`LockClass`]. API matches `std::sync`
/// minus poisoning: `lock()` returns the guard directly.
pub struct Mutex<T: ?Sized> {
    class: &'static LockClass,
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    pub fn new(class: &'static LockClass, value: T) -> Self {
        Self {
            class,
            inner: StdMutex::new(value),
        }
    }
}

impl<T: ?Sized> Mutex<T> {
    pub fn lock(&self) -> MutexGuard<'_, T> {
        let tracked = lockdep_acquire(self.class);
        let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        MutexGuard {
            class: self.class,
            tracked,
            inner: Some(inner),
        }
    }
}

impl<T: ?Sized + fmt::Debug> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Mutex").field("class", &self.class.name).finish_non_exhaustive()
    }
}

/// Guard for [`Mutex`]. The inner `Option` exists so [`Condvar::wait`]
/// can take the `std` guard out without running this type's release
/// logic twice.
pub struct MutexGuard<'a, T: ?Sized> {
    class: &'static LockClass,
    tracked: bool,
    inner: Option<StdMutexGuard<'a, T>>,
}

impl<T: ?Sized> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard still holds the lock")
    }
}

impl<T: ?Sized> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard still holds the lock")
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if self.tracked {
            lockdep_release(self.class);
        }
    }
}

// ---------------------------------------------------------------------------
// RwLock
// ---------------------------------------------------------------------------

/// A reader-writer lock registered with a [`LockClass`]. Read and write
/// acquisitions count identically for ordering purposes: a read-mode
/// inversion is still an inversion (two threads in opposite orders with
/// one writer deadlock the same way).
pub struct RwLock<T: ?Sized> {
    class: &'static LockClass,
    inner: StdRwLock<T>,
}

impl<T> RwLock<T> {
    pub fn new(class: &'static LockClass, value: T) -> Self {
        Self {
            class,
            inner: StdRwLock::new(value),
        }
    }
}

impl<T: ?Sized> RwLock<T> {
    pub fn read(&self) -> RwLockReadGuard<'_, T> {
        let tracked = lockdep_acquire(self.class);
        let inner = self.inner.read().unwrap_or_else(PoisonError::into_inner);
        RwLockReadGuard {
            class: self.class,
            tracked,
            inner,
        }
    }

    pub fn write(&self) -> RwLockWriteGuard<'_, T> {
        let tracked = lockdep_acquire(self.class);
        let inner = self.inner.write().unwrap_or_else(PoisonError::into_inner);
        RwLockWriteGuard {
            class: self.class,
            tracked,
            inner,
        }
    }
}

impl<T: ?Sized + fmt::Debug> fmt::Debug for RwLock<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RwLock").field("class", &self.class.name).finish_non_exhaustive()
    }
}

pub struct RwLockReadGuard<'a, T: ?Sized> {
    class: &'static LockClass,
    tracked: bool,
    inner: StdRwLockReadGuard<'a, T>,
}

impl<T: ?Sized> std::ops::Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T: ?Sized> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        if self.tracked {
            lockdep_release(self.class);
        }
    }
}

pub struct RwLockWriteGuard<'a, T: ?Sized> {
    class: &'static LockClass,
    tracked: bool,
    inner: StdRwLockWriteGuard<'a, T>,
}

impl<T: ?Sized> std::ops::Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T: ?Sized> std::ops::DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T: ?Sized> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        if self.tracked {
            lockdep_release(self.class);
        }
    }
}

// ---------------------------------------------------------------------------
// Condvar
// ---------------------------------------------------------------------------

/// Condition variable over the shim's [`Mutex`]. Waiting releases the
/// mutex, so the detector pops the class for the duration of the wait
/// and re-validates the re-acquisition on wakeup (the surrounding held
/// stack — e.g. the WAL commit gate around a `done_cv` wait — is still
/// in force and is re-checked).
pub struct Condvar {
    inner: StdCondvar,
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl Condvar {
    pub fn new() -> Self {
        Self {
            inner: StdCondvar::new(),
        }
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        let class = guard.class;
        let inner = guard.inner.take().expect("guard still holds the lock");
        if guard.tracked {
            lockdep_release(class);
            guard.tracked = false;
        }
        drop(guard); // releases nothing: inner taken, tracking disarmed
        let inner = self.inner.wait(inner).unwrap_or_else(PoisonError::into_inner);
        MutexGuard {
            class,
            tracked: lockdep_acquire(class),
            inner: Some(inner),
        }
    }

    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
        let class = guard.class;
        let inner = guard.inner.take().expect("guard still holds the lock");
        if guard.tracked {
            lockdep_release(class);
            guard.tracked = false;
        }
        drop(guard);
        let (inner, timed_out) = self
            .inner
            .wait_timeout(inner, dur)
            .unwrap_or_else(PoisonError::into_inner);
        (
            MutexGuard {
                class,
                tracked: lockdep_acquire(class),
                inner: Some(inner),
            },
            timed_out,
        )
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Test-only classes; ranks far above the production table so these
    // never interfere with real locks held by other tests in the same
    // process.
    static T_OUTER: LockClass = LockClass::new("test.sync.outer", 10_000);
    static T_INNER: LockClass = LockClass::new("test.sync.inner", 10_010);

    #[test]
    fn in_order_nesting_is_clean() {
        let a = Mutex::new(&T_OUTER, 1);
        let b = Mutex::new(&T_INNER, 2);
        let ga = a.lock();
        let gb = b.lock();
        assert_eq!(*ga + *gb, 3);
    }

    #[test]
    fn rank_inversion_panics_with_both_names() {
        static INV_A: LockClass = LockClass::new("test.sync.inv_a", 10_100);
        static INV_B: LockClass = LockClass::new("test.sync.inv_b", 10_110);
        let err = std::thread::spawn(|| {
            let a = Mutex::new(&INV_A, ());
            let b = Mutex::new(&INV_B, ());
            let _gb = b.lock();
            let _ga = a.lock(); // rank 10_100 under rank 10_110: violation
        })
        .join()
        .expect_err("inversion must panic under lockdep");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("test.sync.inv_a"), "{msg}");
        assert!(msg.contains("test.sync.inv_b"), "{msg}");
    }

    #[test]
    fn condvar_wait_repushes_class() {
        static CV_M: LockClass = LockClass::new("test.sync.cv_m", 10_200);
        let m = Mutex::new(&CV_M, false);
        let cv = Condvar::new();
        let mut g = m.lock();
        let (g2, res) = cv.wait_timeout(g, Duration::from_millis(10));
        assert!(res.timed_out());
        g = g2;
        *g = true;
        assert!(*g);
    }

    #[test]
    fn guards_can_drop_out_of_order() {
        static OO_A: LockClass = LockClass::new("test.sync.oo_a", 10_300);
        static OO_B: LockClass = LockClass::new("test.sync.oo_b", 10_310);
        let a = Mutex::new(&OO_A, ());
        let b = Mutex::new(&OO_B, ());
        let ga = a.lock();
        let gb = b.lock();
        drop(ga); // release outer first: legal Rust, must not corrupt the stack
        drop(gb);
        // A fresh in-order pass must still be clean.
        let _ga = a.lock();
        let _gb = b.lock();
    }

    #[test]
    fn rwlock_read_then_inner_mutex_is_clean() {
        static RW_O: LockClass = LockClass::new("test.sync.rw_outer", 10_400);
        static RW_I: LockClass = LockClass::new("test.sync.rw_inner", 10_410);
        let r = RwLock::new(&RW_O, 7);
        let m = Mutex::new(&RW_I, 1);
        let gr = r.read();
        let gm = m.lock();
        assert_eq!(*gr + *gm, 8);
        drop(gm);
        drop(gr);
        let mut gw = r.write();
        *gw += 1;
        drop(gw);
        assert_eq!(*r.read(), 8);
    }
}
