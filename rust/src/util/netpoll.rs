//! Dependency-free readiness polling: thin safe wrappers over POSIX
//! `poll(2)` and `pipe(2)`, declared directly via `extern "C"` so the
//! crate stays free of the `libc`/`mio` crates (offline vendored build).
//!
//! Used by [`crate::service::frontend`] to park thousands of idle TCP
//! connections without a thread each: the event loop blocks in
//! [`wait_readable`] over every idle socket plus a [`WakePipe`] that
//! worker threads tickle when they hand a connection back.
//!
//! The constants below are the Linux values (the only platform the
//! project's CI and container target); they also match most BSDs for the
//! `POLL*` flags.

use std::io;
use std::os::raw::{c_int, c_ulong, c_void};
use std::os::unix::io::RawFd;
use std::sync::atomic::{AtomicBool, Ordering};

#[repr(C)]
#[derive(Clone, Copy, Debug)]
struct PollFd {
    fd: c_int,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

/// Interest mask for [`PollSet::wait`]: readability.
pub const EV_READ: i16 = POLLIN;
/// Interest mask for [`PollSet::wait`]: writability (used by the
/// front-end to park half-written responses until the peer drains its
/// receive window).
pub const EV_WRITE: i16 = POLLOUT;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    fn pipe(fds: *mut c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
}

/// Reusable poll set: amortizes the `pollfd` and ready-index buffers
/// across wakeups, so a hot event loop over a large fleet does not pay
/// two O(fleet) allocations per served request.
#[derive(Debug, Default)]
pub struct PollSet {
    pfds: Vec<PollFd>,
    ready: Vec<usize>,
}

impl PollSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Block until at least one of `fds` is readable (or has hung up /
    /// errored — callers must attempt the read to observe which), or
    /// `timeout_ms` elapses. Returns the indices into `fds` that are
    /// ready; an empty slice means the timeout fired. A negative timeout
    /// blocks indefinitely.
    pub fn wait_readable(&mut self, fds: &[RawFd], timeout_ms: i32) -> io::Result<&[usize]> {
        self.pfds.clear();
        self.pfds
            .extend(fds.iter().map(|&fd| PollFd { fd, events: POLLIN, revents: 0 }));
        self.poll_prepared(timeout_ms)
    }

    /// Mixed-interest wait: each entry is `(fd, events)` with `events` a
    /// combination of [`EV_READ`] / [`EV_WRITE`]. Error/hangup conditions
    /// always count as ready (the caller's read or write observes them).
    pub fn wait(&mut self, fds: &[(RawFd, i16)], timeout_ms: i32) -> io::Result<&[usize]> {
        self.pfds.clear();
        self.pfds
            .extend(fds.iter().map(|&(fd, events)| PollFd { fd, events, revents: 0 }));
        self.poll_prepared(timeout_ms)
    }

    fn poll_prepared(&mut self, timeout_ms: i32) -> io::Result<&[usize]> {
        loop {
            let rc =
                unsafe { poll(self.pfds.as_mut_ptr(), self.pfds.len() as c_ulong, timeout_ms) };
            if rc < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(err);
            }
            self.ready.clear();
            if rc > 0 {
                for (i, p) in self.pfds.iter().enumerate() {
                    if p.revents & (p.events | POLLERR | POLLHUP | POLLNVAL) != 0 {
                        self.ready.push(i);
                    }
                }
            }
            return Ok(&self.ready);
        }
    }
}

/// One-shot convenience wrapper over [`PollSet::wait_readable`] for
/// tests and cold paths.
pub fn wait_readable(fds: &[RawFd], timeout_ms: i32) -> io::Result<Vec<usize>> {
    let mut set = PollSet::new();
    set.wait_readable(fds, timeout_ms).map(|r| r.to_vec())
}

/// Block until `fd` is writable or `timeout_ms` elapses. Returns whether
/// the descriptor became writable (false = timeout).
pub fn wait_writable(fd: RawFd, timeout_ms: i32) -> io::Result<bool> {
    let mut pfd = PollFd { fd, events: POLLOUT, revents: 0 };
    loop {
        let rc = unsafe { poll(&mut pfd, 1, timeout_ms) };
        if rc < 0 {
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                continue;
            }
            return Err(err);
        }
        return Ok(rc > 0);
    }
}

/// A self-pipe for waking a [`wait_readable`] loop from another thread.
///
/// `wake` writes at most one byte until the loop `drain`s it again, so
/// the pipe can never fill up and block a waker (the classic self-pipe
/// trick without `O_NONBLOCK`).
#[derive(Debug)]
pub struct WakePipe {
    read_fd: RawFd,
    write_fd: RawFd,
    signaled: AtomicBool,
}

impl WakePipe {
    pub fn new() -> io::Result<Self> {
        let mut fds: [c_int; 2] = [0; 2];
        if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self {
            read_fd: fds[0],
            write_fd: fds[1],
            signaled: AtomicBool::new(false),
        })
    }

    /// The fd to include in a [`wait_readable`] set.
    pub fn read_fd(&self) -> RawFd {
        self.read_fd
    }

    /// Make the next (or current) `wait_readable` call return. Cheap and
    /// idempotent while the loop has not drained yet.
    pub fn wake(&self) {
        if !self.signaled.swap(true, Ordering::SeqCst) {
            let byte = [1u8];
            let _ = unsafe { write(self.write_fd, byte.as_ptr() as *const c_void, 1) };
        }
    }

    /// Consume pending wake bytes. Call only after `read_fd` polled
    /// readable (the pipe is a blocking descriptor).
    pub fn drain(&self) {
        let mut buf = [0u8; 16];
        let _ = unsafe { read(self.read_fd, buf.as_mut_ptr() as *mut c_void, buf.len()) };
        self.signaled.store(false, Ordering::SeqCst);
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        unsafe {
            close(self.read_fd);
            close(self.write_fd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Duration;

    #[test]
    fn wake_pipe_unblocks_poll() {
        let wake = std::sync::Arc::new(WakePipe::new().unwrap());
        let w = std::sync::Arc::clone(&wake);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            w.wake();
        });
        let ready = wait_readable(&[wake.read_fd()], 5_000).unwrap();
        assert_eq!(ready, vec![0]);
        wake.drain();
        t.join().unwrap();
        // Drained: a short poll now times out.
        let ready = wait_readable(&[wake.read_fd()], 10).unwrap();
        assert!(ready.is_empty());
        // Wake works again after a drain.
        wake.wake();
        let ready = wait_readable(&[wake.read_fd()], 5_000).unwrap();
        assert_eq!(ready, vec![0]);
    }

    #[test]
    fn socket_readability_is_reported() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();

        // Nothing written yet: poll times out.
        let fds = [server_side.as_raw_fd()];
        assert!(wait_readable(&fds, 10).unwrap().is_empty());

        client.write_all(b"x").unwrap();
        client.flush().unwrap();
        let ready = wait_readable(&fds, 5_000).unwrap();
        assert_eq!(ready, vec![0]);

        // A connected socket with room in its send buffer is writable.
        assert!(wait_writable(server_side.as_raw_fd(), 1_000).unwrap());
    }

    #[test]
    fn mixed_interest_wait() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();

        let mut set = PollSet::new();
        // Write interest on a socket with buffer space: ready. Read
        // interest on the same idle socket: not ready.
        let entries = [
            (server_side.as_raw_fd(), EV_READ),
            (server_side.as_raw_fd(), EV_WRITE),
        ];
        let ready = set.wait(&entries, 1_000).unwrap().to_vec();
        assert_eq!(ready, vec![1]);

        client.write_all(b"x").unwrap();
        client.flush().unwrap();
        let ready = set.wait(&entries, 5_000).unwrap().to_vec();
        assert_eq!(ready, vec![0, 1]);
    }

    #[test]
    fn hangup_is_reported_as_ready() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(client);
        // Peer closed: the socket must poll ready so the event loop can
        // observe EOF and reap the connection.
        let ready = wait_readable(&[server_side.as_raw_fd()], 5_000).unwrap();
        assert_eq!(ready, vec![0]);
    }
}
