//! Dependency-free readiness polling: thin safe wrappers over POSIX
//! `poll(2)`, `pipe(2)` and Linux `epoll(7)`, declared directly via
//! `extern "C"` so the crate stays free of the `libc`/`mio` crates
//! (offline vendored build).
//!
//! # The `Poller` abstraction
//!
//! [`Poller`] is the readiness interface the front-end event loop
//! ([`crate::service::frontend`]) drives. It has two backends behind one
//! enum, selected by [`PollerKind`]:
//!
//! * [`PollerKind::Poll`] — the historical rebuilt-each-wakeup `poll(2)`
//!   set. Every [`Poller::wait`] rebuilds the full `pollfd` array from
//!   the registration map and asks the kernel to scan all of it, so a
//!   wakeup costs O(registered) even when one fd is ready. Kept as the
//!   measurable baseline (`--poller=poll`, C-FRONTEND-EPOLL).
//! * [`PollerKind::Epoll`] — `epoll_create1`/`epoll_ctl`/`epoll_wait`
//!   with **incremental registration**: the kernel retains the interest
//!   set between waits and [`Poller::register`]/[`Poller::deregister`]
//!   run only on connection state changes (accept, park, hand-off to a
//!   worker, write-park, close), so a wakeup costs O(ready).
//!
//! Registration-state invariants shared by both backends:
//!
//! * One registration per fd. [`Poller::register`] on an
//!   already-registered fd replaces the previous token/interest (epoll's
//!   `EEXIST` is repaired with `EPOLL_CTL_MOD`), and
//!   [`Poller::deregister`] is idempotent — a missing or already-closed
//!   fd is not an error. Owners therefore never need to know whether a
//!   racing path got there first.
//! * An fd must be deregistered **before** its owner closes it or hands
//!   it to another thread that may close it. epoll auto-forgets closed
//!   fds, but the fd number can be reused by a new `accept(2)` and a
//!   stale registration would then alias the new connection.
//! * Interest is level-triggered in both backends: a ready fd keeps
//!   reporting until the owner consumes the readiness or deregisters, so
//!   a wakeup delivered while the event buffer was full is never lost.
//!
//! Both backends count cumulative [`Poller::wakeups`] and
//! [`Poller::scan_cost`] (fds scanned per wait for poll, events
//! delivered for epoll) so benches and metrics can show the
//! O(registered)-vs-O(ready) difference directly.
//!
//! # The `WakePipe`
//!
//! [`WakePipe`] is a self-pipe for waking the event loop from worker
//! threads. Opened `O_CLOEXEC | O_NONBLOCK`; see [`WakePipe::drain`] for
//! the flag/byte ordering protocol (the lost-wakeup fix).
//!
//! The constants below are the Linux values (the only platform the
//! project's CI and container target); the `POLL*` flags also match most
//! BSDs, the `EPOLL*` interface is Linux-only.

use std::io;
use std::os::raw::{c_int, c_ulong, c_void};
use std::os::unix::io::RawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

#[repr(C)]
#[derive(Clone, Copy, Debug)]
struct PollFd {
    fd: c_int,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

/// Interest mask for [`PollSet::wait`] / [`Poller::register`]:
/// readability.
pub const EV_READ: i16 = POLLIN;
/// Interest mask for [`PollSet::wait`] / [`Poller::register`]:
/// writability (used by the front-end to park half-written responses
/// until the peer drains its receive window).
pub const EV_WRITE: i16 = POLLOUT;

// epoll event bits happen to share the poll(2) values for IN/OUT/ERR/HUP
// but are a distinct 32-bit namespace; keep them separate for clarity.
const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLL_CLOEXEC: c_int = 0x80000;

const O_NONBLOCK: c_int = 0x800;
const O_CLOEXEC: c_int = 0x80000;
const F_GETFD: c_int = 1;
const F_SETFD: c_int = 2;
const F_GETFL: c_int = 3;
const F_SETFL: c_int = 4;
const FD_CLOEXEC: c_int = 1;

const EEXIST: i32 = 17;
const ENOENT: i32 = 2;
const EBADF: i32 = 9;

/// `struct epoll_event` is `__attribute__((packed))` on x86-64 only (a
/// kernel ABI quirk kept for 32-bit compatibility); everywhere else it
/// has natural alignment.
#[cfg(target_arch = "x86_64")]
#[repr(C, packed)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

#[cfg(not(target_arch = "x86_64"))]
#[repr(C)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

impl std::fmt::Debug for EpollEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Copy out first: references into a packed struct are UB.
        let (events, data) = (self.events, self.data);
        f.debug_struct("EpollEvent").field("events", &events).field("data", &data).finish()
    }
}

extern "C" {
    fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    fn pipe(fds: *mut c_int) -> c_int;
    fn pipe2(fds: *mut c_int, flags: c_int) -> c_int;
    fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(
        epfd: c_int,
        events: *mut EpollEvent,
        maxevents: c_int,
        timeout: c_int,
    ) -> c_int;
}

/// Deadline tracking for `EINTR` retry loops: a syscall interrupted by a
/// signal must resume with the *remaining* budget, not the original
/// timeout, or a finite wait can stretch unboundedly under a signal
/// storm.
struct Deadline {
    /// `None`: the caller asked to block indefinitely.
    at: Option<Instant>,
}

impl Deadline {
    fn after_ms(timeout_ms: i32) -> Self {
        let at =
            (timeout_ms >= 0).then(|| Instant::now() + Duration::from_millis(timeout_ms as u64));
        Self { at }
    }

    /// Remaining budget in milliseconds — rounded up, so a sub-ms
    /// remainder retries once more instead of busy-spinning at 0 — or
    /// `None` once the deadline has elapsed.
    fn remaining_ms(&self) -> Option<i32> {
        let at = match self.at {
            None => return Some(-1),
            Some(at) => at,
        };
        let now = Instant::now();
        if now >= at {
            return None;
        }
        let ms = (at - now).as_millis().saturating_add(1);
        Some(ms.min(i32::MAX as u128) as i32)
    }
}

/// `poll(2)` with deadline-aware `EINTR` handling: returns the raw ready
/// count, with 0 meaning the timeout (or the post-interrupt remainder)
/// elapsed.
fn poll_with_deadline(pfds: &mut [PollFd], timeout_ms: i32) -> io::Result<c_int> {
    let deadline = Deadline::after_ms(timeout_ms);
    let mut timeout = timeout_ms;
    loop {
        // SAFETY: `pfds` is a live, exclusively borrowed slice of
        // repr(C) PollFd; the pointer and length describe exactly that
        // allocation for the duration of the call, and poll(2) writes
        // only within it (the revents fields).
        let rc = unsafe { poll(pfds.as_mut_ptr(), pfds.len() as c_ulong, timeout) };
        if rc >= 0 {
            return Ok(rc);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
        match deadline.remaining_ms() {
            Some(ms) => timeout = ms,
            None => return Ok(0),
        }
    }
}

/// Reusable poll set: amortizes the `pollfd` and ready-index buffers
/// across wakeups, so a hot event loop over a large fleet does not pay
/// two O(fleet) allocations per served request.
#[derive(Debug, Default)]
pub struct PollSet {
    pfds: Vec<PollFd>,
    ready: Vec<usize>,
}

impl PollSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Block until at least one of `fds` is readable (or has hung up /
    /// errored — callers must attempt the read to observe which), or
    /// `timeout_ms` elapses. Returns the indices into `fds` that are
    /// ready; an empty slice means the timeout fired. A negative timeout
    /// blocks indefinitely.
    pub fn wait_readable(&mut self, fds: &[RawFd], timeout_ms: i32) -> io::Result<&[usize]> {
        self.pfds.clear();
        self.pfds
            .extend(fds.iter().map(|&fd| PollFd { fd, events: POLLIN, revents: 0 }));
        self.poll_prepared(timeout_ms)
    }

    /// Mixed-interest wait: each entry is `(fd, events)` with `events` a
    /// combination of [`EV_READ`] / [`EV_WRITE`]. Error/hangup conditions
    /// always count as ready (the caller's read or write observes them).
    pub fn wait(&mut self, fds: &[(RawFd, i16)], timeout_ms: i32) -> io::Result<&[usize]> {
        self.pfds.clear();
        self.pfds
            .extend(fds.iter().map(|&(fd, events)| PollFd { fd, events, revents: 0 }));
        self.poll_prepared(timeout_ms)
    }

    fn poll_prepared(&mut self, timeout_ms: i32) -> io::Result<&[usize]> {
        let rc = poll_with_deadline(&mut self.pfds, timeout_ms)?;
        self.ready.clear();
        if rc > 0 {
            for (i, p) in self.pfds.iter().enumerate() {
                if p.revents & (p.events | POLLERR | POLLHUP | POLLNVAL) != 0 {
                    self.ready.push(i);
                }
            }
        }
        Ok(&self.ready)
    }
}

/// One-shot convenience wrapper over [`PollSet::wait_readable`] for
/// tests and cold paths.
pub fn wait_readable(fds: &[RawFd], timeout_ms: i32) -> io::Result<Vec<usize>> {
    let mut set = PollSet::new();
    set.wait_readable(fds, timeout_ms).map(|r| r.to_vec())
}

/// Block until `fd` is writable or `timeout_ms` elapses. Returns whether
/// the descriptor became writable (false = timeout).
pub fn wait_writable(fd: RawFd, timeout_ms: i32) -> io::Result<bool> {
    let mut pfd = [PollFd { fd, events: POLLOUT, revents: 0 }];
    let rc = poll_with_deadline(&mut pfd, timeout_ms)?;
    Ok(rc > 0)
}

/// Which readiness backend a [`Poller`] uses. See the module docs for
/// the cost model of each.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PollerKind {
    /// Rebuild-every-wakeup `poll(2)`: O(registered) per wait. The
    /// baseline for C-FRONTEND-EPOLL comparisons.
    Poll,
    /// `epoll(7)` with incremental registration: O(ready) per wait.
    #[default]
    Epoll,
}

impl PollerKind {
    /// Parse the CLI / env spelling (`"poll"` or `"epoll"`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "poll" => Some(Self::Poll),
            "epoll" => Some(Self::Epoll),
            _ => None,
        }
    }

    /// Backend selected by the `OSSVIZIER_POLLER` env knob (the CI test
    /// matrix sets it to `poll` / `epoll`); epoll when unset or
    /// unrecognized.
    pub fn from_env() -> Self {
        std::env::var("OSSVIZIER_POLLER")
            .ok()
            .and_then(|v| Self::parse(v.trim()))
            .unwrap_or_default()
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Poll => "poll",
            Self::Epoll => "epoll",
        }
    }
}

/// One readiness event from [`Poller::wait`]. `token` is the cookie the
/// owner registered the fd with; `events` is the ready mask ([`EV_READ`]
/// / [`EV_WRITE`]), with error/hangup folded into both directions so the
/// owner's next read or write observes the failure.
#[derive(Clone, Copy, Debug)]
pub struct PollerEvent {
    pub token: u64,
    pub events: i16,
}

fn poll_ready_mask(revents: i16) -> i16 {
    let mut mask = 0;
    if revents & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0 {
        mask |= EV_READ;
    }
    if revents & (POLLOUT | POLLERR | POLLHUP | POLLNVAL) != 0 {
        mask |= EV_WRITE;
    }
    mask
}

fn epoll_ready_mask(events: u32) -> i16 {
    let mut mask = 0;
    if events & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0 {
        mask |= EV_READ;
    }
    if events & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0 {
        mask |= EV_WRITE;
    }
    mask
}

fn epoll_interest(interest: i16) -> u32 {
    // Level-triggered on purpose: readiness the event buffer could not
    // hold in one wait is re-reported on the next, so nothing is lost.
    let mut ev = 0;
    if interest & EV_READ != 0 {
        ev |= EPOLLIN;
    }
    if interest & EV_WRITE != 0 {
        ev |= EPOLLOUT;
    }
    ev
}

/// The rebuilt-each-wakeup `poll(2)` backend. Registration maintains an
/// fd map; every [`PollBackend::wait`] rebuilds the full `pollfd` array
/// from it — deliberately preserving the historical O(registered)
/// per-wakeup cost this backend exists to baseline.
#[derive(Debug, Default)]
pub struct PollBackend {
    registered: std::collections::HashMap<RawFd, (u64, i16)>,
    pfds: Vec<PollFd>,
    toks: Vec<u64>,
    events: Vec<PollerEvent>,
    wakeups: u64,
    scan_cost: u64,
}

impl PollBackend {
    fn wait(&mut self, timeout_ms: i32) -> io::Result<&[PollerEvent]> {
        self.pfds.clear();
        self.toks.clear();
        for (&fd, &(token, interest)) in &self.registered {
            self.pfds.push(PollFd { fd, events: interest, revents: 0 });
            self.toks.push(token);
        }
        let rc = poll_with_deadline(&mut self.pfds, timeout_ms)?;
        self.wakeups += 1;
        self.scan_cost += self.pfds.len() as u64;
        self.events.clear();
        if rc > 0 {
            for (p, &token) in self.pfds.iter().zip(&self.toks) {
                let mask = poll_ready_mask(p.revents);
                if mask != 0 {
                    self.events.push(PollerEvent { token, events: mask });
                }
            }
        }
        Ok(&self.events)
    }
}

/// The `epoll(7)` backend: the kernel retains the interest set between
/// waits, registration changes are O(1) `epoll_ctl` calls, and a wakeup
/// reports only the ready fds.
#[derive(Debug)]
pub struct EpollBackend {
    epfd: RawFd,
    /// Userspace mirror of the kernel interest set (fd → token,
    /// interest). Sizes [`Poller::registered`] and lets register/modify
    /// repair `EEXIST`/`ENOENT` after fd-close races.
    registered: std::collections::HashMap<RawFd, (u64, i16)>,
    buf: Vec<EpollEvent>,
    events: Vec<PollerEvent>,
    wakeups: u64,
    scan_cost: u64,
}

impl EpollBackend {
    fn new() -> io::Result<Self> {
        // SAFETY: epoll_create1 takes no pointers; the returned fd (or
        // -1) is checked immediately below.
        let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self {
            epfd,
            registered: std::collections::HashMap::new(),
            // Level-triggered: 256 slots per wait is a batch size, not a
            // capacity limit — overflow readiness re-reports next wait.
            buf: vec![EpollEvent { events: 0, data: 0 }; 256],
            events: Vec::new(),
            wakeups: 0,
            scan_cost: 0,
        })
    }

    fn ctl(&self, op: c_int, fd: RawFd, token: u64, interest: i16) -> io::Result<()> {
        let mut ev = EpollEvent { events: epoll_interest(interest), data: token };
        let arg = if op == EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev as *mut _ };
        // SAFETY: `arg` is either null (DEL, where the kernel ignores
        // it) or a pointer to `ev`, which lives on this stack frame for
        // the whole call; the kernel only reads it.
        if unsafe { epoll_ctl(self.epfd, op, fd, arg) } != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn register(&mut self, fd: RawFd, token: u64, interest: i16) -> io::Result<()> {
        match self.ctl(EPOLL_CTL_ADD, fd, token, interest) {
            Err(e) if e.raw_os_error() == Some(EEXIST) => {
                self.ctl(EPOLL_CTL_MOD, fd, token, interest)?;
            }
            other => other?,
        }
        self.registered.insert(fd, (token, interest));
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, token: u64, interest: i16) -> io::Result<()> {
        match self.ctl(EPOLL_CTL_MOD, fd, token, interest) {
            Err(e) if e.raw_os_error() == Some(ENOENT) => {
                self.ctl(EPOLL_CTL_ADD, fd, token, interest)?;
            }
            other => other?,
        }
        self.registered.insert(fd, (token, interest));
        Ok(())
    }

    fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        self.registered.remove(&fd);
        match self.ctl(EPOLL_CTL_DEL, fd, 0, 0) {
            // Already gone — closed fds auto-deregister — so removal is
            // idempotent for owners racing a peer hangup.
            Err(e) if matches!(e.raw_os_error(), Some(ENOENT) | Some(EBADF)) => Ok(()),
            other => other,
        }
    }

    fn wait(&mut self, timeout_ms: i32) -> io::Result<&[PollerEvent]> {
        let deadline = Deadline::after_ms(timeout_ms);
        let mut timeout = timeout_ms;
        let rc = loop {
            // SAFETY: `self.buf` is a live Vec of repr(C) EpollEvent;
            // the pointer/len pair describes exactly that allocation and
            // the kernel writes at most `len` events into it. The return
            // count is bounds-checked before `buf[..rc]` is read back.
            let rc = unsafe {
                epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as c_int, timeout)
            };
            if rc >= 0 {
                break rc;
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
            match deadline.remaining_ms() {
                Some(ms) => timeout = ms,
                None => break 0,
            }
        };
        self.wakeups += 1;
        self.scan_cost += rc as u64;
        self.events.clear();
        for ev in &self.buf[..rc as usize] {
            let (bits, token) = (ev.events, ev.data);
            let mask = epoll_ready_mask(bits);
            if mask != 0 {
                self.events.push(PollerEvent { token, events: mask });
            }
        }
        Ok(&self.events)
    }
}

impl Drop for EpollBackend {
    fn drop(&mut self) {
        // SAFETY: `epfd` was returned by epoll_create1 in `new` and is
        // owned exclusively by this backend, so this is the only close.
        // The result is deliberately discarded: there is no recovery
        // from a failed close in Drop.
        let _ = unsafe { close(self.epfd) };
    }
}

/// Readiness poller with pluggable backend. See the module docs for the
/// backend cost models and the registration-state invariants.
#[derive(Debug)]
pub enum Poller {
    Poll(PollBackend),
    Epoll(EpollBackend),
}

impl Poller {
    pub fn new(kind: PollerKind) -> io::Result<Self> {
        match kind {
            PollerKind::Poll => Ok(Self::Poll(PollBackend::default())),
            PollerKind::Epoll => Ok(Self::Epoll(EpollBackend::new()?)),
        }
    }

    pub fn kind(&self) -> PollerKind {
        match self {
            Self::Poll(_) => PollerKind::Poll,
            Self::Epoll(_) => PollerKind::Epoll,
        }
    }

    /// Start watching `fd` with the given interest ([`EV_READ`] /
    /// [`EV_WRITE`] combination), reported as `token`. Registering an
    /// already-registered fd replaces its token and interest.
    pub fn register(&mut self, fd: RawFd, token: u64, interest: i16) -> io::Result<()> {
        match self {
            Self::Poll(b) => {
                b.registered.insert(fd, (token, interest));
                Ok(())
            }
            Self::Epoll(b) => b.register(fd, token, interest),
        }
    }

    /// Change the token/interest of a registered fd (registers it if a
    /// close race already dropped it).
    pub fn modify(&mut self, fd: RawFd, token: u64, interest: i16) -> io::Result<()> {
        match self {
            Self::Poll(b) => {
                b.registered.insert(fd, (token, interest));
                Ok(())
            }
            Self::Epoll(b) => b.modify(fd, token, interest),
        }
    }

    /// Stop watching `fd`. Idempotent; must happen before the owning
    /// connection closes the fd (see module docs on fd-number reuse).
    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match self {
            Self::Poll(b) => {
                b.registered.remove(&fd);
                Ok(())
            }
            Self::Epoll(b) => b.deregister(fd),
        }
    }

    /// Block until at least one registered fd is ready or `timeout_ms`
    /// elapses (negative: block indefinitely). An empty slice means
    /// timeout. `EINTR` resumes with the remaining budget.
    pub fn wait(&mut self, timeout_ms: i32) -> io::Result<&[PollerEvent]> {
        match self {
            Self::Poll(b) => b.wait(timeout_ms),
            Self::Epoll(b) => b.wait(timeout_ms),
        }
    }

    /// Number of currently registered fds.
    pub fn registered(&self) -> usize {
        match self {
            Self::Poll(b) => b.registered.len(),
            Self::Epoll(b) => b.registered.len(),
        }
    }

    /// Cumulative [`Poller::wait`] returns (including timeouts).
    pub fn wakeups(&self) -> u64 {
        match self {
            Self::Poll(b) => b.wakeups,
            Self::Epoll(b) => b.wakeups,
        }
    }

    /// Cumulative per-wakeup work: fds scanned (poll) or events
    /// delivered (epoll). `scan_cost / wakeups` is the number
    /// C-FRONTEND-EPOLL asserts on — O(registered) for poll,
    /// O(ready) for epoll.
    pub fn scan_cost(&self) -> u64 {
        match self {
            Self::Poll(b) => b.scan_cost,
            Self::Epoll(b) => b.scan_cost,
        }
    }
}

/// A self-pipe for waking a [`wait_readable`] / [`Poller::wait`] loop
/// from another thread.
///
/// `wake` writes a byte only when the `signaled` flag was clear, so
/// back-to-back wakes cost one atomic swap and the pipe can never fill
/// up and block a waker. Both fds are `O_CLOEXEC | O_NONBLOCK`:
/// close-on-exec so a forked child cannot hold the loop's pipe open, and
/// non-blocking so a spurious readiness report (possible after
/// `EPOLLET` misuse or fork inheritance) can never block the event loop
/// in `drain`.
#[derive(Debug)]
pub struct WakePipe {
    read_fd: RawFd,
    write_fd: RawFd,
    signaled: AtomicBool,
}

impl WakePipe {
    pub fn new() -> io::Result<Self> {
        let mut fds: [c_int; 2] = [0; 2];
        // SAFETY: `fds` is a live [c_int; 2] on this stack frame; pipe2
        // writes exactly two fds into it. The return code is checked.
        if unsafe { pipe2(fds.as_mut_ptr(), O_CLOEXEC | O_NONBLOCK) } != 0 {
            // Portability fallback: plain pipe(2) + fcntl. Non-atomic
            // with respect to a concurrent fork, which is fine — nothing
            // forks while a WakePipe is being constructed.
            // SAFETY: same contract as pipe2 above — `fds` holds two
            // slots and the return code is checked.
            if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
                return Err(io::Error::last_os_error());
            }
            for &fd in &fds {
                // SAFETY: `fd` was just returned by pipe(2) and takes no
                // pointer arguments. Results deliberately discarded:
                // the flags are best-effort hardening, and the fallback
                // path's behaviour is verified by the cloexec test.
                let _ = unsafe { fcntl(fd, F_SETFD, FD_CLOEXEC) };
                // SAFETY: as above.
                let _ = unsafe { fcntl(fd, F_SETFL, O_NONBLOCK) };
            }
        }
        Ok(Self {
            read_fd: fds[0],
            write_fd: fds[1],
            signaled: AtomicBool::new(false),
        })
    }

    /// The fd to include in a [`wait_readable`] set.
    pub fn read_fd(&self) -> RawFd {
        self.read_fd
    }

    /// Make the next (or current) `wait_readable` call return. Cheap and
    /// idempotent while the loop has not drained yet.
    pub fn wake(&self) {
        if !self.signaled.swap(true, Ordering::SeqCst) {
            let byte = [1u8];
            // SAFETY: `byte` is a live 1-byte stack buffer; the kernel
            // reads exactly 1 byte from it. The result is deliberately
            // discarded: with O_NONBLOCK the only failure mode is a full
            // pipe, which already guarantees a pending wakeup.
            let _ = unsafe { write(self.write_fd, byte.as_ptr() as *const c_void, 1) };
        }
    }

    /// Consume pending wake bytes and re-arm for the next wake.
    ///
    /// The ordering is load-bearing: `signaled` is cleared **before**
    /// the pipe is read. The historical order (read, then clear) lost
    /// wakeups — a `wake()` racing into that window saw the flag still
    /// set, skipped its write, and the subsequent clear forgot it ever
    /// happened, leaving a parked connection to the mercy of the 250 ms
    /// backstop sweep.
    pub fn drain(&self) {
        self.drain_with(|| {});
    }

    /// [`WakePipe::drain`] with a hook injected into the window between
    /// the flag clear and the pipe read, so tests can pin the exact
    /// interleaving the pre-fix ordering lost.
    fn drain_with(&self, in_window: impl FnOnce()) {
        // 1. Clear the flag first: from here on a racing wake() sees it
        //    clear and writes a fresh byte (possibly consumed by step 2
        //    below — repaired in step 3).
        self.signaled.store(false, Ordering::SeqCst);
        in_window();
        // 2. Drain the pipe completely. O_NONBLOCK: a short or failed
        //    read means empty, never a blocked event loop.
        let mut buf = [0u8; 64];
        loop {
            // SAFETY: `buf` is a live 64-byte stack buffer; the kernel
            // writes at most `buf.len()` bytes into it. A negative
            // return (error, including EAGAIN on the non-blocking fd)
            // breaks the loop like a short read — empty pipe.
            let n = unsafe { read(self.read_fd, buf.as_mut_ptr() as *mut c_void, buf.len()) };
            if n < buf.len() as isize {
                break;
            }
        }
        // 3. Re-arm: if a wake() raced in after step 1, step 2 may have
        //    eaten its byte while the flag is set again. An empty pipe
        //    with the flag set would be a permanent wedge — every future
        //    wake() would skip the write — so put a byte back. A
        //    spurious extra readable event is harmless; a silent one is
        //    not.
        if self.signaled.load(Ordering::SeqCst) {
            let byte = [1u8];
            // SAFETY: same contract as the write in [`WakePipe::wake`]:
            // 1-byte stack buffer, failure means the pipe already holds
            // a byte.
            let _ = unsafe { write(self.write_fd, byte.as_ptr() as *const c_void, 1) };
        }
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        // SAFETY: both fds came from pipe2/pipe in `new` and are owned
        // exclusively by this WakePipe, so this is the only close of
        // each. Results deliberately discarded: no recovery in Drop.
        let _ = unsafe { close(self.read_fd) };
        // SAFETY: as above.
        let _ = unsafe { close(self.write_fd) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn wake_pipe_unblocks_poll() {
        let wake = Arc::new(WakePipe::new().unwrap());
        let w = Arc::clone(&wake);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            w.wake();
        });
        let ready = wait_readable(&[wake.read_fd()], 5_000).unwrap();
        assert_eq!(ready, vec![0]);
        wake.drain();
        t.join().unwrap();
        // Drained: a short poll now times out.
        let ready = wait_readable(&[wake.read_fd()], 10).unwrap();
        assert!(ready.is_empty());
        // Wake works again after a drain.
        wake.wake();
        let ready = wait_readable(&[wake.read_fd()], 5_000).unwrap();
        assert_eq!(ready, vec![0]);
    }

    #[test]
    fn wake_pipe_is_cloexec_and_nonblocking() {
        let wake = WakePipe::new().unwrap();
        for fd in [wake.read_fd, wake.write_fd] {
            // SAFETY: `fd` is a live pipe fd owned by `wake`; F_GETFD
            // takes no pointer arguments and the result is asserted on.
            let fd_flags = unsafe { fcntl(fd, F_GETFD) };
            assert!(fd_flags >= 0 && fd_flags & FD_CLOEXEC != 0, "fd {fd} not CLOEXEC");
            // SAFETY: as above, for F_GETFL.
            let fl_flags = unsafe { fcntl(fd, F_GETFL) };
            assert!(fl_flags >= 0 && fl_flags & O_NONBLOCK != 0, "fd {fd} not O_NONBLOCK");
        }
    }

    #[test]
    fn drain_on_empty_pipe_does_not_block() {
        // Nothing pending: with a non-blocking read side both drains
        // return immediately instead of hanging the event loop (the
        // spurious-readiness hardening).
        let wake = WakePipe::new().unwrap();
        wake.drain();
        wake.drain();
    }

    /// The lost-wakeup regression, pinned deterministically: a `wake()`
    /// from another thread lands in the exact window inside `drain`
    /// where the pre-fix ordering (read pipe, then clear flag) dropped
    /// it. Post-fix, that wake must always leave the pipe readable —
    /// either its own byte survived the drain or the re-arm step put one
    /// back. This test fails on the pre-fix ordering (the racing wake
    /// sees `signaled` still true, skips its write, and the flag clear
    /// erases it) and on a store-then-read variant without the re-arm
    /// step (the drain eats the racing byte and the pipe wedges with the
    /// flag set).
    #[test]
    fn wake_racing_into_drain_is_never_lost() {
        let wake = Arc::new(WakePipe::new().unwrap());
        for round in 0..200 {
            wake.wake();
            assert!(!wait_readable(&[wake.read_fd()], 5_000).unwrap().is_empty());
            let w = Arc::clone(&wake);
            wake.drain_with(move || {
                std::thread::spawn(move || w.wake()).join().unwrap();
            });
            assert!(
                !wait_readable(&[wake.read_fd()], 5_000).unwrap().is_empty(),
                "round {round}: wake landing mid-drain was lost (pipe never readable)"
            );
            wake.drain();
            assert!(wait_readable(&[wake.read_fd()], 0).unwrap().is_empty());
        }
    }

    /// Free-running multithreaded hammer: producers slam `wake()` while
    /// a consumer polls and drains. Invariant under the fixed protocol:
    /// whenever a wake produced after the last drain exists, the pipe
    /// becomes readable — a 5 s silence with pending wakes means one was
    /// lost (the wedge state: flag set, pipe empty).
    #[test]
    fn wake_pipe_hammer_no_lost_wakeups() {
        const PRODUCERS: u64 = 4;
        const PER_PRODUCER: u64 = 5_000;
        const TOTAL: u64 = PRODUCERS * PER_PRODUCER;
        let wake = Arc::new(WakePipe::new().unwrap());
        let produced = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..PRODUCERS {
            let w = Arc::clone(&wake);
            let p = Arc::clone(&produced);
            handles.push(std::thread::spawn(move || {
                for _ in 0..PER_PRODUCER {
                    p.fetch_add(1, Ordering::SeqCst);
                    w.wake();
                }
            }));
        }
        // `seen` snapshots the counter right after a drain: wakes before
        // the snapshot are covered by that drain, later ones must make
        // the pipe readable again.
        let mut seen = 0u64;
        loop {
            let before = produced.load(Ordering::SeqCst);
            let timeout = if before > seen { 5_000 } else { 20 };
            let ready = wait_readable(&[wake.read_fd()], timeout).unwrap();
            if ready.is_empty() {
                assert!(
                    before <= seen,
                    "lost wakeup: {before} produced, drains covered only {seen}"
                );
                if seen == TOTAL {
                    break;
                }
                continue;
            }
            wake.drain();
            seen = produced.load(Ordering::SeqCst);
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn socket_readability_is_reported() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();

        // Nothing written yet: poll times out.
        let fds = [server_side.as_raw_fd()];
        assert!(wait_readable(&fds, 10).unwrap().is_empty());

        client.write_all(b"x").unwrap();
        client.flush().unwrap();
        let ready = wait_readable(&fds, 5_000).unwrap();
        assert_eq!(ready, vec![0]);

        // A connected socket with room in its send buffer is writable.
        assert!(wait_writable(server_side.as_raw_fd(), 1_000).unwrap());
    }

    #[test]
    fn mixed_interest_wait() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();

        let mut set = PollSet::new();
        // Write interest on a socket with buffer space: ready. Read
        // interest on the same idle socket: not ready.
        let entries = [
            (server_side.as_raw_fd(), EV_READ),
            (server_side.as_raw_fd(), EV_WRITE),
        ];
        let ready = set.wait(&entries, 1_000).unwrap().to_vec();
        assert_eq!(ready, vec![1]);

        client.write_all(b"x").unwrap();
        client.flush().unwrap();
        let ready = set.wait(&entries, 5_000).unwrap().to_vec();
        assert_eq!(ready, vec![0, 1]);
    }

    #[test]
    fn hangup_is_reported_as_ready() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(client);
        // Peer closed: the socket must poll ready so the event loop can
        // observe EOF and reap the connection.
        let ready = wait_readable(&[server_side.as_raw_fd()], 5_000).unwrap();
        assert_eq!(ready, vec![0]);
    }

    #[test]
    fn poller_kind_parses_and_defaults() {
        assert_eq!(PollerKind::parse("poll"), Some(PollerKind::Poll));
        assert_eq!(PollerKind::parse("epoll"), Some(PollerKind::Epoll));
        assert_eq!(PollerKind::parse("kqueue"), None);
        assert_eq!(PollerKind::default(), PollerKind::Epoll);
        assert_eq!(PollerKind::Poll.name(), "poll");
        assert_eq!(PollerKind::Epoll.name(), "epoll");
    }

    /// Shared conformance check for both backends: registration,
    /// level-triggered readiness, token routing, modify, idempotent
    /// deregistration.
    fn poller_conformance(kind: PollerKind) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();

        let mut poller = Poller::new(kind).unwrap();
        assert_eq!(poller.kind(), kind);
        poller.register(server_side.as_raw_fd(), 7, EV_READ).unwrap();
        assert_eq!(poller.registered(), 1);
        assert!(poller.wait(10).unwrap().is_empty());

        client.write_all(b"x").unwrap();
        client.flush().unwrap();
        let evs = poller.wait(5_000).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].token, 7);
        assert!(evs[0].events & EV_READ != 0);
        // Level-triggered: unconsumed readiness re-reports.
        assert_eq!(poller.wait(1_000).unwrap().len(), 1);

        // Re-register with a new token/interest: send buffer has room,
        // so write interest is immediately ready under the new token.
        poller.modify(server_side.as_raw_fd(), 8, EV_WRITE).unwrap();
        let evs = poller.wait(5_000).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].token, 8);
        assert!(evs[0].events & EV_WRITE != 0);

        // Deregistered fds never fire; deregistration is idempotent.
        poller.deregister(server_side.as_raw_fd()).unwrap();
        poller.deregister(server_side.as_raw_fd()).unwrap();
        assert_eq!(poller.registered(), 0);
        assert!(poller.wait(10).unwrap().is_empty());
    }

    #[test]
    fn poll_backend_conformance() {
        poller_conformance(PollerKind::Poll);
    }

    #[test]
    fn epoll_backend_conformance() {
        poller_conformance(PollerKind::Epoll);
    }

    fn poller_reports_hangup(kind: PollerKind) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let mut poller = Poller::new(kind).unwrap();
        poller.register(server_side.as_raw_fd(), 3, EV_READ).unwrap();
        drop(client);
        let evs = poller.wait(5_000).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].token, 3);
        assert!(evs[0].events & EV_READ != 0, "hangup must count as readable");
    }

    #[test]
    fn poll_backend_reports_hangup() {
        poller_reports_hangup(PollerKind::Poll);
    }

    #[test]
    fn epoll_backend_reports_hangup() {
        poller_reports_hangup(PollerKind::Epoll);
    }

    /// The structural point of the epoll backend, verified in miniature
    /// (C-FRONTEND-EPOLL is the full-size version): with a fleet of idle
    /// registered sockets and one hot one, poll(2) pays a per-wakeup
    /// scan proportional to the fleet while epoll pays O(ready).
    #[test]
    fn epoll_scan_cost_is_o_ready_not_o_registered() {
        const FLEET: usize = 50;
        const WAKEUPS: u64 = 20;
        for kind in [PollerKind::Poll, PollerKind::Epoll] {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let mut poller = Poller::new(kind).unwrap();
            let mut fleet = Vec::new(); // keep both sides alive
            for i in 0..FLEET {
                let c = TcpStream::connect(addr).unwrap();
                let (s, _) = listener.accept().unwrap();
                poller.register(s.as_raw_fd(), i as u64, EV_READ).unwrap();
                fleet.push((c, s));
            }
            let mut hot_client = TcpStream::connect(addr).unwrap();
            let (hot, _) = listener.accept().unwrap();
            poller.register(hot.as_raw_fd(), 999, EV_READ).unwrap();

            for _ in 0..WAKEUPS {
                hot_client.write_all(b"x").unwrap();
                hot_client.flush().unwrap();
                let evs = poller.wait(5_000).unwrap();
                assert!(evs.iter().any(|e| e.token == 999));
                // Consume so the level-triggered readiness clears.
                let mut b = [0u8; 8];
                (&hot).read(&mut b).unwrap();
            }

            let per_wakeup = poller.scan_cost() as f64 / poller.wakeups() as f64;
            match kind {
                PollerKind::Poll => assert!(
                    per_wakeup >= FLEET as f64,
                    "poll must scan the whole fleet per wakeup: {per_wakeup:.1}"
                ),
                PollerKind::Epoll => assert!(
                    per_wakeup <= 4.0,
                    "epoll per-wakeup cost must not scale with the fleet: {per_wakeup:.1}"
                ),
            }
        }
    }
}
