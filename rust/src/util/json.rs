//! Minimal JSON implementation (parser + writer).
//!
//! Used for the launcher's config files, human-readable datastore exports,
//! designer state blobs stored in [`crate::pyvizier::Metadata`], and the
//! benchmark harness's result files. `serde_json` is not available in the
//! vendored crate set, so this module implements RFC 8259 directly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so serialization is
/// deterministic (important for golden tests and WAL snapshots).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error produced by [`parse`]: message plus byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e18 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Look up a key in an object value.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Insert into an object value (panics if `self` is not an object).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_number(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    item.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_number(n: f64, out: &mut String) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; encode as null per common practice.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&(n as i64).to_string());
    } else {
        // {:?} gives a shortest round-trippable representation for f64.
        out.push_str(&format!("{n:?}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn expect_keyword(&mut self, kw: &str) -> Result<(), JsonError> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected '{kw}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => {
                self.expect_keyword("null")?;
                Ok(Json::Null)
            }
            Some(b't') => {
                self.expect_keyword("true")?;
                Ok(Json::Bool(true))
            }
            Some(b'f') => {
                self.expect_keyword("false")?;
                Ok(Json::Bool(false))
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{08}'),
                    Some(b'f') => s.push('\u{0C}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let len = utf8_len(b).ok_or_else(|| self.err("invalid utf-8"))?;
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self.peek().is_some_and(|b| b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(s: &str) -> String {
        parse(s).unwrap().to_string()
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": null}, "x"], "c": true}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(true)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_compact() {
        assert_eq!(roundtrip(r#"{"a":1,"b":[true,null]}"#), r#"{"a":1,"b":[true,null]}"#);
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::Str("line1\nline2\t\"quoted\" \\slash \u{1F600} \u{7}".into());
        let text = original.to_string();
        assert_eq!(parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // Surrogate pair for 😀 U+1F600.
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("\u{1F600}".into()));
    }

    #[test]
    fn float_roundtrip_precision() {
        for x in [0.1, 1e-9, 123456.789, -2.5, 1e300, std::f64::consts::PI] {
            let text = Json::Num(x).to_string();
            assert_eq!(parse(&text).unwrap().as_f64().unwrap(), x, "text {text}");
        }
    }

    #[test]
    fn errors_report_offset() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.offset > 0);
        assert!(parse("[1,]").is_err());
        assert!(parse("").is_err());
        assert!(parse("{\"a\":1} trailing").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn deterministic_object_order() {
        let mut o = Json::obj();
        o.set("z", Json::Num(1.0)).set("a", Json::Num(2.0));
        assert_eq!(o.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = parse(r#"{"a":[1,2,{"b":"c"}],"d":{}}"#).unwrap();
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(parse(&s).is_ok());
    }
}
