//! Shared substrates: PRNG, JSON, time, ids, CLI parsing, thread pool,
//! retry/backoff. These replace crates (`rand`, `serde_json`, `clap`,
//! `tokio`) that are not available in the offline vendored registry —
//! see DESIGN.md §3.

pub mod backoff;
pub mod benchkit;
pub mod cli;
pub mod id;
pub mod json;
pub mod netpoll;
pub mod rng;
pub mod sync;
pub mod threadpool;
pub mod time;
pub mod trace;
