//! Dependency-free distributed tracing: per-request span trees across
//! wire → frontend → policy → WAL (the per-request counterpart to the
//! aggregate histograms in `service::metrics`).
//!
//! The paper positions Vizier as a service tuning thousands of users'
//! systems; operating such a service means answering "where did *this*
//! `SuggestTrials` spend its 400 ms?" — queue wait, coalesce fan-in, GP
//! fit, WAL fsync — which aggregates cannot. The design borrows the
//! discipline of `util::sync`'s lockdep rather than an external tracing
//! stack:
//!
//! * **Zero-cost when disabled.** Every entry point starts with
//!   [`enabled`], one cached boolean load (the `lockdep_enabled`
//!   pattern). Disabled builds allocate no rings, take no locks, and
//!   record nothing.
//! * **Bounded memory, lock-free recording.** Each recording thread owns
//!   a fixed-size ring of seqlock slots ([`SpanRing`]); finished spans
//!   are published with plain atomic stores — no lock, no allocation.
//!   The global registry of rings (one `Arc` per thread, capped at
//!   [`MAX_RINGS`]) is only locked when a thread records its *first*
//!   span and when [`snapshot`] collects; its class
//!   (`trace.registry`, rank 390) is a leaf in the lock hierarchy so
//!   publishing is legal under any crate lock (WAL lanes, shards, …).
//! * **Context is ambient.** The active `(trace id, span id)` lives in a
//!   thread-local; RAII [`Span`]s save/restore it so nesting works
//!   without threading parameters through every call. Cross-thread and
//!   cross-process edges (coalesced policy jobs, v2 frames, Pythia hops)
//!   carry an explicit [`TraceCtx`] instead — see
//!   [`crate::wire::messages::append_trace_context`].
//!
//! Sampling is decided once per root span (`--trace-sample-rate` /
//! `OSSVIZIER_TRACE`); children inherit the decision implicitly because
//! an unsampled request simply never installs a current context.
//! Readers ([`snapshot`] → `GetTraces`) tolerate concurrent writers: a
//! slot caught mid-write fails its seqlock check and is skipped, so a
//! snapshot is a consistent *sample* of recent spans, never a torn one.

use std::cell::{Cell, RefCell};
use std::marker::PhantomData;
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use super::sync::{classes, Mutex};

// ---------------------------------------------------------------------------
// Span name codes
// ---------------------------------------------------------------------------
// Spans carry a numeric name code (a `u64` slot in the ring) rather than
// a string so recording stays allocation-free; [`span_name`] maps codes
// back to stable names. Server RPC spans are `RPC_BASE + method id`,
// client-side RPC spans `CLIENT_RPC_BASE + method id` — the service
// layer pretty-prints the method name when it renders.

/// Time a request spent in the frontend job queue before a worker picked
/// it up (recorded retroactively when the dispatch span starts).
pub const FRONTEND_QUEUE: u64 = 1;
/// One policy computation (`Pythia::run_suggest`); fans into every
/// coalesced request's trace via linked copies.
pub const POLICY_COMPUTE: u64 = 2;
/// One durable datastore commit (`WalDatastore::commit`), including the
/// wait for group-commit durability.
pub const WAL_COMMIT: u64 = 3;
/// The lane-serialized section of a WAL commit: in-memory apply + log
/// append (excludes the durability wait).
pub const WAL_LANE_APPLY: u64 = 4;
/// One committer-thread I/O batch (write + optional fsync). Infra span:
/// batches serve many commits, so it belongs to no single trace.
pub const WAL_FSYNC_BATCH: u64 = 5;
/// One segment rotation in the segmented WAL. Infra span.
pub const WAL_ROTATION: u64 = 6;
/// One client-side round-trip to a remote Pythia server.
pub const PYTHIA_HOP: u64 = 7;
/// Server-side policy execution inside the standalone Pythia service.
pub const PYTHIA_SERVE: u64 = 8;
/// Server-side RPC dispatch spans: `RPC_BASE + method id`.
pub const RPC_BASE: u64 = 1000;
/// Client-side RPC spans (mux transport): `CLIENT_RPC_BASE + method id`.
pub const CLIENT_RPC_BASE: u64 = 2000;

/// Stable text name for a span code. Method ids are rendered numerically
/// here (`util` cannot see `wire::Method`); the service layer substitutes
/// method names when it has them.
pub fn span_name(code: u64) -> String {
    match code {
        FRONTEND_QUEUE => "frontend-queue".into(),
        POLICY_COMPUTE => "policy-compute".into(),
        WAL_COMMIT => "wal-commit".into(),
        WAL_LANE_APPLY => "wal-lane-apply".into(),
        WAL_FSYNC_BATCH => "wal-fsync-batch".into(),
        WAL_ROTATION => "wal-rotation".into(),
        PYTHIA_HOP => "pythia-hop".into(),
        PYTHIA_SERVE => "pythia-serve".into(),
        c if (RPC_BASE..RPC_BASE + 256).contains(&c) => format!("rpc:{}", c - RPC_BASE),
        c if (CLIENT_RPC_BASE..CLIENT_RPC_BASE + 256).contains(&c) => {
            format!("client-rpc:{}", c - CLIENT_RPC_BASE)
        }
        c => format!("span:{c}"),
    }
}

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Process-wide tracing configuration, decided once (first-wins).
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Fraction of root spans sampled in `[0.0, 1.0]`. 0 disables.
    pub sample_rate: f64,
    /// Print a span tree to stderr for requests slower than this
    /// (milliseconds). 0 disables the slow-request log.
    pub slow_ms: u64,
}

static CONFIG: OnceLock<TraceConfig> = OnceLock::new();

fn env_rate() -> f64 {
    match std::env::var("OSSVIZIER_TRACE") {
        Ok(v) if v.is_empty() || v == "0" => 0.0,
        // "1" (and any unparseable non-empty value) means "trace
        // everything"; a float is a sampling rate.
        Ok(v) => v.parse::<f64>().unwrap_or(1.0).clamp(0.0, 1.0),
        Err(_) => 0.0,
    }
}

/// Install the configuration from CLI flags. `None` fields defer to the
/// `OSSVIZIER_TRACE` environment variable (and `--trace-slow-ms` alone
/// implies sampling everything, since a slow-request log needs spans).
/// First caller wins; later calls (and the lazy env fallback) are
/// no-ops, mirroring `lockdep_enabled`'s decide-once discipline.
pub fn init(sample_rate: Option<f64>, slow_ms: Option<u64>) {
    let slow = slow_ms.unwrap_or(0);
    let rate = sample_rate.unwrap_or_else(|| {
        let env = env_rate();
        if env > 0.0 {
            env
        } else if slow > 0 {
            1.0
        } else {
            0.0
        }
    });
    let _ = CONFIG.set(TraceConfig { sample_rate: rate.clamp(0.0, 1.0), slow_ms: slow });
}

fn config() -> TraceConfig {
    *CONFIG.get_or_init(|| TraceConfig { sample_rate: env_rate(), slow_ms: 0 })
}

/// Is tracing active for this process? One cached boolean load on the
/// hot path (the `lockdep_enabled` pattern) — everything else in this
/// module is behind it.
pub fn enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        let c = config();
        c.sample_rate > 0.0 || c.slow_ms > 0
    })
}

/// Slow-request threshold in microseconds, if the slow log is on.
pub fn slow_threshold_us() -> Option<u64> {
    let c = config();
    (c.slow_ms > 0).then(|| c.slow_ms * 1000)
}

// ---------------------------------------------------------------------------
// Ids, clock, sampling
// ---------------------------------------------------------------------------

/// Trace/span identifier pair carried across threads and the wire.
/// `trace_id` names the whole request tree; `span_id` the node new work
/// should parent under. Ids are never 0 (0 = "absent" everywhere).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceCtx {
    pub trace_id: u64,
    pub span_id: u64,
}

fn splitmix64(mut z: u64) -> u64 {
    z ^= z >> 30;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Globally unique nonzero id: a per-process random seed (epoch time)
/// plus an atomic counter, whitened through splitmix64 so ids from
/// different processes don't collide trivially.
fn next_id() -> u64 {
    static SEED: OnceLock<u64> = OnceLock::new();
    static CTR: AtomicU64 = AtomicU64::new(0);
    let seed = *SEED.get_or_init(|| super::time::epoch_micros() | 1);
    let n = CTR.fetch_add(1, Ordering::Relaxed);
    let id = splitmix64(seed.wrapping_add(n.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
    if id == 0 {
        1
    } else {
        id
    }
}

/// Monotonic microseconds since the first trace event in this process.
/// Spans use this (not wall time) so durations survive clock steps.
pub fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

thread_local! {
    /// Active `(trace_id, span_id)`; `(0, 0)` = no context.
    static CURRENT: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
    /// Queue-wait note left by the frontend worker loop for the next
    /// dispatch span (see [`note_queue_wait`]).
    static QUEUE_WAIT_US: Cell<u64> = const { Cell::new(0) };
    /// xorshift state for the per-root sampling decision.
    static SAMPLE_STATE: Cell<u64> = const { Cell::new(0) };
    /// This thread's span ring, registered on first use.
    static RING: RefCell<Option<Arc<SpanRing>>> = const { RefCell::new(None) };
}

/// Per-root sampling decision against `rate` (thread-local xorshift —
/// cheap, and determinism per thread is irrelevant here).
fn sample(rate: f64) -> bool {
    if rate >= 1.0 {
        return true;
    }
    if rate <= 0.0 {
        return false;
    }
    SAMPLE_STATE.with(|s| {
        let mut x = s.get();
        if x == 0 {
            x = next_id() | 1;
        }
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        s.set(x);
        ((x >> 11) as f64 / (1u64 << 53) as f64) < rate
    })
}

// ---------------------------------------------------------------------------
// The span ring (per-thread seqlock slots)
// ---------------------------------------------------------------------------

/// Slots per thread ring. Power of two; at 7 × 8 bytes per slot a ring
/// costs 56 KiB, so even a 100-thread policy pool stays under 6 MiB.
pub const RING_SLOTS: usize = 1024;

/// Registered rings cap: bounds total trace memory against unbounded
/// thread churn. Threads past the cap still record locally (their ring
/// is simply never snapshotted) so the hot path never branches on it.
pub const MAX_RINGS: usize = 512;

/// One finished span as stored in (and read back from) a ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    pub trace_id: u64,
    pub span_id: u64,
    /// Parent span id; 0 for roots. May name a span recorded by another
    /// process (a remote client) — renderers treat an unknown parent as
    /// a remote root.
    pub parent_id: u64,
    pub name_code: u64,
    /// [`now_us`] timestamp at span start.
    pub start_us: u64,
    pub dur_us: u64,
}

const SLOT_FIELDS: usize = 6;

/// One seqlock slot: `seq` is odd while a write is in flight, even when
/// the fields are consistent, 0 when never written. Fields are plain
/// relaxed atomics — the seqlock protocol below makes torn *combinations*
/// detectable, and per-field atomicity makes them well-defined.
struct Slot {
    seq: AtomicU64,
    f: [AtomicU64; SLOT_FIELDS],
}

impl Slot {
    const fn new() -> Self {
        Self {
            seq: AtomicU64::new(0),
            f: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        }
    }
}

/// A fixed-size ring of seqlock slots. Exactly one thread writes
/// ([`push`](Self::push)); any thread may read
/// ([`read_into`](Self::read_into)) without blocking the writer.
pub struct SpanRing {
    slots: Box<[Slot]>,
    /// Monotonic write position (slot = `head % len`).
    head: AtomicU64,
}

impl SpanRing {
    pub fn new(slots: usize) -> Self {
        assert!(slots.is_power_of_two(), "ring size must be a power of two");
        Self {
            slots: (0..slots).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
        }
    }

    /// Publish one record. Writer-side seqlock: mark the slot odd,
    /// release-fence so the mark is visible before any field, store the
    /// fields, then mark it even with a release store so the fields are
    /// visible before the mark. Single-writer, so `head` needs no RMW.
    pub fn push(&self, rec: &SpanRecord) {
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(h as usize) & (self.slots.len() - 1)];
        slot.seq.store(2 * h + 1, Ordering::Relaxed);
        fence(Ordering::Release);
        slot.f[0].store(rec.trace_id, Ordering::Relaxed);
        slot.f[1].store(rec.span_id, Ordering::Relaxed);
        slot.f[2].store(rec.parent_id, Ordering::Relaxed);
        slot.f[3].store(rec.name_code, Ordering::Relaxed);
        slot.f[4].store(rec.start_us, Ordering::Relaxed);
        slot.f[5].store(rec.dur_us, Ordering::Relaxed);
        slot.seq.store(2 * h + 2, Ordering::Release);
        self.head.store(h + 1, Ordering::Release);
    }

    /// Append every consistently-readable record to `out`. Slots caught
    /// mid-write (odd seq, or seq changed across the read) are skipped —
    /// a snapshot samples, it never blocks the writer.
    pub fn read_into(&self, out: &mut Vec<SpanRecord>) {
        for slot in self.slots.iter() {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 & 1 == 1 {
                continue;
            }
            let vals: [u64; SLOT_FIELDS] =
                std::array::from_fn(|i| slot.f[i].load(Ordering::Relaxed));
            fence(Ordering::Acquire);
            let s2 = slot.seq.load(Ordering::Relaxed);
            if s1 != s2 {
                continue;
            }
            out.push(SpanRecord {
                trace_id: vals[0],
                span_id: vals[1],
                parent_id: vals[2],
                name_code: vals[3],
                start_us: vals[4],
                dur_us: vals[5],
            });
        }
    }
}

fn registry() -> &'static Mutex<Vec<Arc<SpanRing>>> {
    static R: OnceLock<Mutex<Vec<Arc<SpanRing>>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(&classes::TRACE_REGISTRY, Vec::new()))
}

/// Rings currently registered (0 when tracing never recorded anything —
/// the bench's structural zero-cost check).
pub fn registered_rings() -> usize {
    registry().lock().len()
}

fn publish(rec: &SpanRecord) {
    RING.with(|r| {
        let mut opt = r.borrow_mut();
        if opt.is_none() {
            let ring = Arc::new(SpanRing::new(RING_SLOTS));
            let mut reg = registry().lock();
            if reg.len() < MAX_RINGS {
                reg.push(Arc::clone(&ring));
            }
            drop(reg);
            *opt = Some(ring);
        }
        opt.as_ref().expect("ring installed above").push(rec);
    });
}

/// Collect every readable span from every registered ring. Rings of
/// exited threads are kept alive by the registry's `Arc`, so their spans
/// survive until overwritten counterparts would have.
pub fn snapshot() -> Vec<SpanRecord> {
    if !enabled() {
        return Vec::new();
    }
    let rings: Vec<Arc<SpanRing>> = registry().lock().clone();
    let mut out = Vec::new();
    for ring in rings {
        ring.read_into(&mut out);
    }
    out
}

// ---------------------------------------------------------------------------
// Ambient context and RAII spans
// ---------------------------------------------------------------------------

/// The current thread's active context, if any (what a coalesced job or
/// an outgoing wire frame should propagate).
pub fn current() -> Option<TraceCtx> {
    if !enabled() {
        return None;
    }
    let (t, s) = CURRENT.with(|c| c.get());
    (t != 0).then_some(TraceCtx { trace_id: t, span_id: s })
}

/// Restores the previous thread-local context on drop (see
/// [`set_current`]).
pub struct CtxGuard {
    prev: Option<(u64, u64)>,
    _not_send: PhantomData<*const ()>,
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        if let Some(prev) = self.prev {
            CURRENT.with(|c| c.set(prev));
        }
    }
}

/// Install `ctx` (or clear with `None`) as the thread's active context
/// until the guard drops — how a worker thread adopts the context of the
/// request it is serving (coalesced policy jobs, per-op completion).
pub fn set_current(ctx: Option<TraceCtx>) -> CtxGuard {
    if !enabled() {
        return CtxGuard { prev: None, _not_send: PhantomData };
    }
    let next = ctx.map_or((0, 0), |c| (c.trace_id, c.span_id));
    let prev = CURRENT.with(|c| c.replace(next));
    CtxGuard { prev: Some(prev), _not_send: PhantomData }
}

/// An in-flight span: records itself into the thread ring and restores
/// the previous ambient context when dropped (or via
/// [`finish`](Self::finish) when the caller wants the record back).
pub struct Span {
    ctx: TraceCtx,
    parent: u64,
    code: u64,
    start_us: u64,
    prev: (u64, u64),
    live: bool,
    _not_send: PhantomData<*const ()>,
}

impl Span {
    /// This span's context — what children (local or remote) parent to.
    pub fn ctx(&self) -> TraceCtx {
        self.ctx
    }

    fn end(&mut self) -> SpanRecord {
        self.live = false;
        let rec = SpanRecord {
            trace_id: self.ctx.trace_id,
            span_id: self.ctx.span_id,
            parent_id: self.parent,
            name_code: self.code,
            start_us: self.start_us,
            dur_us: now_us().saturating_sub(self.start_us),
        };
        publish(&rec);
        CURRENT.with(|c| c.set(self.prev));
        rec
    }

    /// End the span now and return its record (for the slow-request
    /// log); the eventual drop is a no-op.
    pub fn finish(mut self) -> SpanRecord {
        self.end()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.live {
            self.end();
        }
    }
}

fn begin(trace_id: u64, parent: u64, code: u64) -> Span {
    let span_id = next_id();
    let prev = CURRENT.with(|c| c.replace((trace_id, span_id)));
    Span {
        ctx: TraceCtx { trace_id, span_id },
        parent,
        code,
        start_us: now_us(),
        prev,
        live: true,
        _not_send: PhantomData,
    }
}

/// Start a new sampled root span (fresh trace id, no parent). `None`
/// when tracing is off or the sampler says no.
pub fn root_span(code: u64) -> Option<Span> {
    if !enabled() || !sample(config().sample_rate) {
        return None;
    }
    Some(begin(next_id(), 0, code))
}

/// Start a local root continuing a remote trace: same trace id, parented
/// under the remote caller's span. Remote traces are always honored —
/// the sampling decision was the root's to make.
pub fn root_span_in(ctx: TraceCtx, code: u64) -> Option<Span> {
    if !enabled() || ctx.trace_id == 0 {
        return None;
    }
    Some(begin(ctx.trace_id, ctx.span_id, code))
}

/// Start a child of the current ambient span; `None` when there is no
/// active (sampled) context.
pub fn child_span(code: u64) -> Option<Span> {
    if !enabled() {
        return None;
    }
    let cur = current()?;
    Some(begin(cur.trace_id, cur.span_id, code))
}

/// Start the span for one server-side RPC dispatch: continue `remote`'s
/// trace if the frame carried one, else nest under any ambient context
/// (the in-process `LocalTransport` path), else make a fresh sampled
/// root. Also converts the worker loop's queue-wait note into a
/// retroactive `frontend-queue` child covering the time before dispatch.
pub fn rpc_span(code: u64, remote: Option<TraceCtx>) -> Option<Span> {
    if !enabled() {
        return None;
    }
    let q = take_queue_wait();
    let span = match remote {
        Some(ctx) if ctx.trace_id != 0 => begin(ctx.trace_id, ctx.span_id, code),
        _ => match current() {
            Some(cur) => begin(cur.trace_id, cur.span_id, code),
            None => {
                if !sample(config().sample_rate) {
                    return None;
                }
                begin(next_id(), 0, code)
            }
        },
    };
    if q > 0 {
        publish(&SpanRecord {
            trace_id: span.ctx.trace_id,
            span_id: next_id(),
            parent_id: span.ctx.span_id,
            name_code: FRONTEND_QUEUE,
            start_us: span.start_us.saturating_sub(q),
            dur_us: q,
        });
    }
    Some(span)
}

/// Leave a queue-wait note for the next [`rpc_span`] on this thread
/// (called by the frontend worker loop, which knows the enqueue time but
/// not the trace context — that is still inside the frame).
pub fn note_queue_wait(us: u64) {
    if !enabled() {
        return;
    }
    QUEUE_WAIT_US.with(|q| q.set(us));
}

fn take_queue_wait() -> u64 {
    QUEUE_WAIT_US.with(|q| q.replace(0))
}

/// Record a completed-span *copy* into `ctx`'s trace — how one coalesced
/// policy computation fans into each of the K waiting requests' trees
/// (same interval, distinct span ids, each parented under its own
/// request).
pub fn record_linked(ctx: TraceCtx, code: u64, start_us: u64, dur_us: u64) {
    if !enabled() || ctx.trace_id == 0 {
        return;
    }
    publish(&SpanRecord {
        trace_id: ctx.trace_id,
        span_id: next_id(),
        parent_id: ctx.span_id,
        name_code: code,
        start_us,
        dur_us,
    });
}

/// Record background work that belongs to no request (fsync batches,
/// segment rotation): trace id 0, grouped under the "infra" pseudo-trace
/// by `GetTraces` when asked.
pub fn record_infra(code: u64, start_us: u64, dur_us: u64) {
    if !enabled() {
        return;
    }
    publish(&SpanRecord {
        trace_id: 0,
        span_id: next_id(),
        parent_id: 0,
        name_code: code,
        start_us,
        dur_us,
    });
}

// ---------------------------------------------------------------------------
// Rendering
// ---------------------------------------------------------------------------

/// Render one trace's spans as an indented tree. Rows are
/// `(span_id, parent_id, name, start_us, dur_us)`; offsets print
/// relative to the earliest start. Spans whose parent is absent (a
/// remote caller's span, or one that fell off its ring) render as roots
/// marked `^`. Shared by the server's slow-request log and the client's
/// `traces()` report.
pub fn render_spans(rows: &[(u64, u64, String, u64, u64)]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let t0 = rows.iter().map(|r| r.3).min().unwrap_or(0);
    let ids: std::collections::HashSet<u64> = rows.iter().map(|r| r.0).collect();
    let mut children: std::collections::HashMap<u64, Vec<usize>> =
        std::collections::HashMap::new();
    let mut roots: Vec<usize> = Vec::new();
    for (i, row) in rows.iter().enumerate() {
        if row.1 != 0 && ids.contains(&row.1) {
            children.entry(row.1).or_default().push(i);
        } else {
            roots.push(i);
        }
    }
    let by_start = |list: &mut Vec<usize>| list.sort_by_key(|&i| (rows[i].3, rows[i].0));
    by_start(&mut roots);
    for list in children.values_mut() {
        by_start(list);
    }
    let mut out = String::new();
    // Iterative DFS with an explicit stack; `visited` guards against a
    // (corrupt) parent cycle ever looping the renderer.
    let mut visited: std::collections::HashSet<usize> = std::collections::HashSet::new();
    let mut stack: Vec<(usize, usize)> = roots.iter().rev().map(|&i| (i, 0)).collect();
    while let Some((i, depth)) = stack.pop() {
        if !visited.insert(i) {
            continue;
        }
        let (span_id, parent_id, ref name, start, dur) = rows[i];
        let remote = parent_id != 0 && !ids.contains(&parent_id);
        out.push_str(&format!(
            "{:indent$}{}{} [{} us @ +{} us]\n",
            "",
            name,
            if remote { " ^" } else { "" },
            dur,
            start.saturating_sub(t0),
            indent = depth * 2,
        ));
        if let Some(kids) = children.get(&span_id) {
            for &k in kids.iter().rev() {
                stack.push((k, depth + 1));
            }
        }
    }
    out
}

/// Render the spans of `trace_id` out of a [`snapshot`] using
/// [`span_name`] — the server-side slow-request log body.
pub fn render_trace(spans: &[SpanRecord], trace_id: u64) -> String {
    let rows: Vec<(u64, u64, String, u64, u64)> = spans
        .iter()
        .filter(|s| s.trace_id == trace_id)
        .map(|s| (s.span_id, s.parent_id, span_name(s.name_code), s.start_us, s.dur_us))
        .collect();
    render_spans(&rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: these tests deliberately avoid `init`/`enabled` — the config
    // is a process-global `OnceLock` shared with every other unit test
    // in this binary, so only the pure pieces are tested here. Full
    // end-to-end behaviour (propagation, fan-in, disabled mode) lives in
    // `tests/tracing.rs` / `tests/tracing_disabled.rs`, each its own
    // process.

    #[test]
    fn ids_are_nonzero_and_distinct() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10_000 {
            let id = next_id();
            assert_ne!(id, 0);
            assert!(seen.insert(id), "duplicate id {id}");
        }
    }

    #[test]
    fn sample_edge_rates() {
        assert!(sample(1.0));
        assert!(sample(2.0));
        assert!(!sample(0.0));
        assert!(!sample(-1.0));
        // A middling rate must eventually say both yes and no.
        let hits = (0..10_000).filter(|_| sample(0.5)).count();
        assert!(hits > 1_000 && hits < 9_000, "rate 0.5 produced {hits}/10000");
    }

    #[test]
    fn ring_roundtrips_records() {
        let ring = SpanRing::new(8);
        let rec = SpanRecord {
            trace_id: 7,
            span_id: 8,
            parent_id: 9,
            name_code: WAL_COMMIT,
            start_us: 100,
            dur_us: 42,
        };
        ring.push(&rec);
        let mut out = Vec::new();
        ring.read_into(&mut out);
        assert_eq!(out, vec![rec]);
    }

    #[test]
    fn ring_wraps_and_keeps_latest() {
        let ring = SpanRing::new(8);
        for i in 0..20u64 {
            ring.push(&SpanRecord {
                trace_id: 1,
                span_id: i + 1,
                parent_id: 0,
                name_code: 0,
                start_us: i,
                dur_us: 0,
            });
        }
        let mut out = Vec::new();
        ring.read_into(&mut out);
        assert_eq!(out.len(), 8);
        let ids: std::collections::HashSet<u64> = out.iter().map(|r| r.span_id).collect();
        for want in 13..=20 {
            assert!(ids.contains(&want), "latest records must survive wrap");
        }
    }

    #[test]
    fn ring_survives_concurrent_reads() {
        let ring = Arc::new(SpanRing::new(16));
        let w = Arc::clone(&ring);
        let writer = std::thread::spawn(move || {
            for i in 0..50_000u64 {
                w.push(&SpanRecord {
                    trace_id: i,
                    span_id: i,
                    parent_id: i,
                    name_code: i,
                    start_us: i,
                    dur_us: i,
                });
            }
        });
        let mut out = Vec::new();
        while !writer.is_finished() {
            out.clear();
            ring.read_into(&mut out);
            // Every accepted record must be internally consistent: the
            // writer stores the same value in every field.
            for r in &out {
                assert!(
                    r.trace_id == r.span_id
                        && r.span_id == r.parent_id
                        && r.parent_id == r.name_code
                        && r.name_code == r.start_us
                        && r.start_us == r.dur_us,
                    "torn read: {r:?}"
                );
            }
        }
        writer.join().unwrap();
    }

    #[test]
    fn span_names_are_stable() {
        assert_eq!(span_name(FRONTEND_QUEUE), "frontend-queue");
        assert_eq!(span_name(POLICY_COMPUTE), "policy-compute");
        assert_eq!(span_name(WAL_COMMIT), "wal-commit");
        assert_eq!(span_name(RPC_BASE + 6), "rpc:6");
        assert_eq!(span_name(CLIENT_RPC_BASE + 17), "client-rpc:17");
        assert_eq!(span_name(999), "span:999");
    }

    #[test]
    fn render_tree_indents_and_orders() {
        let rows = vec![
            (1, 0, "rpc:SuggestTrials".to_string(), 100, 500),
            (2, 1, "policy-compute".to_string(), 200, 300),
            (3, 1, "frontend-queue".to_string(), 90, 10),
            (4, 2, "pythia-hop".to_string(), 210, 100),
        ];
        let text = render_spans(&rows);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("rpc:SuggestTrials ["));
        // Children sorted by start: queue (90) before policy (200).
        assert!(lines[1].starts_with("  frontend-queue"));
        assert!(lines[2].starts_with("  policy-compute"));
        assert!(lines[3].starts_with("    pythia-hop"));
        // Offsets are relative to the earliest start (90).
        assert!(lines[0].contains("@ +10 us"), "got {:?}", lines[0]);
        assert!(lines[1].contains("@ +0 us"), "got {:?}", lines[1]);
    }

    #[test]
    fn render_marks_remote_parents_as_roots() {
        let rows = vec![
            // Parent 99 was recorded by another process.
            (1, 99, "rpc:Ping".to_string(), 10, 5),
            (2, 1, "wal-commit".to_string(), 11, 2),
        ];
        let text = render_spans(&rows);
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].starts_with("rpc:Ping ^"), "got {:?}", lines[0]);
        assert!(lines[1].starts_with("  wal-commit"));
    }

    #[test]
    fn render_survives_parent_cycles() {
        let rows = vec![
            (1, 2, "a".to_string(), 0, 1),
            (2, 1, "b".to_string(), 1, 1),
        ];
        // Both parents "exist", neither is a root: nothing to render,
        // but the renderer must not loop or panic.
        let _ = render_spans(&rows);
    }

    #[test]
    fn render_trace_filters_by_id() {
        let spans = vec![
            SpanRecord { trace_id: 1, span_id: 10, parent_id: 0, name_code: RPC_BASE + 17, start_us: 0, dur_us: 9 },
            SpanRecord { trace_id: 2, span_id: 11, parent_id: 0, name_code: WAL_COMMIT, start_us: 0, dur_us: 1 },
        ];
        let text = render_trace(&spans, 1);
        assert!(text.contains("rpc:17"));
        assert!(!text.contains("wal-commit"));
    }
}
