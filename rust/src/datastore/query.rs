//! Trial filters used by the PolicySupporter (paper §6.2: "the Policy can
//! request only the Trials it needs; ... this can reduce the database work
//! by orders of magnitude relative to loading all the Trials").

use crate::wire::messages::{TrialProto, TrialState};

/// A conjunctive filter over trials.
#[derive(Debug, Clone, Default)]
pub struct TrialFilter {
    /// Keep only these states (empty = all states).
    pub states: Vec<TrialState>,
    /// Keep trials with `id >= min_id` (incremental reads for O(1)-update
    /// designers, §6.3).
    pub min_id: Option<u64>,
    /// Keep trials with `id <= max_id`.
    pub max_id: Option<u64>,
    /// Keep trials assigned to this client.
    pub client_id: Option<String>,
    /// Cap the number of returned trials (newest-first when set).
    pub limit: Option<usize>,
}

impl TrialFilter {
    pub fn completed() -> Self {
        Self {
            states: vec![TrialState::Completed, TrialState::Infeasible],
            ..Default::default()
        }
    }

    pub fn active() -> Self {
        Self {
            states: vec![TrialState::Requested, TrialState::Active],
            ..Default::default()
        }
    }

    pub fn newer_than(mut self, id: u64) -> Self {
        self.min_id = Some(id + 1);
        self
    }

    pub fn for_client(mut self, client_id: &str) -> Self {
        self.client_id = Some(client_id.to_string());
        self
    }

    pub fn with_limit(mut self, n: usize) -> Self {
        self.limit = Some(n);
        self
    }

    /// The inclusive id window `[lo, hi]` this filter can match — the
    /// range the datastore's chunked trial scan walks, so incremental
    /// reads never touch rows outside the window.
    pub fn id_bounds(&self) -> (u64, u64) {
        (self.min_id.unwrap_or(0), self.max_id.unwrap_or(u64::MAX))
    }

    pub fn matches(&self, t: &TrialProto) -> bool {
        if !self.states.is_empty() && !self.states.contains(&t.state) {
            return false;
        }
        if let Some(min) = self.min_id {
            if t.id < min {
                return false;
            }
        }
        if let Some(max) = self.max_id {
            if t.id > max {
                return false;
            }
        }
        if let Some(cid) = &self.client_id {
            if &t.client_id != cid {
                return false;
            }
        }
        true
    }

    /// Apply the filter to a trial list (already sorted by id ascending).
    pub fn apply(&self, trials: Vec<TrialProto>) -> Vec<TrialProto> {
        let mut kept: Vec<TrialProto> = trials.into_iter().filter(|t| self.matches(t)).collect();
        if let Some(limit) = self.limit {
            if kept.len() > limit {
                // newest-first truncation, then restore ascending order
                kept = kept.split_off(kept.len() - limit);
            }
        }
        kept
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trial(id: u64, state: TrialState, client: &str) -> TrialProto {
        TrialProto {
            id,
            state,
            client_id: client.into(),
            ..Default::default()
        }
    }

    fn trials() -> Vec<TrialProto> {
        vec![
            trial(1, TrialState::Completed, "a"),
            trial(2, TrialState::Active, "a"),
            trial(3, TrialState::Completed, "b"),
            trial(4, TrialState::Infeasible, "b"),
            trial(5, TrialState::Requested, "c"),
        ]
    }

    #[test]
    fn state_filters() {
        let done = TrialFilter::completed().apply(trials());
        assert_eq!(done.iter().map(|t| t.id).collect::<Vec<_>>(), vec![1, 3, 4]);
        let active = TrialFilter::active().apply(trials());
        assert_eq!(active.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 5]);
    }

    #[test]
    fn incremental_reads() {
        let newer = TrialFilter::completed().newer_than(1).apply(trials());
        assert_eq!(newer.iter().map(|t| t.id).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn client_filter() {
        let f = TrialFilter::default().for_client("b");
        assert_eq!(f.apply(trials()).len(), 2);
    }

    #[test]
    fn limit_keeps_newest() {
        let f = TrialFilter::default().with_limit(2);
        let kept = f.apply(trials());
        assert_eq!(kept.iter().map(|t| t.id).collect::<Vec<_>>(), vec![4, 5]);
    }

    #[test]
    fn id_bounds_default_to_full_range() {
        assert_eq!(TrialFilter::default().id_bounds(), (0, u64::MAX));
        let f = TrialFilter { min_id: Some(7), max_id: Some(9), ..Default::default() };
        assert_eq!(f.id_bounds(), (7, 9));
        assert_eq!(TrialFilter::default().newer_than(3).id_bounds(), (4, u64::MAX));
    }

    #[test]
    fn id_window() {
        let f = TrialFilter {
            min_id: Some(2),
            max_id: Some(4),
            ..Default::default()
        };
        assert_eq!(f.apply(trials()).iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 3, 4]);
    }
}
