//! Write-ahead-log datastore: durable storage with crash recovery.
//!
//! Every mutation is encoded as a [`Mutation`] record and appended to a log
//! file before being applied to the in-memory state. On startup the log is
//! replayed, rebuilding the exact pre-crash state — including non-done
//! operations, which the service then resumes (paper §3.2: "The Operations
//! are stored in the database and contain sufficient information to restart
//! the computation after a server crash, reboot, or update").
//!
//! Record framing: `[u32-le len][u8 kind][payload]`. A torn final record
//! (crash mid-write) is detected and truncated at recovery.

use super::memory::InMemoryDatastore;
use super::{Datastore, DsError};
use crate::wire::codec::{decode, encode, Reader, WireError, WireMessage, Writer};
use crate::wire::messages::{OperationProto, StudyProto, TrialProto, UnitMetadataUpdate};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write as IoWrite};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

const KIND_PUT_STUDY: u8 = 1;
const KIND_DELETE_STUDY: u8 = 2;
const KIND_PUT_TRIAL: u8 = 3;
const KIND_DELETE_TRIAL: u8 = 4;
const KIND_PUT_OPERATION: u8 = 5;

/// One durable mutation record.
#[derive(Debug, Clone, PartialEq)]
pub enum Mutation {
    PutStudy(StudyProto),
    DeleteStudy(String),
    PutTrial(String, TrialProto),
    DeleteTrial(String, u64),
    PutOperation(OperationProto),
}

/// Internal envelope so every mutation is one wire message.
#[derive(Debug, Default)]
struct Envelope {
    study_name: String,
    trial_id: u64,
    study: Option<StudyProto>,
    trial: Option<TrialProto>,
    op: Option<OperationProto>,
}

impl WireMessage for Envelope {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.study_name);
        w.u64(2, self.trial_id);
        if let Some(s) = &self.study {
            w.msg(3, s);
        }
        if let Some(t) = &self.trial {
            w.msg(4, t);
        }
        if let Some(o) = &self.op {
            w.msg(5, o);
        }
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut e = Envelope::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => e.study_name = v.as_string()?,
                2 => e.trial_id = v.as_u64()?,
                3 => e.study = Some(v.as_msg()?),
                4 => e.trial = Some(v.as_msg()?),
                5 => e.op = Some(v.as_msg()?),
                _ => {}
            }
        }
        Ok(e)
    }
}

impl Mutation {
    fn kind(&self) -> u8 {
        match self {
            Mutation::PutStudy(_) => KIND_PUT_STUDY,
            Mutation::DeleteStudy(_) => KIND_DELETE_STUDY,
            Mutation::PutTrial(..) => KIND_PUT_TRIAL,
            Mutation::DeleteTrial(..) => KIND_DELETE_TRIAL,
            Mutation::PutOperation(_) => KIND_PUT_OPERATION,
        }
    }

    fn to_envelope(&self) -> Envelope {
        let mut e = Envelope::default();
        match self {
            Mutation::PutStudy(s) => e.study = Some(s.clone()),
            Mutation::DeleteStudy(name) => e.study_name = name.clone(),
            Mutation::PutTrial(study, t) => {
                e.study_name = study.clone();
                e.trial = Some(t.clone());
            }
            Mutation::DeleteTrial(study, id) => {
                e.study_name = study.clone();
                e.trial_id = *id;
            }
            Mutation::PutOperation(o) => e.op = Some(o.clone()),
        }
        e
    }

    fn from_envelope(kind: u8, e: Envelope) -> Result<Mutation, DsError> {
        let missing = |what: &str| DsError::Storage(format!("wal record missing {what}"));
        Ok(match kind {
            KIND_PUT_STUDY => Mutation::PutStudy(e.study.ok_or_else(|| missing("study"))?),
            KIND_DELETE_STUDY => Mutation::DeleteStudy(e.study_name),
            KIND_PUT_TRIAL => Mutation::PutTrial(e.study_name, e.trial.ok_or_else(|| missing("trial"))?),
            KIND_DELETE_TRIAL => Mutation::DeleteTrial(e.study_name, e.trial_id),
            KIND_PUT_OPERATION => Mutation::PutOperation(e.op.ok_or_else(|| missing("op"))?),
            other => return Err(DsError::Storage(format!("unknown wal record kind {other}"))),
        })
    }
}

/// Durable datastore: in-memory state + write-ahead log.
pub struct WalDatastore {
    mem: InMemoryDatastore,
    log: Mutex<BufWriter<File>>,
    path: PathBuf,
    /// When true, fsync after every append (slower, strongest durability).
    sync_every_write: bool,
}

impl WalDatastore {
    /// Open (or create) a WAL-backed store at `path`, replaying any
    /// existing log.
    pub fn open(path: impl AsRef<Path>) -> Result<Self, DsError> {
        Self::open_with_sync(path, false)
    }

    pub fn open_with_sync(path: impl AsRef<Path>, sync_every_write: bool) -> Result<Self, DsError> {
        let path = path.as_ref().to_path_buf();
        let mem = InMemoryDatastore::new();
        let mut valid_len = 0u64;
        if path.exists() {
            let mut f = File::open(&path).map_err(io_err)?;
            let mut buf = Vec::new();
            f.read_to_end(&mut buf).map_err(io_err)?;
            let mut pos = 0usize;
            loop {
                if pos + 4 > buf.len() {
                    break; // torn length prefix
                }
                let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
                if len == 0 || pos + 4 + len > buf.len() {
                    break; // torn record
                }
                let kind = buf[pos + 4];
                let payload = &buf[pos + 5..pos + 4 + len];
                let env: Envelope = decode(payload)
                    .map_err(|e| DsError::Storage(format!("wal decode: {e}")))?;
                let m = Mutation::from_envelope(kind, env)?;
                apply(&mem, &m)?;
                pos += 4 + len;
                valid_len = pos as u64;
            }
        }
        let mut file = OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(&path)
            .map_err(io_err)?;
        // Truncate any torn tail so future appends start at a clean record
        // boundary.
        file.set_len(valid_len).map_err(io_err)?;
        file.seek(SeekFrom::End(0)).map_err(io_err)?;
        Ok(Self {
            mem,
            log: Mutex::new(BufWriter::new(file)),
            path,
            sync_every_write,
        })
    }

    /// Rewrite the log as a compact snapshot of current state (atomic
    /// replace). Bounds recovery time for long-lived servers.
    pub fn compact(&self) -> Result<(), DsError> {
        let mut log = self.log.lock().unwrap();
        let tmp = self.path.with_extension("wal.tmp");
        {
            let file = File::create(&tmp).map_err(io_err)?;
            let mut w = BufWriter::new(file);
            for study in self.mem.list_studies()? {
                let name = study.name.clone();
                append_record(&mut w, &Mutation::PutStudy(study))?;
                for trial in self.mem.list_trials(&name)? {
                    append_record(&mut w, &Mutation::PutTrial(name.clone(), trial))?;
                }
            }
            for op in self.mem.pending_operations()? {
                append_record(&mut w, &Mutation::PutOperation(op))?;
            }
            w.flush().map_err(io_err)?;
            w.get_ref().sync_all().map_err(io_err)?;
        }
        std::fs::rename(&tmp, &self.path).map_err(io_err)?;
        let file = OpenOptions::new()
            .read(true)
            .append(true)
            .open(&self.path)
            .map_err(io_err)?;
        *log = BufWriter::new(file);
        Ok(())
    }

    /// Size of the log file in bytes.
    pub fn log_size(&self) -> u64 {
        std::fs::metadata(&self.path).map(|m| m.len()).unwrap_or(0)
    }

    fn append(&self, m: &Mutation) -> Result<(), DsError> {
        let mut log = self.log.lock().unwrap();
        append_record(&mut *log, m)?;
        log.flush().map_err(io_err)?;
        if self.sync_every_write {
            log.get_ref().sync_data().map_err(io_err)?;
        }
        Ok(())
    }
}

fn io_err(e: std::io::Error) -> DsError {
    DsError::Storage(e.to_string())
}

fn append_record<W: IoWrite>(w: &mut W, m: &Mutation) -> Result<(), DsError> {
    let payload = encode(&m.to_envelope());
    let total = (1 + payload.len()) as u32;
    w.write_all(&total.to_le_bytes()).map_err(io_err)?;
    w.write_all(&[m.kind()]).map_err(io_err)?;
    w.write_all(&payload).map_err(io_err)?;
    Ok(())
}

fn apply(mem: &InMemoryDatastore, m: &Mutation) -> Result<(), DsError> {
    match m {
        Mutation::PutStudy(s) => mem.apply_put_study(s.clone()),
        Mutation::DeleteStudy(name) => mem.apply_delete_study(name),
        Mutation::PutTrial(study, t) => mem.apply_put_trial(study, t.clone())?,
        Mutation::DeleteTrial(study, id) => mem.apply_delete_trial(study, *id),
        Mutation::PutOperation(o) => mem.apply_put_operation(o.clone()),
    }
    Ok(())
}

impl Datastore for WalDatastore {
    fn create_study(&self, study: StudyProto) -> Result<StudyProto, DsError> {
        let created = self.mem.create_study(study)?;
        self.append(&Mutation::PutStudy(created.clone()))?;
        Ok(created)
    }

    fn get_study(&self, name: &str) -> Result<StudyProto, DsError> {
        self.mem.get_study(name)
    }

    fn lookup_study(&self, display_name: &str) -> Result<StudyProto, DsError> {
        self.mem.lookup_study(display_name)
    }

    fn list_studies(&self) -> Result<Vec<StudyProto>, DsError> {
        self.mem.list_studies()
    }

    fn update_study(&self, study: StudyProto) -> Result<(), DsError> {
        self.mem.update_study(study.clone())?;
        self.append(&Mutation::PutStudy(study))
    }

    fn delete_study(&self, name: &str) -> Result<(), DsError> {
        self.mem.delete_study(name)?;
        self.append(&Mutation::DeleteStudy(name.to_string()))
    }

    fn create_trial(&self, study: &str, trial: TrialProto) -> Result<TrialProto, DsError> {
        let created = self.mem.create_trial(study, trial)?;
        self.append(&Mutation::PutTrial(study.to_string(), created.clone()))?;
        Ok(created)
    }

    fn get_trial(&self, study: &str, id: u64) -> Result<TrialProto, DsError> {
        self.mem.get_trial(study, id)
    }

    fn list_trials(&self, study: &str) -> Result<Vec<TrialProto>, DsError> {
        self.mem.list_trials(study)
    }

    fn query_trials(
        &self,
        study: &str,
        filter: &super::query::TrialFilter,
    ) -> Result<Vec<TrialProto>, DsError> {
        self.mem.query_trials(study, filter)
    }

    fn update_trial(&self, study: &str, trial: TrialProto) -> Result<(), DsError> {
        self.mem.update_trial(study, trial.clone())?;
        self.append(&Mutation::PutTrial(study.to_string(), trial))
    }

    fn delete_trial(&self, study: &str, id: u64) -> Result<(), DsError> {
        self.mem.delete_trial(study, id)?;
        self.append(&Mutation::DeleteTrial(study.to_string(), id))
    }

    fn mutate_trial(
        &self,
        study: &str,
        id: u64,
        f: &mut dyn FnMut(&mut TrialProto) -> Result<(), DsError>,
    ) -> Result<TrialProto, DsError> {
        let updated = self.mem.mutate_trial(study, id, f)?;
        self.append(&Mutation::PutTrial(study.to_string(), updated.clone()))?;
        Ok(updated)
    }

    fn create_operation(&self, op: OperationProto) -> Result<OperationProto, DsError> {
        let created = self.mem.create_operation(op)?;
        self.append(&Mutation::PutOperation(created.clone()))?;
        Ok(created)
    }

    fn get_operation(&self, name: &str) -> Result<OperationProto, DsError> {
        self.mem.get_operation(name)
    }

    fn update_operation(&self, op: OperationProto) -> Result<(), DsError> {
        self.mem.update_operation(op.clone())?;
        self.append(&Mutation::PutOperation(op))
    }

    fn pending_operations(&self) -> Result<Vec<OperationProto>, DsError> {
        self.mem.pending_operations()
    }

    fn update_metadata(
        &self,
        study: &str,
        updates: &[UnitMetadataUpdate],
    ) -> Result<(), DsError> {
        self.mem.update_metadata(study, updates)?;
        // Log the resulting rows (study spec and/or touched trials).
        let s = self.mem.get_study(study)?;
        self.append(&Mutation::PutStudy(s))?;
        for u in updates {
            if u.trial_id != 0 {
                let t = self.mem.get_trial(study, u.trial_id)?;
                self.append(&Mutation::PutTrial(study.to_string(), t))?;
            }
        }
        Ok(())
    }

    fn trial_count(&self, study: &str) -> Result<usize, DsError> {
        self.mem.trial_count(study)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::messages::TrialState;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ossvizier-wal-{tag}-{}-{}",
            std::process::id(),
            crate::util::id::next_uid()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn study(display: &str) -> StudyProto {
        StudyProto {
            display_name: display.to_string(),
            ..Default::default()
        }
    }

    #[test]
    fn state_survives_reopen() {
        let dir = tmpdir("reopen");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            let s = ds.create_study(study("exp")).unwrap();
            let mut t = TrialProto::default();
            t.client_id = "w0".into();
            let t = ds.create_trial(&s.name, t).unwrap();
            ds.mutate_trial(&s.name, t.id, &mut |t| {
                t.state = TrialState::Active;
                Ok(())
            })
            .unwrap();
            ds.create_operation(OperationProto {
                study_name: s.name.clone(),
                count: 2,
                ..Default::default()
            })
            .unwrap();
        } // drop = crash without any shutdown handshake
        let ds = WalDatastore::open(&path).unwrap();
        let s = ds.lookup_study("exp").unwrap();
        let t = ds.get_trial(&s.name, 1).unwrap();
        assert_eq!(t.state, TrialState::Active);
        assert_eq!(t.client_id, "w0");
        // Pending operation recovered -> service can resume it.
        let pending = ds.pending_operations().unwrap();
        assert_eq!(pending.len(), 1);
        assert_eq!(pending[0].count, 2);
        // Id counters continue, no collisions.
        let t2 = ds.create_trial(&s.name, TrialProto::default()).unwrap();
        assert_eq!(t2.id, 2);
        let s2 = ds.create_study(study("exp2")).unwrap();
        assert_eq!(s2.name, "studies/2");
    }

    #[test]
    fn torn_tail_is_discarded() {
        let dir = tmpdir("torn");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            ds.create_study(study("a")).unwrap();
            ds.create_study(study("b")).unwrap();
        }
        // Corrupt: chop bytes off the final record.
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 3).unwrap();
        let ds = WalDatastore::open(&path).unwrap();
        assert!(ds.lookup_study("a").is_ok());
        assert!(ds.lookup_study("b").is_err(), "torn record dropped");
        // Store remains writable after truncation.
        ds.create_study(study("c")).unwrap();
        drop(ds);
        let ds = WalDatastore::open(&path).unwrap();
        assert!(ds.lookup_study("c").is_ok());
    }

    #[test]
    fn deletes_survive_replay() {
        let dir = tmpdir("delete");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            let s = ds.create_study(study("a")).unwrap();
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
            ds.delete_trial(&s.name, 1).unwrap();
            let s2 = ds.create_study(study("gone")).unwrap();
            ds.delete_study(&s2.name).unwrap();
        }
        let ds = WalDatastore::open(&path).unwrap();
        let s = ds.lookup_study("a").unwrap();
        assert!(ds.get_trial(&s.name, 1).is_err());
        assert!(ds.get_trial(&s.name, 2).is_ok());
        assert!(ds.lookup_study("gone").is_err());
    }

    #[test]
    fn compaction_shrinks_log_and_preserves_state() {
        let dir = tmpdir("compact");
        let path = dir.join("store.wal");
        let ds = WalDatastore::open(&path).unwrap();
        let s = ds.create_study(study("a")).unwrap();
        let t = ds.create_trial(&s.name, TrialProto::default()).unwrap();
        // Many updates to the same trial bloat the log.
        for i in 0..200 {
            ds.mutate_trial(&s.name, t.id, &mut |t| {
                t.created_ms = i;
                Ok(())
            })
            .unwrap();
        }
        let before = ds.log_size();
        ds.compact().unwrap();
        let after = ds.log_size();
        assert!(after < before / 10, "log {before} -> {after}");
        // Post-compaction appends + replay still correct.
        ds.create_trial(&s.name, TrialProto::default()).unwrap();
        drop(ds);
        let ds = WalDatastore::open(&path).unwrap();
        assert_eq!(ds.trial_count(&ds.lookup_study("a").unwrap().name).unwrap(), 2);
        assert_eq!(ds.get_trial("studies/1", 1).unwrap().created_ms, 199);
    }

    #[test]
    fn metadata_updates_durable() {
        let dir = tmpdir("md");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            let s = ds.create_study(study("a")).unwrap();
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
            ds.update_metadata(
                &s.name,
                &[
                    UnitMetadataUpdate {
                        trial_id: 0,
                        item: Some(crate::wire::messages::MetadataItem {
                            namespace: "evo".into(),
                            key: "state".into(),
                            value: b"pop1".to_vec(),
                        }),
                    },
                    UnitMetadataUpdate {
                        trial_id: 1,
                        item: Some(crate::wire::messages::MetadataItem {
                            namespace: "".into(),
                            key: "ckpt".into(),
                            value: b"path".to_vec(),
                        }),
                    },
                ],
            )
            .unwrap();
        }
        let ds = WalDatastore::open(&path).unwrap();
        let s = ds.lookup_study("a").unwrap();
        assert_eq!(s.spec.metadata[0].value, b"pop1");
        assert_eq!(ds.get_trial(&s.name, 1).unwrap().metadata[0].value, b"path");
    }
}
