//! Write-ahead-log datastore: durable storage with crash recovery.
//!
//! Every mutation is encoded as a [`Mutation`] record and appended to a log
//! file before the call returns. On startup the log is replayed, rebuilding
//! the exact pre-crash state — including non-done operations, which the
//! service then resumes (paper §3.2: "The Operations are stored in the
//! database and contain sufficient information to restart the computation
//! after a server crash, reboot, or update").
//!
//! # Group commit
//!
//! By default appends go through **group commit**: a writer applies its
//! mutation to the in-memory state and appends the encoded record to a
//! shared buffer under the commit lock, then blocks until a dedicated
//! committer thread has written the buffer to the file (and fsynced it,
//! in [`WalOptions::sync`] mode). The committer drains whatever
//! accumulated while the previous batch was being flushed, so K
//! concurrent writers share ~1 flush/fsync instead of paying K. Because
//! the in-memory apply and the buffer append happen atomically, replay
//! order always matches apply order. The commit lock does serialize the
//! (microsecond-scale) in-memory applies — the point of the batching is
//! amortizing the millisecond-scale flush/fsync, which happens outside
//! it; per-shard commit sequencing is a ROADMAP item.
//!
//! Acknowledgement = durability: `create_trial` & co. return only after
//! the batch containing their record is flushed, so every acknowledged
//! mutation survives a crash. A crash mid-batch leaves a torn final
//! record, which is detected and truncated at recovery — exactly the
//! record(s) whose writers were never acknowledged.
//!
//! The pre-group-commit behavior (append + flush inline, serially, under
//! the log lock) is kept as [`WalOptions::group_commit`]` = false` and
//! serves as the baseline in `bench_datastore`.
//!
//! Record framing: `[u32-le len][u8 kind][payload]`. A torn final record
//! (crash mid-write) is detected and truncated at recovery.

use super::memory::InMemoryDatastore;
use super::{Datastore, DsError};
use crate::wire::codec::{decode, encode, Reader, WireError, WireMessage, Writer};
use crate::wire::messages::{OperationProto, StudyProto, TrialProto, UnitMetadataUpdate};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write as IoWrite};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

const KIND_PUT_STUDY: u8 = 1;
const KIND_DELETE_STUDY: u8 = 2;
const KIND_PUT_TRIAL: u8 = 3;
const KIND_DELETE_TRIAL: u8 = 4;
const KIND_PUT_OPERATION: u8 = 5;

/// One durable mutation record.
#[derive(Debug, Clone, PartialEq)]
pub enum Mutation {
    PutStudy(StudyProto),
    DeleteStudy(String),
    PutTrial(String, TrialProto),
    DeleteTrial(String, u64),
    PutOperation(OperationProto),
}

/// Internal envelope so every mutation is one wire message.
#[derive(Debug, Default)]
struct Envelope {
    study_name: String,
    trial_id: u64,
    study: Option<StudyProto>,
    trial: Option<TrialProto>,
    op: Option<OperationProto>,
}

impl WireMessage for Envelope {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.study_name);
        w.u64(2, self.trial_id);
        if let Some(s) = &self.study {
            w.msg(3, s);
        }
        if let Some(t) = &self.trial {
            w.msg(4, t);
        }
        if let Some(o) = &self.op {
            w.msg(5, o);
        }
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut e = Envelope::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => e.study_name = v.as_string()?,
                2 => e.trial_id = v.as_u64()?,
                3 => e.study = Some(v.as_msg()?),
                4 => e.trial = Some(v.as_msg()?),
                5 => e.op = Some(v.as_msg()?),
                _ => {}
            }
        }
        Ok(e)
    }
}

impl Mutation {
    fn kind(&self) -> u8 {
        match self {
            Mutation::PutStudy(_) => KIND_PUT_STUDY,
            Mutation::DeleteStudy(_) => KIND_DELETE_STUDY,
            Mutation::PutTrial(..) => KIND_PUT_TRIAL,
            Mutation::DeleteTrial(..) => KIND_DELETE_TRIAL,
            Mutation::PutOperation(_) => KIND_PUT_OPERATION,
        }
    }

    fn to_envelope(&self) -> Envelope {
        let mut e = Envelope::default();
        match self {
            Mutation::PutStudy(s) => e.study = Some(s.clone()),
            Mutation::DeleteStudy(name) => e.study_name = name.clone(),
            Mutation::PutTrial(study, t) => {
                e.study_name = study.clone();
                e.trial = Some(t.clone());
            }
            Mutation::DeleteTrial(study, id) => {
                e.study_name = study.clone();
                e.trial_id = *id;
            }
            Mutation::PutOperation(o) => e.op = Some(o.clone()),
        }
        e
    }

    fn from_envelope(kind: u8, e: Envelope) -> Result<Mutation, DsError> {
        let missing = |what: &str| DsError::Storage(format!("wal record missing {what}"));
        Ok(match kind {
            KIND_PUT_STUDY => Mutation::PutStudy(e.study.ok_or_else(|| missing("study"))?),
            KIND_DELETE_STUDY => Mutation::DeleteStudy(e.study_name),
            KIND_PUT_TRIAL => Mutation::PutTrial(e.study_name, e.trial.ok_or_else(|| missing("trial"))?),
            KIND_DELETE_TRIAL => Mutation::DeleteTrial(e.study_name, e.trial_id),
            KIND_PUT_OPERATION => Mutation::PutOperation(e.op.ok_or_else(|| missing("op"))?),
            other => return Err(DsError::Storage(format!("unknown wal record kind {other}"))),
        })
    }
}

/// Durability / batching knobs for [`WalDatastore`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalOptions {
    /// fsync each commit batch before acknowledging its writers
    /// (durable against machine crash, not just process crash).
    pub sync: bool,
    /// Batch concurrent appends through the committer thread (group
    /// commit). `false` = the serial legacy path: every append writes and
    /// flushes inline under the log lock (benchmark baseline).
    pub group_commit: bool,
}

impl Default for WalOptions {
    fn default() -> Self {
        Self {
            sync: false,
            group_commit: true,
        }
    }
}

/// Shared state between writers and the committer thread.
#[derive(Default)]
struct CommitState {
    /// Encoded records waiting for the next batch.
    buf: Vec<u8>,
    /// Records enqueued so far (monotonic).
    enqueued: u64,
    /// Records durably flushed so far.
    durable: u64,
    /// True while the committer is writing a batch it has already taken
    /// out of `buf` (those records are neither in `buf` nor durable yet).
    inflight: bool,
    /// Sticky committer I/O error; fails all subsequent commits.
    error: Option<String>,
    shutdown: bool,
}

struct CommitShared {
    state: Mutex<CommitState>,
    /// Committer waits here for work (or shutdown).
    work: Condvar,
    /// Writers wait here for `durable` to cover their record.
    done: Condvar,
}

/// Durable datastore: in-memory state + write-ahead log.
pub struct WalDatastore {
    mem: InMemoryDatastore,
    log: Arc<Mutex<BufWriter<File>>>,
    path: PathBuf,
    opts: WalOptions,
    commit: Option<Arc<CommitShared>>,
    committer: Option<JoinHandle<()>>,
    /// Batches flushed by the committer (observability: `records_flushed /
    /// batches_flushed` = achieved group-commit factor).
    batches_flushed: Arc<AtomicU64>,
    records_flushed: Arc<AtomicU64>,
}

impl WalDatastore {
    /// Open (or create) a WAL-backed store at `path`, replaying any
    /// existing log. Group commit on, no fsync (see [`WalOptions`]).
    pub fn open(path: impl AsRef<Path>) -> Result<Self, DsError> {
        Self::open_with_options(path, WalOptions::default())
    }

    /// `open`, but fsync every commit batch when `sync_every_write`.
    pub fn open_with_sync(path: impl AsRef<Path>, sync_every_write: bool) -> Result<Self, DsError> {
        Self::open_with_options(
            path,
            WalOptions {
                sync: sync_every_write,
                ..WalOptions::default()
            },
        )
    }

    /// Open with explicit durability/batching options.
    pub fn open_with_options(path: impl AsRef<Path>, opts: WalOptions) -> Result<Self, DsError> {
        let path = path.as_ref().to_path_buf();
        let mem = InMemoryDatastore::new();
        let mut valid_len = 0u64;
        if path.exists() {
            let mut f = File::open(&path).map_err(io_err)?;
            let mut buf = Vec::new();
            f.read_to_end(&mut buf).map_err(io_err)?;
            let mut pos = 0usize;
            loop {
                if pos + 4 > buf.len() {
                    break; // torn length prefix
                }
                let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
                if len == 0 || pos + 4 + len > buf.len() {
                    break; // torn record
                }
                let kind = buf[pos + 4];
                let payload = &buf[pos + 5..pos + 4 + len];
                let env: Envelope = decode(payload)
                    .map_err(|e| DsError::Storage(format!("wal decode: {e}")))?;
                let m = Mutation::from_envelope(kind, env)?;
                apply(&mem, &m)?;
                pos += 4 + len;
                valid_len = pos as u64;
            }
        }
        let mut file = OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(&path)
            .map_err(io_err)?;
        // Truncate any torn tail so future appends start at a clean record
        // boundary.
        file.set_len(valid_len).map_err(io_err)?;
        file.seek(SeekFrom::End(0)).map_err(io_err)?;
        let log = Arc::new(Mutex::new(BufWriter::new(file)));
        let batches_flushed = Arc::new(AtomicU64::new(0));
        let records_flushed = Arc::new(AtomicU64::new(0));

        let (commit, committer) = if opts.group_commit {
            let shared = Arc::new(CommitShared {
                state: Mutex::new(CommitState::default()),
                work: Condvar::new(),
                done: Condvar::new(),
            });
            let handle = std::thread::Builder::new()
                .name("wal-committer".into())
                .spawn({
                    let shared = Arc::clone(&shared);
                    let log = Arc::clone(&log);
                    let batches = Arc::clone(&batches_flushed);
                    let records = Arc::clone(&records_flushed);
                    let sync = opts.sync;
                    move || committer_loop(&shared, &log, sync, &batches, &records)
                })
                .map_err(io_err)?;
            (Some(shared), Some(handle))
        } else {
            (None, None)
        };
        Ok(Self {
            mem,
            log,
            path,
            opts,
            commit,
            committer,
            batches_flushed,
            records_flushed,
        })
    }

    /// Rewrite the log as a compact snapshot of current state (atomic
    /// replace). Bounds recovery time for long-lived servers.
    pub fn compact(&self) -> Result<(), DsError> {
        // Quiesce the committer: wait until both the shared buffer and
        // any in-flight batch have been durably flushed (or the committer
        // reported an error), then keep holding the commit lock through
        // the snapshot swap. Writers take this lock before touching mem,
        // so state cannot change under the snapshot, and no writer is
        // ever acknowledged against records that only the pre-compaction
        // log contained.
        let _guard = match &self.commit {
            Some(shared) => {
                let mut state = shared.state.lock().unwrap();
                while (!state.buf.is_empty() || state.inflight) && state.error.is_none() {
                    shared.work.notify_one();
                    state = shared.done.wait(state).unwrap();
                }
                if let Some(e) = &state.error {
                    return Err(DsError::Storage(format!("wal committer failed: {e}")));
                }
                Some(state)
            }
            None => None,
        };

        let mut log = self.log.lock().unwrap();
        let tmp = self.path.with_extension("wal.tmp");
        {
            let file = File::create(&tmp).map_err(io_err)?;
            let mut w = BufWriter::new(file);
            for study in self.mem.list_studies()? {
                let name = study.name.clone();
                append_record(&mut w, &Mutation::PutStudy(study))?;
                for trial in self.mem.list_trials(&name)? {
                    append_record(&mut w, &Mutation::PutTrial(name.clone(), trial))?;
                }
            }
            for op in self.mem.pending_operations()? {
                append_record(&mut w, &Mutation::PutOperation(op))?;
            }
            w.flush().map_err(io_err)?;
            w.get_ref().sync_all().map_err(io_err)?;
        }
        std::fs::rename(&tmp, &self.path).map_err(io_err)?;
        let file = OpenOptions::new()
            .read(true)
            .append(true)
            .open(&self.path)
            .map_err(io_err)?;
        *log = BufWriter::new(file);
        Ok(())
    }

    /// Size of the log file in bytes.
    pub fn log_size(&self) -> u64 {
        std::fs::metadata(&self.path).map(|m| m.len()).unwrap_or(0)
    }

    /// Batches the committer has flushed (0 in serial mode).
    pub fn batches_flushed(&self) -> u64 {
        self.batches_flushed.load(Ordering::Relaxed)
    }

    /// Records flushed through the committer (0 in serial mode).
    /// `records_flushed() / batches_flushed()` is the achieved
    /// group-commit factor.
    pub fn records_flushed(&self) -> u64 {
        self.records_flushed.load(Ordering::Relaxed)
    }

    /// Run a mutating operation and durably log the mutations it returns.
    ///
    /// Group-commit mode: the in-memory apply and the buffer append happen
    /// under the commit lock (so log order == apply order), then the
    /// writer blocks until the committer has flushed its records.
    /// Serial mode: apply, then append + flush inline under the log lock.
    fn commit<T>(
        &self,
        op: impl FnOnce(&InMemoryDatastore) -> Result<(T, Vec<Mutation>), DsError>,
    ) -> Result<T, DsError> {
        match &self.commit {
            Some(shared) => {
                let mut state = shared.state.lock().unwrap();
                if let Some(e) = &state.error {
                    return Err(DsError::Storage(format!("wal committer failed: {e}")));
                }
                let (value, muts) = op(&self.mem)?;
                if muts.is_empty() {
                    return Ok(value);
                }
                for m in &muts {
                    append_record(&mut state.buf, m)?;
                }
                state.enqueued += muts.len() as u64;
                let my_seq = state.enqueued;
                shared.work.notify_one();
                while state.durable < my_seq && state.error.is_none() {
                    state = shared.done.wait(state).unwrap();
                }
                if let Some(e) = &state.error {
                    return Err(DsError::Storage(format!("wal committer failed: {e}")));
                }
                Ok(value)
            }
            None => {
                // The log lock spans the in-memory apply too, so records
                // for the same key cannot be appended in the opposite
                // order they were applied (replay = acknowledged state).
                let mut log = self.log.lock().unwrap();
                let (value, muts) = op(&self.mem)?;
                for m in &muts {
                    append_record(&mut *log, m)?;
                }
                log.flush().map_err(io_err)?;
                if self.opts.sync {
                    log.get_ref().sync_data().map_err(io_err)?;
                }
                Ok(value)
            }
        }
    }
}

impl Drop for WalDatastore {
    fn drop(&mut self) {
        if let Some(shared) = &self.commit {
            let mut state = shared.state.lock().unwrap();
            state.shutdown = true;
            shared.work.notify_all();
            drop(state);
        }
        if let Some(handle) = self.committer.take() {
            let _ = handle.join();
        }
        // Best-effort flush of the serial path's buffered writer.
        if let Ok(mut log) = self.log.lock() {
            let _ = log.flush();
        }
    }
}

/// The committer: drains the shared buffer in batches. Whatever
/// accumulates while one batch is being written becomes the next batch,
/// so the batch size adapts to the arrival rate.
fn committer_loop(
    shared: &CommitShared,
    log: &Mutex<BufWriter<File>>,
    sync: bool,
    batches: &AtomicU64,
    records: &AtomicU64,
) {
    loop {
        let (batch, target) = {
            let mut state = shared.state.lock().unwrap();
            while state.buf.is_empty() && !state.shutdown {
                state = shared.work.wait(state).unwrap();
            }
            if state.buf.is_empty() && state.shutdown {
                return;
            }
            state.inflight = true;
            (std::mem::take(&mut state.buf), state.enqueued)
        };
        // I/O happens outside the commit lock: writers keep enqueueing
        // into the (now empty) buffer while this batch hits the disk.
        let result = (|| -> Result<(), std::io::Error> {
            let mut log = log.lock().unwrap();
            log.write_all(&batch)?;
            log.flush()?;
            if sync {
                log.get_ref().sync_data()?;
            }
            Ok(())
        })();
        let mut state = shared.state.lock().unwrap();
        state.inflight = false;
        match result {
            Ok(()) => {
                let n_before = state.durable;
                state.durable = state.durable.max(target);
                batches.fetch_add(1, Ordering::Relaxed);
                records.fetch_add(state.durable - n_before, Ordering::Relaxed);
            }
            Err(e) => {
                state.error = Some(e.to_string());
            }
        }
        shared.done.notify_all();
    }
}

fn io_err(e: std::io::Error) -> DsError {
    DsError::Storage(e.to_string())
}

fn append_record<W: IoWrite>(w: &mut W, m: &Mutation) -> Result<(), DsError> {
    let payload = encode(&m.to_envelope());
    let total = (1 + payload.len()) as u32;
    w.write_all(&total.to_le_bytes()).map_err(io_err)?;
    w.write_all(&[m.kind()]).map_err(io_err)?;
    w.write_all(&payload).map_err(io_err)?;
    Ok(())
}

fn apply(mem: &InMemoryDatastore, m: &Mutation) -> Result<(), DsError> {
    match m {
        Mutation::PutStudy(s) => mem.apply_put_study(s.clone()),
        Mutation::DeleteStudy(name) => mem.apply_delete_study(name),
        Mutation::PutTrial(study, t) => mem.apply_put_trial(study, t.clone())?,
        Mutation::DeleteTrial(study, id) => mem.apply_delete_trial(study, *id),
        Mutation::PutOperation(o) => mem.apply_put_operation(o.clone()),
    }
    Ok(())
}

impl Datastore for WalDatastore {
    fn create_study(&self, study: StudyProto) -> Result<StudyProto, DsError> {
        self.commit(|mem| {
            let created = mem.create_study(study)?;
            let m = Mutation::PutStudy(created.clone());
            Ok((created, vec![m]))
        })
    }

    fn get_study(&self, name: &str) -> Result<StudyProto, DsError> {
        self.mem.get_study(name)
    }

    fn lookup_study(&self, display_name: &str) -> Result<StudyProto, DsError> {
        self.mem.lookup_study(display_name)
    }

    fn list_studies(&self) -> Result<Vec<StudyProto>, DsError> {
        self.mem.list_studies()
    }

    fn list_studies_page(
        &self,
        page_size: usize,
        page_token: &str,
    ) -> Result<super::StudyPage, DsError> {
        self.mem.list_studies_page(page_size, page_token)
    }

    fn update_study(&self, study: StudyProto) -> Result<(), DsError> {
        self.commit(|mem| {
            mem.update_study(study.clone())?;
            Ok(((), vec![Mutation::PutStudy(study)]))
        })
    }

    fn delete_study(&self, name: &str) -> Result<(), DsError> {
        self.commit(|mem| {
            mem.delete_study(name)?;
            Ok(((), vec![Mutation::DeleteStudy(name.to_string())]))
        })
    }

    fn create_trial(&self, study: &str, trial: TrialProto) -> Result<TrialProto, DsError> {
        self.commit(|mem| {
            let created = mem.create_trial(study, trial)?;
            let m = Mutation::PutTrial(study.to_string(), created.clone());
            Ok((created, vec![m]))
        })
    }

    fn get_trial(&self, study: &str, id: u64) -> Result<TrialProto, DsError> {
        self.mem.get_trial(study, id)
    }

    fn list_trials(&self, study: &str) -> Result<Vec<TrialProto>, DsError> {
        self.mem.list_trials(study)
    }

    fn list_trials_page(
        &self,
        study: &str,
        page_size: usize,
        page_token: &str,
    ) -> Result<super::TrialPage, DsError> {
        // Reads bypass the log: delegate to the in-memory image's keyed
        // page scan.
        self.mem.list_trials_page(study, page_size, page_token)
    }

    fn query_trials(
        &self,
        study: &str,
        filter: &super::query::TrialFilter,
    ) -> Result<Vec<TrialProto>, DsError> {
        self.mem.query_trials(study, filter)
    }

    fn update_trial(&self, study: &str, trial: TrialProto) -> Result<(), DsError> {
        self.commit(|mem| {
            mem.update_trial(study, trial.clone())?;
            Ok(((), vec![Mutation::PutTrial(study.to_string(), trial)]))
        })
    }

    fn delete_trial(&self, study: &str, id: u64) -> Result<(), DsError> {
        self.commit(|mem| {
            mem.delete_trial(study, id)?;
            Ok(((), vec![Mutation::DeleteTrial(study.to_string(), id)]))
        })
    }

    fn mutate_trial(
        &self,
        study: &str,
        id: u64,
        f: &mut dyn FnMut(&mut TrialProto) -> Result<(), DsError>,
    ) -> Result<TrialProto, DsError> {
        self.commit(|mem| {
            let updated = mem.mutate_trial(study, id, f)?;
            let m = Mutation::PutTrial(study.to_string(), updated.clone());
            Ok((updated, vec![m]))
        })
    }

    fn create_operation(&self, op: OperationProto) -> Result<OperationProto, DsError> {
        self.commit(|mem| {
            let created = mem.create_operation(op)?;
            let m = Mutation::PutOperation(created.clone());
            Ok((created, vec![m]))
        })
    }

    fn get_operation(&self, name: &str) -> Result<OperationProto, DsError> {
        self.mem.get_operation(name)
    }

    fn update_operation(&self, op: OperationProto) -> Result<(), DsError> {
        self.commit(|mem| {
            mem.update_operation(op.clone())?;
            Ok(((), vec![Mutation::PutOperation(op)]))
        })
    }

    fn pending_operations(&self) -> Result<Vec<OperationProto>, DsError> {
        self.mem.pending_operations()
    }

    fn update_metadata(
        &self,
        study: &str,
        updates: &[UnitMetadataUpdate],
    ) -> Result<(), DsError> {
        self.commit(|mem| {
            mem.update_metadata(study, updates)?;
            // Log the resulting rows (study spec and/or touched trials)
            // as one atomic batch.
            let mut muts = vec![Mutation::PutStudy(mem.get_study(study)?)];
            for u in updates {
                if u.trial_id != 0 {
                    let t = mem.get_trial(study, u.trial_id)?;
                    muts.push(Mutation::PutTrial(study.to_string(), t));
                }
            }
            Ok(((), muts))
        })
    }

    fn trial_count(&self, study: &str) -> Result<usize, DsError> {
        self.mem.trial_count(study)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::messages::TrialState;
    use std::sync::Arc;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ossvizier-wal-{tag}-{}-{}",
            std::process::id(),
            crate::util::id::next_uid()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn study(display: &str) -> StudyProto {
        StudyProto {
            display_name: display.to_string(),
            ..Default::default()
        }
    }

    #[test]
    fn state_survives_reopen() {
        let dir = tmpdir("reopen");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            let s = ds.create_study(study("exp")).unwrap();
            let mut t = TrialProto::default();
            t.client_id = "w0".into();
            let t = ds.create_trial(&s.name, t).unwrap();
            ds.mutate_trial(&s.name, t.id, &mut |t| {
                t.state = TrialState::Active;
                Ok(())
            })
            .unwrap();
            ds.create_operation(OperationProto {
                study_name: s.name.clone(),
                count: 2,
                ..Default::default()
            })
            .unwrap();
        } // drop = crash without any shutdown handshake
        let ds = WalDatastore::open(&path).unwrap();
        let s = ds.lookup_study("exp").unwrap();
        let t = ds.get_trial(&s.name, 1).unwrap();
        assert_eq!(t.state, TrialState::Active);
        assert_eq!(t.client_id, "w0");
        // Pending operation recovered -> service can resume it.
        let pending = ds.pending_operations().unwrap();
        assert_eq!(pending.len(), 1);
        assert_eq!(pending[0].count, 2);
        // Id counters continue, no collisions.
        let t2 = ds.create_trial(&s.name, TrialProto::default()).unwrap();
        assert_eq!(t2.id, 2);
        let s2 = ds.create_study(study("exp2")).unwrap();
        assert_eq!(s2.name, "studies/2");
    }

    #[test]
    fn torn_tail_is_discarded() {
        let dir = tmpdir("torn");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            ds.create_study(study("a")).unwrap();
            ds.create_study(study("b")).unwrap();
        }
        // Corrupt: chop bytes off the final record.
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 3).unwrap();
        let ds = WalDatastore::open(&path).unwrap();
        assert!(ds.lookup_study("a").is_ok());
        assert!(ds.lookup_study("b").is_err(), "torn record dropped");
        // Store remains writable after truncation.
        ds.create_study(study("c")).unwrap();
        drop(ds);
        let ds = WalDatastore::open(&path).unwrap();
        assert!(ds.lookup_study("c").is_ok());
    }

    #[test]
    fn deletes_survive_replay() {
        let dir = tmpdir("delete");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            let s = ds.create_study(study("a")).unwrap();
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
            ds.delete_trial(&s.name, 1).unwrap();
            let s2 = ds.create_study(study("gone")).unwrap();
            ds.delete_study(&s2.name).unwrap();
        }
        let ds = WalDatastore::open(&path).unwrap();
        let s = ds.lookup_study("a").unwrap();
        assert!(ds.get_trial(&s.name, 1).is_err());
        assert!(ds.get_trial(&s.name, 2).is_ok());
        assert!(ds.lookup_study("gone").is_err());
    }

    #[test]
    fn compaction_shrinks_log_and_preserves_state() {
        let dir = tmpdir("compact");
        let path = dir.join("store.wal");
        let ds = WalDatastore::open(&path).unwrap();
        let s = ds.create_study(study("a")).unwrap();
        let t = ds.create_trial(&s.name, TrialProto::default()).unwrap();
        // Many updates to the same trial bloat the log.
        for i in 0..200 {
            ds.mutate_trial(&s.name, t.id, &mut |t| {
                t.created_ms = i;
                Ok(())
            })
            .unwrap();
        }
        let before = ds.log_size();
        ds.compact().unwrap();
        let after = ds.log_size();
        assert!(after < before / 10, "log {before} -> {after}");
        // Post-compaction appends + replay still correct.
        ds.create_trial(&s.name, TrialProto::default()).unwrap();
        drop(ds);
        let ds = WalDatastore::open(&path).unwrap();
        assert_eq!(ds.trial_count(&ds.lookup_study("a").unwrap().name).unwrap(), 2);
        assert_eq!(ds.get_trial("studies/1", 1).unwrap().created_ms, 199);
    }

    #[test]
    fn metadata_updates_durable() {
        let dir = tmpdir("md");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            let s = ds.create_study(study("a")).unwrap();
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
            ds.update_metadata(
                &s.name,
                &[
                    UnitMetadataUpdate {
                        trial_id: 0,
                        item: Some(crate::wire::messages::MetadataItem {
                            namespace: "evo".into(),
                            key: "state".into(),
                            value: b"pop1".to_vec(),
                        }),
                    },
                    UnitMetadataUpdate {
                        trial_id: 1,
                        item: Some(crate::wire::messages::MetadataItem {
                            namespace: "".into(),
                            key: "ckpt".into(),
                            value: b"path".to_vec(),
                        }),
                    },
                ],
            )
            .unwrap();
        }
        let ds = WalDatastore::open(&path).unwrap();
        let s = ds.lookup_study("a").unwrap();
        assert_eq!(s.spec.metadata[0].value, b"pop1");
        assert_eq!(ds.get_trial(&s.name, 1).unwrap().metadata[0].value, b"path");
    }

    #[test]
    fn serial_mode_matches_group_commit_state() {
        let run = |opts: WalOptions, tag: &str| -> Vec<(u64, u64)> {
            let path = tmpdir(tag).join("store.wal");
            {
                let ds = WalDatastore::open_with_options(&path, opts).unwrap();
                let s = ds.create_study(study("m")).unwrap();
                for i in 0..20 {
                    let t = ds.create_trial(&s.name, TrialProto::default()).unwrap();
                    ds.mutate_trial(&s.name, t.id, &mut |t| {
                        t.created_ms = i;
                        Ok(())
                    })
                    .unwrap();
                }
                ds.delete_trial(&s.name, 5).unwrap();
            }
            let ds = WalDatastore::open(&path).unwrap();
            ds.list_trials("studies/1")
                .unwrap()
                .into_iter()
                .map(|t| (t.id, t.created_ms))
                .collect()
        };
        let grouped = run(WalOptions::default(), "gc");
        let serial = run(WalOptions { sync: false, group_commit: false }, "serial");
        assert_eq!(grouped, serial);
        assert_eq!(grouped.len(), 19);
    }

    #[test]
    fn concurrent_writers_share_flushes() {
        let path = tmpdir("batch").join("store.wal");
        let ds = Arc::new(WalDatastore::open_with_sync(&path, true).unwrap());
        let s = ds.create_study(study("gc")).unwrap();
        let threads = 8;
        let per_thread = 50u64;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let ds = Arc::clone(&ds);
                let name = s.name.clone();
                std::thread::spawn(move || {
                    for _ in 0..per_thread {
                        ds.create_trial(&name, TrialProto::default()).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total = threads as u64 * per_thread;
        assert_eq!(ds.trial_count(&s.name).unwrap() as u64, total);
        // +1 record for the create_study.
        assert_eq!(ds.records_flushed(), total + 1);
        assert!(
            ds.batches_flushed() <= ds.records_flushed(),
            "batches {} must not exceed records {}",
            ds.batches_flushed(),
            ds.records_flushed()
        );
        // All ids dense 1..=total, each durable before its ack.
        drop(ds);
        let ds = WalDatastore::open(&path).unwrap();
        let ids: Vec<u64> =
            ds.list_trials("studies/1").unwrap().into_iter().map(|t| t.id).collect();
        assert_eq!(ids, (1..=total).collect::<Vec<u64>>());
    }

    #[test]
    fn torn_group_commit_tail_preserves_acknowledged_writes() {
        // Acked mutations live in flushed batches; simulate a crash that
        // tears the *next* batch mid-record and verify every acked write
        // replays while the torn record is rejected.
        let dir = tmpdir("torn-gc");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            let s = ds.create_study(study("acked")).unwrap();
            for _ in 0..10 {
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
            }
        } // clean shutdown: 11 complete records on disk
        let acked_len = std::fs::metadata(&path).unwrap().len();

        // A crash mid-batch: half a record appended after the acked tail.
        let mut torn = Vec::new();
        append_record(
            &mut torn,
            &Mutation::PutTrial("studies/1".into(), TrialProto { id: 99, ..Default::default() }),
        )
        .unwrap();
        let half = &torn[..torn.len() / 2];
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(half).unwrap();
        f.sync_all().unwrap();
        drop(f);

        let ds = WalDatastore::open(&path).unwrap();
        assert_eq!(ds.trial_count("studies/1").unwrap(), 10, "all acked trials survive");
        assert!(ds.get_trial("studies/1", 99).is_err(), "torn record rejected");
        // Recovery truncated back to the acked prefix.
        assert_eq!(ds.log_size(), acked_len);
    }
}
