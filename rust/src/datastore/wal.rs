//! Write-ahead-log datastore: durable storage with crash recovery.
//!
//! Every mutation is encoded as a [`Mutation`] record and appended to the
//! log before the call returns. On startup the log is replayed, rebuilding
//! the exact pre-crash state — including non-done operations, which the
//! service then resumes (paper §3.2: "The Operations are stored in the
//! database and contain sufficient information to restart the computation
//! after a server crash, reboot, or update").
//!
//! # Layouts
//!
//! * **Single file** ([`WalOptions::segment_bytes`]` = None`, the
//!   baseline): one append-only file at `path`. `compact()` rewrites it
//!   in place and **stalls every commit** for the duration of the
//!   snapshot — the behavior this module's segmented layout deprecates.
//! * **Segmented** (`segment_bytes = Some(n)`): `path` is a directory of
//!   numbered segments. Appends go to the active segment
//!   (`wal.000017.log`), which the committer seals (flush + fsync) and
//!   rotates once it reaches `n` bytes. A background compactor thread
//!   snapshots state into a new *base* segment (`wal.000017.base`) and
//!   deletes superseded segments **without ever holding the commit
//!   path** — commits keep flowing into the active segment while the
//!   snapshot is cut.
//!
//! # Segment lifecycle
//!
//! ```text
//! wal.000001.log .. wal.000017.log   (sealed)   wal.000018.log (active)
//!        └── compactor: seal 18 → open 19, snapshot state,
//!            write wal.000018.base.tmp, fsync, rename to
//!            wal.000018.base, fsync dir, delete logs ≤ 18 + older bases
//! ```
//!
//! Replay order is *base first, then `.log` segments in ascending
//! order*. Torn-tail rules are per segment: only the final (highest
//! numbered) log segment may contain a torn record — it is truncated at
//! recovery, exactly like the single-file layout — while a torn record
//! in a sealed segment is reported as corruption (sealed segments are
//! fsynced at rotation, so a legal crash cannot tear them). A crash at
//! any point of the compaction leaves a recoverable directory: an
//! unpublished `*.tmp` snapshot is deleted at open, and once the base is
//! renamed into place the superseded segments are ignored (and cleaned
//! up) whether or not the compactor got to delete them.
//!
//! The snapshot is cut from the *live* in-memory state. With
//! copy-on-write snapshot reads (the default — see
//! [`super::memory`]), each shard is one atomic image load: the
//! compactor pins an immutable `ShardImage` and streams every study,
//! trial, and pending operation out of it while holding **zero** shard
//! locks, so base-snapshot writing cannot perturb the commit path at
//! all. With `OSSVIZIER_DATASTORE_COW=off` the legacy paged path runs
//! instead — study rows per shard (`InMemoryDatastore::snapshot_shard`),
//! then each study's trials in keyed pages — so no lock is ever held
//! longer than one page clone. Either way the base may already contain
//! the effects of records that sit in the tail; replay applies are
//! blind per-key upserts/deletes, so re-applying the tail over the base
//! converges to the exact crash-time state (per shard, replay is always
//! a prefix of the apply order that covers every acknowledged commit).
//!
//! # Group commit and per-shard commit lanes
//!
//! By default appends go through **group commit**: a writer applies its
//! mutation to the in-memory state and appends the encoded record to a
//! commit *lane*, then blocks until the dedicated committer thread has
//! flushed that lane's records (fsynced, in [`WalOptions::sync`] mode).
//! The committer drains every lane into one write, so K concurrent
//! writers share ~1 flush/fsync instead of paying K.
//!
//! Lanes are **per shard** ([`InMemoryDatastore::shard_index`] of the
//! study/operation name): the in-memory apply and the lane append happen
//! under the *lane's* lock only, so writers to different shards apply in
//! parallel and the N-shard parallelism of the store survives
//! durability. Replay order only needs to hold per study, and a study's
//! records all route to one lane (creates reserve their resource name
//! before committing), so per-lane FIFO + full-lane drains give exactly
//! that guarantee. [`WalOptions::serial_apply`] collapses everything
//! into a single lane — the pre-lane behavior, kept as the C-WAL-SHARD
//! baseline. The pre-group-commit path (append + flush inline under the
//! log lock) is kept as [`WalOptions::group_commit`]` = false`.
//!
//! Acknowledgement = durability: `create_trial` & co. return only after
//! the flush covering their records, so every acknowledged mutation
//! survives a crash; a torn final record is exactly one whose writers
//! were never acknowledged.
//!
//! Every WAL file — the single-file log, each `.log` segment, and each
//! `.base` snapshot — starts with a 16-byte header: an 8-byte magic, the
//! format version, and the shard count the store was opened with. Opens
//! fail fast (with the expected/found values in the error) on a
//! cross-version or cross-shard-count file instead of misreplaying it:
//! per-study replay order is a per-*lane* guarantee, and lane routing
//! changes with the shard count. Record framing after the header:
//! `[u32-le len][u8 kind][payload]` (identical in `.log` and `.base`
//! segments).
//!
//! The commit path's locks are registered with the crate lock hierarchy
//! ([`crate::util::sync::classes`]): `wal.commit_gate` → `wal.commit_work`
//! → `wal.commit_lane` → `wal.log_writer` → the datastore locks, with
//! `wal.compactor` reachable from under the gate. The orderings described
//! in this module's comments are machine-checked under lockdep (debug
//! builds / `OSSVIZIER_LOCKDEP=1`) — see `rust/docs/INVARIANTS.md`.

use super::memory::{cow_default_from_env, InMemoryDatastore, DEFAULT_SHARD_COUNT};
use super::{Datastore, DsError};
use crate::service::metrics::{DatastoreMetrics, WalMetrics};
use crate::util::sync::{classes, Condvar, Mutex, RwLock};
use crate::util::time::Stopwatch;
use crate::util::trace;
use crate::wire::codec::{decode, encode, Reader, WireError, WireMessage, Writer};
use crate::wire::messages::{OperationProto, StudyProto, TrialProto, UnitMetadataUpdate};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write as IoWrite};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

const KIND_PUT_STUDY: u8 = 1;
const KIND_DELETE_STUDY: u8 = 2;
const KIND_PUT_TRIAL: u8 = 3;
const KIND_DELETE_TRIAL: u8 = 4;
const KIND_PUT_OPERATION: u8 = 5;

/// Magic prefix of every WAL file (single-file log, `.log` segment, and
/// `.base` snapshot alike).
const WAL_MAGIC: [u8; 8] = *b"OSVZWAL\0";
/// Bump on any incompatible change to the header, record framing, or
/// envelope encoding.
const WAL_FORMAT_VERSION: u32 = 1;
/// Bytes of the per-file header: magic + format version (u32 le) +
/// shard-count stamp (u32 le).
const WAL_HEADER_LEN: u64 = 16;

fn wal_header(shard_count: u32) -> [u8; WAL_HEADER_LEN as usize] {
    let mut h = [0u8; WAL_HEADER_LEN as usize];
    h[..8].copy_from_slice(&WAL_MAGIC);
    h[8..12].copy_from_slice(&WAL_FORMAT_VERSION.to_le_bytes());
    h[12..16].copy_from_slice(&shard_count.to_le_bytes());
    h
}

/// One durable mutation record.
#[derive(Debug, Clone, PartialEq)]
pub enum Mutation {
    PutStudy(StudyProto),
    DeleteStudy(String),
    PutTrial(String, TrialProto),
    DeleteTrial(String, u64),
    PutOperation(OperationProto),
}

/// Internal envelope so every mutation is one wire message.
#[derive(Debug, Default)]
struct Envelope {
    study_name: String,
    trial_id: u64,
    study: Option<StudyProto>,
    trial: Option<TrialProto>,
    op: Option<OperationProto>,
}

impl WireMessage for Envelope {
    fn encode_fields(&self, w: &mut Writer) {
        w.str(1, &self.study_name);
        w.u64(2, self.trial_id);
        if let Some(s) = &self.study {
            w.msg(3, s);
        }
        if let Some(t) = &self.trial {
            w.msg(4, t);
        }
        if let Some(o) = &self.op {
            w.msg(5, o);
        }
    }
    fn decode_fields(r: &mut Reader) -> Result<Self, WireError> {
        let mut e = Envelope::default();
        while let Some((f, v)) = r.next_field()? {
            match f {
                1 => e.study_name = v.as_string()?,
                2 => e.trial_id = v.as_u64()?,
                3 => e.study = Some(v.as_msg()?),
                4 => e.trial = Some(v.as_msg()?),
                5 => e.op = Some(v.as_msg()?),
                _ => {}
            }
        }
        Ok(e)
    }
}

impl Mutation {
    fn kind(&self) -> u8 {
        match self {
            Mutation::PutStudy(_) => KIND_PUT_STUDY,
            Mutation::DeleteStudy(_) => KIND_DELETE_STUDY,
            Mutation::PutTrial(..) => KIND_PUT_TRIAL,
            Mutation::DeleteTrial(..) => KIND_DELETE_TRIAL,
            Mutation::PutOperation(_) => KIND_PUT_OPERATION,
        }
    }

    fn to_envelope(&self) -> Envelope {
        let mut e = Envelope::default();
        match self {
            Mutation::PutStudy(s) => e.study = Some(s.clone()),
            Mutation::DeleteStudy(name) => e.study_name = name.clone(),
            Mutation::PutTrial(study, t) => {
                e.study_name = study.clone();
                e.trial = Some(t.clone());
            }
            Mutation::DeleteTrial(study, id) => {
                e.study_name = study.clone();
                e.trial_id = *id;
            }
            Mutation::PutOperation(o) => e.op = Some(o.clone()),
        }
        e
    }

    fn from_envelope(kind: u8, e: Envelope) -> Result<Mutation, DsError> {
        let missing = |what: &str| DsError::Storage(format!("wal record missing {what}"));
        Ok(match kind {
            KIND_PUT_STUDY => Mutation::PutStudy(e.study.ok_or_else(|| missing("study"))?),
            KIND_DELETE_STUDY => Mutation::DeleteStudy(e.study_name),
            KIND_PUT_TRIAL => Mutation::PutTrial(e.study_name, e.trial.ok_or_else(|| missing("trial"))?),
            KIND_DELETE_TRIAL => Mutation::DeleteTrial(e.study_name, e.trial_id),
            KIND_PUT_OPERATION => Mutation::PutOperation(e.op.ok_or_else(|| missing("op"))?),
            other => return Err(DsError::Storage(format!("unknown wal record kind {other}"))),
        })
    }
}

/// Durability / batching / layout knobs for [`WalDatastore`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalOptions {
    /// fsync each commit batch before acknowledging its writers
    /// (durable against machine crash, not just process crash).
    pub sync: bool,
    /// Batch concurrent appends through the committer thread (group
    /// commit). `false` = the serial legacy path: every append writes and
    /// flushes inline under the log lock (benchmark baseline).
    pub group_commit: bool,
    /// Collapse the per-shard commit lanes into one global lane, which
    /// serializes the in-memory applies of *all* writers — the
    /// pre-per-shard-sequencing behavior, kept as the C-WAL-SHARD
    /// benchmark baseline. Only meaningful with `group_commit`.
    pub serial_apply: bool,
    /// `Some(n)`: segmented layout (`path` is a directory); the active
    /// segment rotates once it reaches `n` bytes and `compact()` runs on
    /// the background compactor without stalling commits. `None`: the
    /// single-file baseline layout.
    pub segment_bytes: Option<u64>,
    /// Segmented layout only: request a background compaction whenever
    /// more than this many segment files exist after a rotation
    /// (0 = compact only on explicit `compact()` calls).
    pub auto_compact_segments: u64,
    /// Segmented layout only: bytes-amplification trigger. Request a
    /// background compaction when the live log exceeds this multiple of
    /// the live-state size — approximated by the newest `.base` file,
    /// which is exactly the live state as of the last compaction. A
    /// store with no base yet treats any full segment of log as
    /// amplified (the first compaction establishes the baseline).
    /// Checked on rotation only, so the stat cost is per segment, not
    /// per commit. 0 = disabled. Complements `auto_compact_segments`:
    /// the segment-count trigger bounds replay *file count*; this one
    /// bounds replay *bytes* when a small hot state is overwritten many
    /// times per segment.
    pub compact_amplification: u64,
    /// Datastore read-path mode for the in-memory store the WAL replays
    /// into. `Some(true)` = copy-on-write snapshot reads (lock-free
    /// readers, zero-lock compactor snapshots), `Some(false)` = the
    /// lock-per-read baseline, `None` = follow
    /// `OSSVIZIER_DATASTORE_COW` (defaulting to on). See
    /// [`super::memory`] for the snapshot/publish protocol.
    pub datastore_cow: Option<bool>,
}

impl Default for WalOptions {
    fn default() -> Self {
        Self {
            sync: false,
            group_commit: true,
            serial_apply: false,
            segment_bytes: None,
            auto_compact_segments: 0,
            compact_amplification: 0,
            datastore_cow: None,
        }
    }
}

// ---------------------------------------------------------------------------
// Segment naming
// ---------------------------------------------------------------------------

fn log_name(n: u64) -> String {
    format!("wal.{n:06}.log")
}

fn base_name(n: u64) -> String {
    format!("wal.{n:06}.base")
}

enum SegFile {
    Log(u64),
    Base(u64),
    Tmp,
}

fn parse_segment(name: &str) -> Option<SegFile> {
    let rest = name.strip_prefix("wal.")?;
    if rest.ends_with(".tmp") {
        return Some(SegFile::Tmp);
    }
    if let Some(num) = rest.strip_suffix(".log") {
        return num.parse().ok().map(SegFile::Log);
    }
    if let Some(num) = rest.strip_suffix(".base") {
        return num.parse().ok().map(SegFile::Base);
    }
    None
}

/// Segment files at `path` in replay order: the newest base (if any)
/// first, then `.log` segments in ascending order. For a single-file
/// store this is the file itself. Introspection for tests and tooling.
pub fn segment_files(path: &Path) -> Vec<PathBuf> {
    if !path.is_dir() {
        return if path.exists() { vec![path.to_path_buf()] } else { Vec::new() };
    }
    let mut logs: Vec<u64> = Vec::new();
    let mut bases: Vec<u64> = Vec::new();
    if let Ok(entries) = std::fs::read_dir(path) {
        for entry in entries.flatten() {
            if let Some(name) = entry.file_name().to_str() {
                match parse_segment(name) {
                    Some(SegFile::Log(n)) => logs.push(n),
                    Some(SegFile::Base(n)) => bases.push(n),
                    _ => {}
                }
            }
        }
    }
    let base = bases.iter().max().copied();
    logs.retain(|n| base.is_none_or(|b| *n > b));
    logs.sort_unstable();
    let mut out = Vec::new();
    if let Some(b) = base {
        out.push(path.join(base_name(b)));
    }
    out.extend(logs.into_iter().map(|n| path.join(log_name(n))));
    out
}

/// The segment new appends land in (and the only one recovery will
/// truncate a torn tail from): the highest-numbered `.log` for a
/// segmented store, the file itself for a single-file store.
pub fn tail_segment(path: &Path) -> Option<PathBuf> {
    let last = segment_files(path).into_iter().next_back()?;
    if path.is_dir() && !last.extension().is_some_and(|e| e == "log") {
        return None; // only a base on disk: nothing to append to yet
    }
    Some(last)
}

/// Total on-disk size of the log at `path` (all segments for a
/// segmented store).
pub fn total_log_bytes(path: &Path) -> u64 {
    if !path.is_dir() {
        return std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
    }
    let mut total = 0;
    if let Ok(entries) = std::fs::read_dir(path) {
        for entry in entries.flatten() {
            if entry.file_name().to_str().is_some_and(|n| parse_segment(n).is_some()) {
                total += entry.metadata().map(|m| m.len()).unwrap_or(0);
            }
        }
    }
    total
}

fn sync_dir(dir: &Path) {
    // Best-effort directory fsync so the rename/unlink batch is durable.
    if let Ok(f) = File::open(dir) {
        let _ = f.sync_all();
    }
}

// ---------------------------------------------------------------------------
// Commit lanes + committer
// ---------------------------------------------------------------------------

#[derive(Default)]
struct LaneState {
    /// Encoded records waiting for the next batch (appended in apply
    /// order — the lane lock spans the in-memory apply and this append).
    buf: Vec<u8>,
    /// Records enqueued on this lane so far (monotonic).
    enqueued: u64,
}

struct WorkState {
    /// Per-lane count of records durably flushed.
    durable: Vec<u64>,
    /// Set by writers after enqueueing; cleared by the committer.
    pending: bool,
    /// True while the committer is writing records it has already taken
    /// out of the lanes.
    inflight: bool,
    /// Sticky committer I/O error; fails all subsequent commits.
    error: Option<String>,
    shutdown: bool,
}

struct CommitShared {
    lanes: Vec<Mutex<LaneState>>,
    work: Mutex<WorkState>,
    /// Committer waits here for work (or shutdown).
    work_cv: Condvar,
    /// Writers (and the single-file compactor) wait here for durability.
    done_cv: Condvar,
}

impl CommitShared {
    fn new(lanes: usize) -> Self {
        Self {
            lanes: (0..lanes)
                .map(|_| Mutex::new(&classes::WAL_LANE, LaneState::default()))
                .collect(),
            work: Mutex::new(&classes::WAL_WORK, WorkState {
                durable: vec![0; lanes],
                pending: false,
                inflight: false,
                error: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        }
    }
}

fn committer_failed(e: &str) -> DsError {
    DsError::Storage(format!("wal committer failed: {e}"))
}

/// The log file the committer (or the serial path) appends to.
struct LogWriter {
    w: BufWriter<File>,
    /// Bytes in the segment the writer points at.
    bytes: u64,
    /// Active segment number (0 in the single-file layout).
    seg_no: u64,
}

/// Everything the committer and compactor threads need to reach the log.
struct LogCtx {
    log: Mutex<LogWriter>,
    /// Segment directory (None = single-file layout).
    dir: Option<PathBuf>,
    sync: bool,
    segment_bytes: Option<u64>,
    auto_compact_segments: u64,
    compact_amplification: u64,
    /// Header stamped on every file this store creates (format version +
    /// shard count); replay refuses files whose stamp differs.
    header: [u8; WAL_HEADER_LEN as usize],
    metrics: Arc<WalMetrics>,
}

/// Seal the active segment (flush + fsync — sealed segments must never
/// legally contain torn records) and open the next one. Caller holds the
/// log lock; this is the only commit-path cost of rotation.
fn rotate_locked(
    lw: &mut LogWriter,
    dir: &Path,
    header: &[u8; WAL_HEADER_LEN as usize],
    metrics: &WalMetrics,
) -> std::io::Result<()> {
    let rotate_start = trace::now_us();
    // Seal at the last-known-good byte. A failed batch write (e.g. disk
    // full) can leave a partial record past `lw.bytes` — the committer
    // only advances it after a successful flush — and a sealed segment
    // must never carry a torn record (recovery refuses to open one).
    // The flush is best-effort: if it fails, set_len clips whatever made
    // it to the file back to the good prefix.
    let _ = lw.w.flush();
    lw.w.get_ref().set_len(lw.bytes)?;
    lw.w.get_ref().sync_all()?;
    let next = lw.seg_no + 1;
    let file = OpenOptions::new()
        .create_new(true)
        .read(true)
        .write(true)
        .open(dir.join(log_name(next)))?;
    // Persist the directory entry before any record is acknowledged out
    // of the new segment: without this, a machine crash could drop the
    // whole file even though its batches were fsynced (sync mode's
    // "acknowledgement = durability" promise covers the entry too).
    sync_dir(dir);
    lw.w = BufWriter::new(file);
    // Flush the header immediately: `reset_writer` restores a failed
    // segment to `lw.bytes` with set_len, which must never *extend* the
    // file over a still-buffered header (zero-fill would corrupt the
    // magic). A crash before this flush leaves a torn header, legal in
    // the final segment only — exactly like a torn record.
    lw.w.write_all(header)?;
    lw.w.flush()?;
    lw.bytes = WAL_HEADER_LEN;
    lw.seg_no = next;
    metrics.rotations.fetch_add(1, Ordering::Relaxed);
    metrics.segments.fetch_add(1, Ordering::Relaxed);
    trace::record_infra(trace::WAL_ROTATION, rotate_start, trace::now_us().saturating_sub(rotate_start));
    Ok(())
}

/// After a failed flush, drop the buffered writer (it may retain part of
/// the failed batch) and reopen the segment clipped to the last
/// acknowledged byte, so a later commit cannot strand acknowledged
/// records behind a torn region (replay stops at the first torn
/// record). Best-effort: if the reopen itself fails the old writer
/// stays, and the next commit re-attempts the reset.
fn reset_writer(lw: &mut LogWriter, seg_path: &Path) {
    if let Ok(mut f) = OpenOptions::new().read(true).write(true).open(seg_path) {
        let _ = f.set_len(lw.bytes);
        let _ = f.seek(SeekFrom::Start(lw.bytes));
        lw.w = BufWriter::new(f);
    }
}

/// Bytes of live log segments (newer than the newest base) and of the
/// newest base itself at `dir`. Stat-based; called on rotation only.
fn live_log_and_base_bytes(dir: &Path) -> (u64, u64) {
    let mut logs: Vec<(u64, u64)> = Vec::new();
    let mut best_base: Option<(u64, u64)> = None;
    if let Ok(entries) = std::fs::read_dir(dir) {
        for entry in entries.flatten() {
            let Some(name) = entry.file_name().to_str().map(str::to_owned) else { continue };
            let Ok(meta) = entry.metadata() else { continue };
            match parse_segment(&name) {
                Some(SegFile::Log(n)) => logs.push((n, meta.len())),
                Some(SegFile::Base(n)) => {
                    if best_base.is_none_or(|(b, _)| n > b) {
                        best_base = Some((n, meta.len()));
                    }
                }
                _ => {}
            }
        }
    }
    let base_no = best_base.map(|(n, _)| n);
    let log_bytes = logs
        .iter()
        .filter(|(n, _)| base_no.is_none_or(|b| *n > b))
        .map(|(_, len)| len)
        .sum();
    (log_bytes, best_base.map_or(0, |(_, len)| len))
}

fn maybe_auto_compact(ctx: &LogCtx, compactor: Option<&Arc<CompactorShared>>) {
    let Some(compactor) = compactor else { return };
    if ctx.auto_compact_segments != 0
        && ctx.metrics.segments.load(Ordering::Relaxed) > ctx.auto_compact_segments
    {
        compactor.request_async();
        return;
    }
    if ctx.compact_amplification != 0 {
        if let Some(dir) = ctx.dir.as_deref() {
            let (log_bytes, base_bytes) = live_log_and_base_bytes(dir);
            // No base yet: `base_bytes.max(1)` makes the first rotated
            // segment trip the trigger, establishing the baseline.
            if log_bytes > ctx.compact_amplification.saturating_mul(base_bytes.max(1)) {
                compactor.request_async();
            }
        }
    }
}

/// The committer: drains every lane into one write. Whatever accumulates
/// while one batch is being written becomes the next batch, so the batch
/// size adapts to the arrival rate. Within a lane, records are drained
/// in apply order and earlier batches hit the disk first, which is the
/// per-shard replay-order invariant.
fn committer_loop(
    shared: &CommitShared,
    ctx: &LogCtx,
    compactor: Option<&Arc<CompactorShared>>,
    batches: &AtomicU64,
    records: &AtomicU64,
) {
    let mut batch: Vec<u8> = Vec::new();
    loop {
        {
            let mut ws = shared.work.lock();
            // After a sticky I/O error nothing more is written: writers
            // fail fast, and appending past the torn region a failed
            // batch may have left would strand those records where
            // replay (which stops at the first torn record) can never
            // reach them. Park until shutdown.
            while !ws.shutdown && (!ws.pending || ws.error.is_some()) {
                ws = shared.work_cv.wait(ws);
            }
            if ws.error.is_some() {
                return; // shutdown in error mode: nothing left to drain
            }
            ws.pending = false;
            ws.inflight = true;
        }
        batch.clear();
        let mut targets: Vec<(usize, u64)> = Vec::new();
        for (i, lane) in shared.lanes.iter().enumerate() {
            let mut st = lane.lock();
            if st.buf.is_empty() {
                continue;
            }
            batch.append(&mut st.buf);
            targets.push((i, st.enqueued));
        }
        if targets.is_empty() {
            let mut ws = shared.work.lock();
            ws.inflight = false;
            let stop = ws.shutdown && !ws.pending;
            drop(ws);
            shared.done_cv.notify_all();
            if stop {
                return;
            }
            continue;
        }
        // I/O happens outside the lane locks: writers keep applying and
        // enqueueing while this batch hits the disk.
        let io_start = trace::now_us();
        let io = (|| -> std::io::Result<bool> {
            let mut lw = ctx.log.lock();
            lw.w.write_all(&batch)?;
            lw.w.flush()?;
            if ctx.sync {
                lw.w.get_ref().sync_data()?;
            }
            lw.bytes += batch.len() as u64;
            if let (Some(limit), Some(dir)) = (ctx.segment_bytes, ctx.dir.as_deref()) {
                if lw.bytes >= limit {
                    rotate_locked(&mut lw, dir, &ctx.header, &ctx.metrics)?;
                    return Ok(true);
                }
            }
            Ok(false)
        })();
        // One batch serves many commits, so it belongs to no single
        // trace — recorded as an infra span for `GetTraces
        // include_infra` and fsync-stall forensics.
        trace::record_infra(
            trace::WAL_FSYNC_BATCH,
            io_start,
            trace::now_us().saturating_sub(io_start),
        );
        let mut rotated = false;
        {
            let mut ws = shared.work.lock();
            ws.inflight = false;
            match io {
                Ok(r) => {
                    rotated = r;
                    let mut recs = 0;
                    for (i, t) in &targets {
                        if *t > ws.durable[*i] {
                            recs += *t - ws.durable[*i];
                            ws.durable[*i] = *t;
                        }
                    }
                    batches.fetch_add(1, Ordering::Relaxed);
                    records.fetch_add(recs, Ordering::Relaxed);
                }
                Err(e) => {
                    ws.error = Some(e.to_string());
                }
            }
        }
        shared.done_cv.notify_all();
        if rotated {
            maybe_auto_compact(ctx, compactor);
        }
    }
}

// ---------------------------------------------------------------------------
// Background compactor (segmented layout)
// ---------------------------------------------------------------------------

#[derive(Default)]
struct CompactorState {
    requested: u64,
    completed: u64,
    /// Error (if any) of the most recently completed run.
    last_error: Option<String>,
    shutdown: bool,
}

struct CompactorShared {
    state: Mutex<CompactorState>,
    cv: Condvar,
}

impl Default for CompactorShared {
    fn default() -> Self {
        Self {
            state: Mutex::new(&classes::WAL_COMPACTOR, CompactorState::default()),
            cv: Condvar::new(),
        }
    }
}

impl CompactorShared {
    /// Request a compaction without waiting (coalesces with an already
    /// pending request).
    fn request_async(&self) {
        let mut st = self.state.lock();
        if st.shutdown {
            return;
        }
        if st.requested == st.completed {
            st.requested += 1;
            self.cv.notify_all();
        }
    }

    /// Request a compaction and block until a run that started at or
    /// after this request completes. Commits are NOT blocked meanwhile.
    fn request_and_wait(&self) -> Result<(), DsError> {
        let mut st = self.state.lock();
        if st.shutdown {
            return Err(DsError::Storage("wal compactor is shut down".into()));
        }
        st.requested += 1;
        let goal = st.requested;
        self.cv.notify_all();
        while st.completed < goal && !st.shutdown {
            st = self.cv.wait(st);
        }
        if st.completed < goal {
            return Err(DsError::Storage("wal compactor shut down mid-request".into()));
        }
        match &st.last_error {
            Some(e) => Err(DsError::Storage(format!("wal compaction failed: {e}"))),
            None => Ok(()),
        }
    }

    fn shutdown(&self) {
        let mut st = self.state.lock();
        st.shutdown = true;
        self.cv.notify_all();
    }
}

fn compactor_loop(shared: &CompactorShared, mem: &InMemoryDatastore, ctx: &LogCtx) {
    loop {
        let goal = {
            let mut st = shared.state.lock();
            loop {
                if st.shutdown {
                    return;
                }
                if st.requested > st.completed {
                    break st.requested;
                }
                st = shared.cv.wait(st);
            }
        };
        let result = run_segmented_compaction(mem, ctx);
        let mut st = shared.state.lock();
        st.completed = goal;
        st.last_error = result.err().map(|e| e.to_string());
        shared.cv.notify_all();
    }
}

/// Baseline mode only — trials cloned per shard-lock acquisition while
/// snapshotting: bounds how long the compactor can hold any one shard's
/// writers. Deprecated in spirit: with copy-on-write reads (the
/// default) the snapshot is a single pinned image per shard and no
/// paging is needed.
const SNAPSHOT_TRIAL_PAGE: usize = 512;

/// Stream a snapshot of the live state as replayable records: per
/// shard, every study row, that study's trials, then the shard's
/// pending operations. In copy-on-write mode each shard is one atomic
/// image load — the whole shard streams from an immutable pinned image
/// with zero shard-lock acquisitions, so the commit path cannot observe
/// the compactor at all. In baseline mode each page is one short
/// read-lock acquisition, so the commit path is never stalled for
/// longer than one page clone even on million-trial studies.
/// Per-record (upsert) consistency is all replay needs — records the
/// tail re-applies converge to the same state. Done operations are shed
/// here — compaction is what bounds the log.
fn write_snapshot<W: IoWrite>(mem: &InMemoryDatastore, w: &mut W) -> Result<(), DsError> {
    for idx in 0..mem.shard_count() {
        if let Some(image) = mem.shard_image(idx) {
            // Copy-on-write path: the pinned image is immutable and
            // internally consistent (a prefix of the shard's apply
            // order), so no deleted-mid-stream races exist and the
            // whole shard streams without touching a lock.
            for study in image.studies() {
                append_record(w, &Mutation::PutStudy(study.study().clone()))?;
                for trial in study.trials() {
                    append_record(
                        w,
                        &Mutation::PutTrial(study.study().name.clone(), trial.clone()),
                    )?;
                }
            }
            for op in image.pending_ops() {
                append_record(w, &Mutation::PutOperation(op.clone()))?;
            }
            continue;
        }
        let snap = mem.snapshot_shard(idx);
        for study in snap.studies {
            let name = study.name.clone();
            append_record(w, &Mutation::PutStudy(study))?;
            let mut token = String::new();
            loop {
                let page = match mem.list_trials_page(&name, SNAPSHOT_TRIAL_PAGE, &token) {
                    Ok(page) => page,
                    // The study was deleted while we streamed it: its
                    // DeleteStudy record is in the tail (post-seal), so
                    // any partial trial rows already written are exactly
                    // the orphans tail replay cleans up.
                    Err(DsError::StudyNotFound(_)) => break,
                    Err(e) => return Err(e),
                };
                for t in page.trials {
                    append_record(w, &Mutation::PutTrial(name.clone(), t))?;
                }
                if page.next_page_token.is_empty() {
                    break;
                }
                token = page.next_page_token;
            }
        }
        for op in snap.pending_ops {
            append_record(w, &Mutation::PutOperation(op))?;
        }
    }
    Ok(())
}

/// One compaction pass. The commit path is touched exactly once — the
/// log lock is held just long enough to seal the active segment and open
/// the next — after which commits proceed concurrently with the
/// snapshot, publish, and deletion steps.
fn run_segmented_compaction(mem: &InMemoryDatastore, ctx: &LogCtx) -> Result<(), DsError> {
    // lint: allow(no-unwrap) — only ever spawned with a segment directory
    let dir = ctx.dir.as_ref().expect("segmented compaction requires a segment directory");
    let sw = Stopwatch::start();
    // 1. Seal. Everything applied before this point is in segments
    //    ≤ `sealed` or already visible to the snapshot; everything after
    //    lands in the tail and re-applies idempotently at replay.
    let sealed = {
        let mut lw = ctx.log.lock();
        let sealed = lw.seg_no;
        rotate_locked(&mut lw, dir, &ctx.header, &ctx.metrics).map_err(io_err)?;
        sealed
    };
    // 2. Snapshot into an unpublished tmp file.
    let tmp = dir.join(format!("{}.tmp", base_name(sealed)));
    {
        let file = File::create(&tmp).map_err(io_err)?;
        let mut w = BufWriter::new(file);
        w.write_all(&ctx.header).map_err(io_err)?;
        write_snapshot(mem, &mut w)?;
        w.flush().map_err(io_err)?;
        w.get_ref().sync_all().map_err(io_err)?;
    }
    // 3. Publish atomically; only then do superseded segments die.
    let base = dir.join(base_name(sealed));
    std::fs::rename(&tmp, &base).map_err(io_err)?;
    sync_dir(dir);
    let base_len = std::fs::metadata(&base).map(|m| m.len()).unwrap_or(0);
    let mut reclaimed = 0u64;
    let mut deleted = 0u64;
    for entry in std::fs::read_dir(dir).map_err(io_err)?.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        // Stale tmps are deleted but not counted toward the gauge delta:
        // they were never counted into `segments` in the first place.
        let (doomed, counted) = match parse_segment(name) {
            Some(SegFile::Log(n)) => (n <= sealed, true),
            Some(SegFile::Base(n)) => (n < sealed, true),
            Some(SegFile::Tmp) => (true, false),
            None => continue,
        };
        if doomed {
            reclaimed += entry.metadata().map(|m| m.len()).unwrap_or(0);
            let _ = std::fs::remove_file(entry.path());
            if counted {
                deleted += 1;
            }
        }
    }
    sync_dir(dir);
    // Delta updates, not a recount-and-store: the committer may rotate
    // (fetch_add) concurrently, and a store would clobber its increment.
    // +1 for the published base, -1 per deleted file.
    ctx.metrics.segments.fetch_add(1, Ordering::Relaxed);
    let _ = ctx
        .metrics
        .segments
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(deleted)));
    ctx.metrics.compactions.fetch_add(1, Ordering::Relaxed);
    ctx.metrics.compaction_micros.record(sw.elapsed_micros());
    ctx.metrics
        .reclaimed_bytes
        .fetch_add(reclaimed.saturating_sub(base_len), Ordering::Relaxed);
    Ok(())
}

// ---------------------------------------------------------------------------
// Replay
// ---------------------------------------------------------------------------

/// Replay one record into the in-memory image.
///
/// Replay applies are blind per-key upserts/deletes, so records whose
/// effects a base snapshot already contains re-apply idempotently. When
/// `tolerate_orphans`, a `PutTrial` whose study is absent is skipped
/// rather than treated as corruption: replaying tail segments over a
/// live-state base hits this exact shape when the snapshot captured a
/// `DeleteStudy` whose record sits later in the tail than the trial
/// write (same study = same commit lane = ordered, so the delete is at
/// or past the snapshot point), and skipping the orphan write is the
/// state the full tail replay converges to anyway. Without a base in
/// front — the single-file layout, a base segment itself, or a
/// never-compacted segment chain — replay order is the complete apply
/// order, an orphan can only mean corruption, and it stays an error.
fn replay_apply(
    mem: &InMemoryDatastore,
    m: &Mutation,
    tolerate_orphans: bool,
) -> Result<(), DsError> {
    match m {
        Mutation::PutStudy(s) => mem.apply_put_study(s.clone()),
        Mutation::DeleteStudy(name) => mem.apply_delete_study(name),
        Mutation::PutTrial(study, t) => match mem.apply_put_trial(study, t.clone()) {
            Ok(()) => {}
            Err(_) if tolerate_orphans => {}
            Err(e) => return Err(e),
        },
        Mutation::DeleteTrial(study, id) => mem.apply_delete_trial(study, *id),
        Mutation::PutOperation(o) => mem.apply_put_operation(o.clone()),
    }
    Ok(())
}

/// Replay every complete record in `path`, returning the byte length of
/// the valid prefix. A torn tail (incomplete length prefix or record) is
/// allowed only when `allow_torn_tail` — the caller truncates it — and
/// is corruption otherwise (sealed and base segments are fsynced before
/// later segments exist). `tolerate_orphans` is for tail segments
/// replayed over a base snapshot (see [`replay_apply`]).
fn replay_file(
    path: &Path,
    mem: &InMemoryDatastore,
    allow_torn_tail: bool,
    tolerate_orphans: bool,
) -> Result<u64, DsError> {
    let mut buf = Vec::new();
    File::open(path).map_err(io_err)?.read_to_end(&mut buf).map_err(io_err)?;
    if buf.len() < WAL_HEADER_LEN as usize {
        // A header can only be torn by a crash between segment creation
        // and its first flush — legal in the final segment only, exactly
        // like a torn record.
        if allow_torn_tail {
            return Ok(0);
        }
        return Err(DsError::Storage(format!(
            "wal segment {} is truncated mid-header ({} of {WAL_HEADER_LEN} bytes)",
            path.display(),
            buf.len()
        )));
    }
    if buf[..8] != WAL_MAGIC {
        return Err(DsError::Storage(format!(
            "{} is not a vizier wal file (bad magic); refusing to replay it",
            path.display()
        )));
    }
    let version = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]);
    if version != WAL_FORMAT_VERSION {
        return Err(DsError::Storage(format!(
            "wal segment {} has format version {version}, but this build reads version \
             {WAL_FORMAT_VERSION}; refusing a cross-version open",
            path.display()
        )));
    }
    let stamped = u32::from_le_bytes([buf[12], buf[13], buf[14], buf[15]]);
    if stamped as usize != mem.shard_count() {
        return Err(DsError::Storage(format!(
            "wal segment {} was written with {stamped} shards but this store opens with \
             {}; per-study replay order is a per-lane guarantee and lane routing changes \
             with the shard count — refusing a cross-shard-count open",
            path.display(),
            mem.shard_count()
        )));
    }
    let mut pos = WAL_HEADER_LEN as usize;
    loop {
        if pos + 4 > buf.len() {
            break; // torn length prefix
        }
        let len = u32::from_le_bytes([buf[pos], buf[pos + 1], buf[pos + 2], buf[pos + 3]]) as usize;
        if len == 0 || pos + 4 + len > buf.len() {
            break; // torn record
        }
        let kind = buf[pos + 4];
        let payload = &buf[pos + 5..pos + 4 + len];
        let env: Envelope = decode(payload)
            .map_err(|e| DsError::Storage(format!("wal decode ({}): {e}", path.display())))?;
        let m = Mutation::from_envelope(kind, env)?;
        replay_apply(mem, &m, tolerate_orphans)?;
        pos += 4 + len;
    }
    let valid = pos as u64;
    if valid < buf.len() as u64 && !allow_torn_tail {
        return Err(DsError::Storage(format!(
            "torn record in sealed wal segment {} (byte {valid} of {}); sealed segments \
             are fsynced at rotation, so this indicates corruption",
            path.display(),
            buf.len()
        )));
    }
    Ok(valid)
}

fn open_single_file(
    path: &Path,
    mem: &InMemoryDatastore,
    metrics: &WalMetrics,
) -> Result<LogWriter, DsError> {
    let mut valid_len = 0u64;
    if path.exists() {
        valid_len = replay_file(path, mem, true, false)?;
    }
    let mut file = OpenOptions::new()
        .create(true)
        .read(true)
        .write(true)
        .open(path)
        .map_err(io_err)?;
    // Truncate any torn tail so future appends start at a clean record
    // boundary.
    file.set_len(valid_len).map_err(io_err)?;
    file.seek(SeekFrom::End(0)).map_err(io_err)?;
    if valid_len < WAL_HEADER_LEN {
        // Fresh file (or one whose header a crash tore): stamp it before
        // any record lands.
        file.write_all(&wal_header(mem.shard_count() as u32)).map_err(io_err)?;
        valid_len = WAL_HEADER_LEN;
    }
    metrics.segments.store(1, Ordering::Relaxed);
    Ok(LogWriter {
        w: BufWriter::new(file),
        bytes: valid_len,
        seg_no: 0,
    })
}

fn open_segmented(
    dir: &Path,
    mem: &InMemoryDatastore,
    metrics: &WalMetrics,
) -> Result<LogWriter, DsError> {
    if dir.is_file() {
        return Err(DsError::Storage(format!(
            "wal path {} is a single-file log but the segmented layout needs a directory; \
             open with segment_bytes: None, or move the legacy file aside",
            dir.display()
        )));
    }
    std::fs::create_dir_all(dir).map_err(io_err)?;
    let mut logs: Vec<u64> = Vec::new();
    let mut bases: Vec<u64> = Vec::new();
    let mut stale: Vec<PathBuf> = Vec::new();
    for entry in std::fs::read_dir(dir).map_err(io_err)?.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        match parse_segment(name) {
            Some(SegFile::Log(n)) => logs.push(n),
            Some(SegFile::Base(n)) => bases.push(n),
            Some(SegFile::Tmp) => stale.push(entry.path()),
            None => {}
        }
    }
    let base = bases.iter().max().copied();
    if let Some(b) = base {
        for n in bases.iter().filter(|n| **n < b) {
            stale.push(dir.join(base_name(*n)));
        }
        logs.retain(|n| {
            if *n <= b {
                stale.push(dir.join(log_name(*n)));
                false
            } else {
                true
            }
        });
    }
    // Crash-mid-compaction leftovers: unpublished tmp snapshots, and
    // segments a published base supersedes (the compactor died between
    // the rename and the deletes). Cleared before replay.
    for p in stale {
        let _ = std::fs::remove_file(&p);
    }
    logs.sort_unstable();
    if let Some(b) = base {
        // The base is a point snapshot written study-before-trials: no
        // torn tails (published by atomic rename) and no orphans.
        replay_file(&dir.join(base_name(b)), mem, false, false)?;
    }
    // Tail records may re-apply effects the base already contains, so
    // orphan trial writes are tolerated — but only when a base actually
    // sits in front; a never-compacted chain is the complete history and
    // stays strict.
    let tolerate_orphans = base.is_some();
    for (i, n) in logs.iter().enumerate() {
        let p = dir.join(log_name(*n));
        let is_final = i + 1 == logs.len();
        let valid = replay_file(&p, mem, is_final, tolerate_orphans)?;
        if is_final {
            let len = std::fs::metadata(&p).map_err(io_err)?.len();
            if valid < len {
                // Truncate the torn tail now, so this file never becomes
                // a sealed segment carrying a torn record.
                let f = OpenOptions::new().write(true).open(&p).map_err(io_err)?;
                f.set_len(valid).map_err(io_err)?;
            }
        }
    }
    // Resume appending to the tail segment (a fresh file every open
    // would accumulate never-rotated empty segments across restarts);
    // if it is already over the size threshold the committer rotates it
    // at the next batch.
    let lw = match logs.last() {
        Some(&n) => {
            let mut file = OpenOptions::new()
                .read(true)
                .write(true)
                .open(dir.join(log_name(n)))
                .map_err(io_err)?;
            let mut bytes = file.seek(SeekFrom::End(0)).map_err(io_err)?;
            if bytes < WAL_HEADER_LEN {
                // The tail's header was torn (crash between rotation's
                // create and its first flush) and replay truncated it to
                // empty: restamp before appending.
                file.set_len(0).map_err(io_err)?;
                file.seek(SeekFrom::Start(0)).map_err(io_err)?;
                file.write_all(&wal_header(mem.shard_count() as u32)).map_err(io_err)?;
                bytes = WAL_HEADER_LEN;
            }
            LogWriter {
                w: BufWriter::new(file),
                bytes,
                seg_no: n,
            }
        }
        None => {
            let n = base.map_or(1, |b| b + 1);
            let mut file = OpenOptions::new()
                .create_new(true)
                .read(true)
                .write(true)
                .open(dir.join(log_name(n)))
                .map_err(io_err)?;
            file.write_all(&wal_header(mem.shard_count() as u32)).map_err(io_err)?;
            sync_dir(dir);
            LogWriter {
                w: BufWriter::new(file),
                bytes: WAL_HEADER_LEN,
                seg_no: n,
            }
        }
    };
    metrics.segments.store(
        logs.len().max(1) as u64 + u64::from(base.is_some()),
        Ordering::Relaxed,
    );
    Ok(lw)
}

// ---------------------------------------------------------------------------
// The datastore
// ---------------------------------------------------------------------------

/// Durable datastore: in-memory state + write-ahead log.
pub struct WalDatastore {
    mem: Arc<InMemoryDatastore>,
    ctx: Arc<LogCtx>,
    path: PathBuf,
    opts: WalOptions,
    /// Writers hold this for read around apply + enqueue; the
    /// single-file `compact()` takes it for write to stall the commit
    /// path (the deprecated behavior the segmented compactor removes).
    commit_gate: RwLock<()>,
    commit: Option<Arc<CommitShared>>,
    committer: Option<JoinHandle<()>>,
    compactor: Option<Arc<CompactorShared>>,
    compactor_join: Option<JoinHandle<()>>,
    /// Batches flushed by the committer (observability: `records_flushed /
    /// batches_flushed` = achieved group-commit factor).
    batches_flushed: Arc<AtomicU64>,
    records_flushed: Arc<AtomicU64>,
}

impl WalDatastore {
    /// Open (or create) a WAL-backed store at `path`, replaying any
    /// existing log. Group commit on, per-shard lanes, no fsync,
    /// single-file layout (see [`WalOptions`]).
    pub fn open(path: impl AsRef<Path>) -> Result<Self, DsError> {
        Self::open_with_options(path, WalOptions::default())
    }

    /// `open`, but fsync every commit batch when `sync_every_write`.
    pub fn open_with_sync(path: impl AsRef<Path>, sync_every_write: bool) -> Result<Self, DsError> {
        Self::open_with_options(
            path,
            WalOptions {
                sync: sync_every_write,
                ..WalOptions::default()
            },
        )
    }

    /// Open with explicit durability/batching/layout options.
    pub fn open_with_options(path: impl AsRef<Path>, opts: WalOptions) -> Result<Self, DsError> {
        let path = path.as_ref().to_path_buf();
        let cow = opts.datastore_cow.unwrap_or_else(cow_default_from_env);
        let mem = Arc::new(InMemoryDatastore::with_shards_cow(DEFAULT_SHARD_COUNT, cow));
        let metrics = Arc::new(WalMetrics::default());
        let (lw, dir) = match opts.segment_bytes {
            None => (open_single_file(&path, &mem, &metrics)?, None),
            Some(_) => (open_segmented(&path, &mem, &metrics)?, Some(path.clone())),
        };
        let ctx = Arc::new(LogCtx {
            log: Mutex::new(&classes::WAL_LOG, lw),
            dir,
            sync: opts.sync,
            segment_bytes: opts.segment_bytes,
            auto_compact_segments: opts.auto_compact_segments,
            compact_amplification: opts.compact_amplification,
            header: wal_header(mem.shard_count() as u32),
            metrics,
        });
        let (compactor, compactor_join) = if opts.segment_bytes.is_some() {
            let shared = Arc::new(CompactorShared::default());
            let handle = std::thread::Builder::new()
                .name("wal-compactor".into())
                .spawn({
                    let shared = Arc::clone(&shared);
                    let mem = Arc::clone(&mem);
                    let ctx = Arc::clone(&ctx);
                    move || compactor_loop(&shared, &mem, &ctx)
                })
                .map_err(io_err)?;
            (Some(shared), Some(handle))
        } else {
            (None, None)
        };
        let batches_flushed = Arc::new(AtomicU64::new(0));
        let records_flushed = Arc::new(AtomicU64::new(0));
        let (commit, committer) = if opts.group_commit {
            let lanes = if opts.serial_apply { 1 } else { mem.shard_count() };
            let shared = Arc::new(CommitShared::new(lanes));
            let handle = std::thread::Builder::new()
                .name("wal-committer".into())
                .spawn({
                    let shared = Arc::clone(&shared);
                    let ctx = Arc::clone(&ctx);
                    let compactor = compactor.clone();
                    let batches = Arc::clone(&batches_flushed);
                    let records = Arc::clone(&records_flushed);
                    move || committer_loop(&shared, &ctx, compactor.as_ref(), &batches, &records)
                })
                .map_err(io_err)?;
            (Some(shared), Some(handle))
        } else {
            (None, None)
        };
        Ok(Self {
            mem,
            ctx,
            path,
            opts,
            commit_gate: RwLock::new(&classes::WAL_COMMIT_GATE, ()),
            commit,
            committer,
            compactor,
            compactor_join,
            batches_flushed,
            records_flushed,
        })
    }

    /// Compact the log so replay cost stays bounded.
    ///
    /// * **Segmented layout**: hands the work to the background
    ///   compactor and waits for it to finish — commits keep flowing
    ///   into the active segment the whole time (the snapshot never
    ///   takes the commit path). Prefer this layout on live servers.
    /// * **Single-file layout** *(deprecated stalling variant)*: quiesces
    ///   the committer and holds the commit gate through the snapshot
    ///   swap, so every writer stalls for the full duration. Kept only
    ///   as the measurement baseline; open with
    ///   `segment_bytes: Some(_)` to get the non-stalling compactor.
    pub fn compact(&self) -> Result<(), DsError> {
        match &self.compactor {
            Some(shared) => shared.request_and_wait(),
            None => self.compact_single_file(),
        }
    }

    /// Request a background compaction without waiting for it. Returns
    /// false on the single-file layout (which has no background
    /// compactor).
    pub fn compact_async(&self) -> bool {
        match &self.compactor {
            Some(shared) => {
                shared.request_async();
                true
            }
            None => false,
        }
    }

    fn compact_single_file(&self) -> Result<(), DsError> {
        let sw = Stopwatch::start();
        // Stall the commit path (legacy semantics): no new applies while
        // the snapshot is cut, so the swapped log exactly covers state.
        let _gate = self.commit_gate.write();
        if let Some(shared) = &self.commit {
            // Everything already enqueued must be durable before the
            // swap (those writers were or will be acknowledged against
            // records the old log contains).
            let mut ws = shared.work.lock();
            loop {
                if let Some(e) = &ws.error {
                    return Err(committer_failed(e));
                }
                let drained = shared.lanes.iter().all(|l| l.lock().buf.is_empty());
                if drained && !ws.inflight {
                    break;
                }
                ws.pending = true;
                shared.work_cv.notify_one();
                ws = shared.done_cv.wait(ws);
            }
        }
        let mut lw = self.ctx.log.lock();
        let before = std::fs::metadata(&self.path).map(|m| m.len()).unwrap_or(0);
        let tmp = self.path.with_extension("wal.tmp");
        {
            let file = File::create(&tmp).map_err(io_err)?;
            let mut w = BufWriter::new(file);
            w.write_all(&self.ctx.header).map_err(io_err)?;
            write_snapshot(&self.mem, &mut w)?;
            w.flush().map_err(io_err)?;
            w.get_ref().sync_all().map_err(io_err)?;
        }
        std::fs::rename(&tmp, &self.path).map_err(io_err)?;
        let file = OpenOptions::new()
            .read(true)
            .append(true)
            .open(&self.path)
            .map_err(io_err)?;
        let len = file.metadata().map_err(io_err)?.len();
        *lw = LogWriter {
            w: BufWriter::new(file),
            bytes: len,
            seg_no: 0,
        };
        self.ctx.metrics.compactions.fetch_add(1, Ordering::Relaxed);
        self.ctx.metrics.compaction_micros.record(sw.elapsed_micros());
        self.ctx
            .metrics
            .reclaimed_bytes
            .fetch_add(before.saturating_sub(len), Ordering::Relaxed);
        Ok(())
    }

    /// The options this store was opened with.
    pub fn options(&self) -> WalOptions {
        self.opts
    }

    /// Total size of the log in bytes (all segments for the segmented
    /// layout).
    pub fn log_size(&self) -> u64 {
        total_log_bytes(&self.path)
    }

    /// Segment files currently on disk (1 for the single-file layout).
    pub fn segment_count(&self) -> u64 {
        self.ctx.metrics.segments.load(Ordering::Relaxed)
    }

    /// The store's instrumentation; link into
    /// [`crate::service::metrics::ServiceMetrics::set_wal`] so reports
    /// cover the durable store.
    pub fn metrics(&self) -> Arc<WalMetrics> {
        Arc::clone(&self.ctx.metrics)
    }

    /// The replay target's snapshot/contention instrumentation; link
    /// into [`crate::service::metrics::ServiceMetrics::set_datastore`]
    /// so reports cover the read path of the durable store.
    pub fn datastore_metrics(&self) -> Arc<DatastoreMetrics> {
        self.mem.metrics()
    }

    /// Batches the committer has flushed (0 in serial mode).
    pub fn batches_flushed(&self) -> u64 {
        self.batches_flushed.load(Ordering::Relaxed)
    }

    /// Records flushed through the committer (0 in serial mode).
    /// `records_flushed() / batches_flushed()` is the achieved
    /// group-commit factor.
    pub fn records_flushed(&self) -> u64 {
        self.records_flushed.load(Ordering::Relaxed)
    }

    /// Run a mutating operation and durably log the mutations it returns.
    ///
    /// Group-commit mode: the in-memory apply and the lane append happen
    /// under the *lane's* lock — the lane chosen by `lane_key`'s shard —
    /// so log order matches apply order per shard while different shards
    /// apply in parallel; the writer then blocks until the committer has
    /// flushed its records. Serial mode: apply, then append + flush
    /// inline under the log lock.
    fn commit<T>(
        &self,
        lane_key: &str,
        op: impl FnOnce(&InMemoryDatastore) -> Result<(T, Vec<Mutation>), DsError>,
    ) -> Result<T, DsError> {
        // The stopwatch starts before the gate: a single-file compact()
        // parks writers right here, and that stall is exactly what
        // commit_wait / commit_stall_max_micros exist to expose. The
        // span covers the same interval (gate + apply + durability wait)
        // inside the requesting trace, when there is one.
        let _commit_span = trace::child_span(trace::WAL_COMMIT);
        let sw = Stopwatch::start();
        let _gate = self.commit_gate.read();
        match &self.commit {
            Some(shared) => {
                {
                    let ws = shared.work.lock();
                    if let Some(e) = &ws.error {
                        return Err(committer_failed(e));
                    }
                }
                let lane_idx = if shared.lanes.len() == 1 {
                    0
                } else {
                    self.mem.shard_index(lane_key)
                };
                let (value, my_seq) = {
                    // The lane-serialized section only (apply + append);
                    // the durability wait shows up as the remainder of
                    // the enclosing wal-commit span.
                    let _lane_span = trace::child_span(trace::WAL_LANE_APPLY);
                    let mut lane = shared.lanes[lane_idx].lock();
                    let (value, muts) = op(self.mem.as_ref())?;
                    if muts.is_empty() {
                        return Ok(value);
                    }
                    for m in &muts {
                        append_record(&mut lane.buf, m)?;
                    }
                    lane.enqueued += muts.len() as u64;
                    (value, lane.enqueued)
                };
                let mut ws = shared.work.lock();
                ws.pending = true;
                shared.work_cv.notify_one();
                while ws.durable[lane_idx] < my_seq && ws.error.is_none() {
                    ws = shared.done_cv.wait(ws);
                }
                if let Some(e) = &ws.error {
                    return Err(committer_failed(e));
                }
                drop(ws);
                self.ctx.metrics.record_commit_wait(sw.elapsed_micros());
                Ok(value)
            }
            None => {
                // The log lock spans the in-memory apply too, so records
                // for the same key cannot be appended in the opposite
                // order they were applied (replay = acknowledged state).
                let lane_span = trace::child_span(trace::WAL_LANE_APPLY);
                let mut lw = self.ctx.log.lock();
                let (value, muts) = op(self.mem.as_ref())?;
                if muts.is_empty() {
                    return Ok(value);
                }
                let mut appended = 0u64;
                for m in &muts {
                    appended += append_record(&mut lw.w, m)? as u64;
                }
                drop(lane_span);
                let flushed = (|| -> std::io::Result<()> {
                    lw.w.flush()?;
                    if self.ctx.sync {
                        lw.w.get_ref().sync_data()?;
                    }
                    Ok(())
                })();
                if let Err(e) = flushed {
                    let seg_path = match self.ctx.dir.as_deref() {
                        Some(dir) => dir.join(log_name(lw.seg_no)),
                        None => self.path.clone(),
                    };
                    reset_writer(&mut lw, &seg_path);
                    return Err(io_err(e));
                }
                lw.bytes += appended;
                let mut rotated = false;
                if let (Some(limit), Some(dir)) = (self.ctx.segment_bytes, self.ctx.dir.as_deref()) {
                    if lw.bytes >= limit {
                        rotate_locked(&mut lw, dir, &self.ctx.header, &self.ctx.metrics)
                            .map_err(io_err)?;
                        rotated = true;
                    }
                }
                drop(lw);
                self.ctx.metrics.record_commit_wait(sw.elapsed_micros());
                if rotated {
                    maybe_auto_compact(&self.ctx, self.compactor.as_ref());
                }
                Ok(value)
            }
        }
    }
}

impl Drop for WalDatastore {
    fn drop(&mut self) {
        if let Some(shared) = &self.commit {
            let mut ws = shared.work.lock();
            ws.shutdown = true;
            ws.pending = true; // force a final drain pass
            drop(ws);
            shared.work_cv.notify_all();
        }
        if let Some(handle) = self.committer.take() {
            let _ = handle.join();
        }
        if let Some(shared) = &self.compactor {
            shared.shutdown();
        }
        if let Some(handle) = self.compactor_join.take() {
            let _ = handle.join();
        }
        // Best-effort flush of the serial path's buffered writer.
        let _ = self.ctx.log.lock().w.flush();
    }
}

fn io_err(e: std::io::Error) -> DsError {
    DsError::Storage(e.to_string())
}

/// Append one framed record, returning the bytes written.
fn append_record<W: IoWrite>(w: &mut W, m: &Mutation) -> Result<usize, DsError> {
    let payload = encode(&m.to_envelope());
    let total = (1 + payload.len()) as u32;
    w.write_all(&total.to_le_bytes()).map_err(io_err)?;
    w.write_all(&[m.kind()]).map_err(io_err)?;
    w.write_all(&payload).map_err(io_err)?;
    Ok(4 + 1 + payload.len())
}

impl Datastore for WalDatastore {
    fn create_study(&self, mut study: StudyProto) -> Result<StudyProto, DsError> {
        // Reserve the name up front so the create routes to the same
        // commit lane as every later record of this study (per-study
        // replay order is a per-lane guarantee).
        if study.name.is_empty() {
            study.name = self.mem.reserve_study_name();
        }
        let lane = study.name.clone();
        self.commit(&lane, move |mem| {
            let created = mem.create_study(study)?;
            let m = Mutation::PutStudy(created.clone());
            Ok((created, vec![m]))
        })
    }

    fn get_study(&self, name: &str) -> Result<StudyProto, DsError> {
        self.mem.get_study(name)
    }

    fn lookup_study(&self, display_name: &str) -> Result<StudyProto, DsError> {
        self.mem.lookup_study(display_name)
    }

    fn list_studies(&self) -> Result<Vec<StudyProto>, DsError> {
        self.mem.list_studies()
    }

    fn list_studies_page(
        &self,
        page_size: usize,
        page_token: &str,
    ) -> Result<super::StudyPage, DsError> {
        self.mem.list_studies_page(page_size, page_token)
    }

    fn update_study(&self, study: StudyProto) -> Result<(), DsError> {
        let lane = study.name.clone();
        self.commit(&lane, move |mem| {
            mem.update_study(study.clone())?;
            Ok(((), vec![Mutation::PutStudy(study)]))
        })
    }

    fn delete_study(&self, name: &str) -> Result<(), DsError> {
        self.commit(name, |mem| {
            mem.delete_study(name)?;
            Ok(((), vec![Mutation::DeleteStudy(name.to_string())]))
        })
    }

    fn create_trial(&self, study: &str, trial: TrialProto) -> Result<TrialProto, DsError> {
        self.commit(study, |mem| {
            let created = mem.create_trial(study, trial)?;
            let m = Mutation::PutTrial(study.to_string(), created.clone());
            Ok((created, vec![m]))
        })
    }

    fn get_trial(&self, study: &str, id: u64) -> Result<TrialProto, DsError> {
        self.mem.get_trial(study, id)
    }

    fn list_trials(&self, study: &str) -> Result<Vec<TrialProto>, DsError> {
        self.mem.list_trials(study)
    }

    fn list_trials_page(
        &self,
        study: &str,
        page_size: usize,
        page_token: &str,
    ) -> Result<super::TrialPage, DsError> {
        // Reads bypass the log: delegate to the in-memory image's keyed
        // page scan.
        self.mem.list_trials_page(study, page_size, page_token)
    }

    fn query_trials(
        &self,
        study: &str,
        filter: &super::query::TrialFilter,
    ) -> Result<Vec<TrialProto>, DsError> {
        self.mem.query_trials(study, filter)
    }

    fn update_trial(&self, study: &str, trial: TrialProto) -> Result<(), DsError> {
        self.commit(study, move |mem| {
            mem.update_trial(study, trial.clone())?;
            Ok(((), vec![Mutation::PutTrial(study.to_string(), trial)]))
        })
    }

    fn delete_trial(&self, study: &str, id: u64) -> Result<(), DsError> {
        self.commit(study, |mem| {
            mem.delete_trial(study, id)?;
            Ok(((), vec![Mutation::DeleteTrial(study.to_string(), id)]))
        })
    }

    fn mutate_trial(
        &self,
        study: &str,
        id: u64,
        f: &mut dyn FnMut(&mut TrialProto) -> Result<(), DsError>,
    ) -> Result<TrialProto, DsError> {
        self.commit(study, |mem| {
            let updated = mem.mutate_trial(study, id, f)?;
            let m = Mutation::PutTrial(study.to_string(), updated.clone());
            Ok((updated, vec![m]))
        })
    }

    fn create_operation(&self, mut op: OperationProto) -> Result<OperationProto, DsError> {
        if op.name.is_empty() {
            op.name = self.mem.reserve_operation_name();
        }
        let lane = op.name.clone();
        self.commit(&lane, move |mem| {
            let created = mem.create_operation(op)?;
            let m = Mutation::PutOperation(created.clone());
            Ok((created, vec![m]))
        })
    }

    fn get_operation(&self, name: &str) -> Result<OperationProto, DsError> {
        self.mem.get_operation(name)
    }

    fn update_operation(&self, op: OperationProto) -> Result<(), DsError> {
        let lane = op.name.clone();
        self.commit(&lane, move |mem| {
            mem.update_operation(op.clone())?;
            Ok(((), vec![Mutation::PutOperation(op)]))
        })
    }

    fn pending_operations(&self) -> Result<Vec<OperationProto>, DsError> {
        self.mem.pending_operations()
    }

    fn update_metadata(
        &self,
        study: &str,
        updates: &[UnitMetadataUpdate],
    ) -> Result<(), DsError> {
        self.commit(study, |mem| {
            mem.update_metadata(study, updates)?;
            // Log the resulting rows (study spec and/or touched trials)
            // as one atomic batch.
            let mut muts = vec![Mutation::PutStudy(mem.get_study(study)?)];
            for u in updates {
                if u.trial_id != 0 {
                    let t = mem.get_trial(study, u.trial_id)?;
                    muts.push(Mutation::PutTrial(study.to_string(), t));
                }
            }
            Ok(((), muts))
        })
    }

    fn trial_count(&self, study: &str) -> Result<usize, DsError> {
        self.mem.trial_count(study)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::messages::TrialState;
    use std::sync::Arc;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ossvizier-wal-{tag}-{}-{}",
            std::process::id(),
            crate::util::id::next_uid()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn study(display: &str) -> StudyProto {
        StudyProto {
            display_name: display.to_string(),
            ..Default::default()
        }
    }

    fn seg_opts(segment_bytes: u64) -> WalOptions {
        WalOptions {
            segment_bytes: Some(segment_bytes),
            ..WalOptions::default()
        }
    }

    #[test]
    fn state_survives_reopen() {
        let dir = tmpdir("reopen");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            let s = ds.create_study(study("exp")).unwrap();
            let mut t = TrialProto::default();
            t.client_id = "w0".into();
            let t = ds.create_trial(&s.name, t).unwrap();
            ds.mutate_trial(&s.name, t.id, &mut |t| {
                t.state = TrialState::Active;
                Ok(())
            })
            .unwrap();
            ds.create_operation(OperationProto {
                study_name: s.name.clone(),
                count: 2,
                ..Default::default()
            })
            .unwrap();
        } // drop = crash without any shutdown handshake
        let ds = WalDatastore::open(&path).unwrap();
        let s = ds.lookup_study("exp").unwrap();
        let t = ds.get_trial(&s.name, 1).unwrap();
        assert_eq!(t.state, TrialState::Active);
        assert_eq!(t.client_id, "w0");
        // Pending operation recovered -> service can resume it.
        let pending = ds.pending_operations().unwrap();
        assert_eq!(pending.len(), 1);
        assert_eq!(pending[0].count, 2);
        // Id counters continue, no collisions.
        let t2 = ds.create_trial(&s.name, TrialProto::default()).unwrap();
        assert_eq!(t2.id, 2);
        let s2 = ds.create_study(study("exp2")).unwrap();
        assert_eq!(s2.name, "studies/2");
    }

    #[test]
    fn torn_tail_is_discarded() {
        let dir = tmpdir("torn");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            ds.create_study(study("a")).unwrap();
            ds.create_study(study("b")).unwrap();
        }
        // Corrupt: chop bytes off the final record.
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 3).unwrap();
        let ds = WalDatastore::open(&path).unwrap();
        assert!(ds.lookup_study("a").is_ok());
        assert!(ds.lookup_study("b").is_err(), "torn record dropped");
        // Store remains writable after truncation.
        ds.create_study(study("c")).unwrap();
        drop(ds);
        let ds = WalDatastore::open(&path).unwrap();
        assert!(ds.lookup_study("c").is_ok());
    }

    #[test]
    fn deletes_survive_replay() {
        let dir = tmpdir("delete");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            let s = ds.create_study(study("a")).unwrap();
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
            ds.delete_trial(&s.name, 1).unwrap();
            let s2 = ds.create_study(study("gone")).unwrap();
            ds.delete_study(&s2.name).unwrap();
        }
        let ds = WalDatastore::open(&path).unwrap();
        let s = ds.lookup_study("a").unwrap();
        assert!(ds.get_trial(&s.name, 1).is_err());
        assert!(ds.get_trial(&s.name, 2).is_ok());
        assert!(ds.lookup_study("gone").is_err());
    }

    #[test]
    fn compaction_shrinks_log_and_preserves_state() {
        let dir = tmpdir("compact");
        let path = dir.join("store.wal");
        let ds = WalDatastore::open(&path).unwrap();
        let s = ds.create_study(study("a")).unwrap();
        let t = ds.create_trial(&s.name, TrialProto::default()).unwrap();
        // Many updates to the same trial bloat the log.
        for i in 0..200 {
            ds.mutate_trial(&s.name, t.id, &mut |t| {
                t.created_ms = i;
                Ok(())
            })
            .unwrap();
        }
        let before = ds.log_size();
        ds.compact().unwrap();
        let after = ds.log_size();
        assert!(after < before / 10, "log {before} -> {after}");
        assert_eq!(ds.metrics().compactions(), 1);
        // Post-compaction appends + replay still correct.
        ds.create_trial(&s.name, TrialProto::default()).unwrap();
        drop(ds);
        let ds = WalDatastore::open(&path).unwrap();
        assert_eq!(ds.trial_count(&ds.lookup_study("a").unwrap().name).unwrap(), 2);
        assert_eq!(ds.get_trial("studies/1", 1).unwrap().created_ms, 199);
    }

    #[test]
    fn metadata_updates_durable() {
        let dir = tmpdir("md");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            let s = ds.create_study(study("a")).unwrap();
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
            ds.update_metadata(
                &s.name,
                &[
                    UnitMetadataUpdate {
                        trial_id: 0,
                        new_trial_index: 0,
                        item: Some(crate::wire::messages::MetadataItem {
                            namespace: "evo".into(),
                            key: "state".into(),
                            value: b"pop1".to_vec(),
                        }),
                    },
                    UnitMetadataUpdate {
                        trial_id: 1,
                        new_trial_index: 0,
                        item: Some(crate::wire::messages::MetadataItem {
                            namespace: "".into(),
                            key: "ckpt".into(),
                            value: b"path".to_vec(),
                        }),
                    },
                ],
            )
            .unwrap();
        }
        let ds = WalDatastore::open(&path).unwrap();
        let s = ds.lookup_study("a").unwrap();
        assert_eq!(s.spec.metadata[0].value, b"pop1");
        assert_eq!(ds.get_trial(&s.name, 1).unwrap().metadata[0].value, b"path");
    }

    #[test]
    fn serial_mode_matches_group_commit_state() {
        let run = |opts: WalOptions, tag: &str| -> Vec<(u64, u64)> {
            let path = tmpdir(tag).join("store.wal");
            {
                let ds = WalDatastore::open_with_options(&path, opts).unwrap();
                let s = ds.create_study(study("m")).unwrap();
                for i in 0..20 {
                    let t = ds.create_trial(&s.name, TrialProto::default()).unwrap();
                    ds.mutate_trial(&s.name, t.id, &mut |t| {
                        t.created_ms = i;
                        Ok(())
                    })
                    .unwrap();
                }
                ds.delete_trial(&s.name, 5).unwrap();
            }
            let ds = WalDatastore::open(&path).unwrap();
            ds.list_trials("studies/1")
                .unwrap()
                .into_iter()
                .map(|t| (t.id, t.created_ms))
                .collect()
        };
        let grouped = run(WalOptions::default(), "gc");
        let serial = run(
            WalOptions {
                group_commit: false,
                ..WalOptions::default()
            },
            "serial",
        );
        assert_eq!(grouped, serial);
        assert_eq!(grouped.len(), 19);
    }

    #[test]
    fn concurrent_writers_share_flushes() {
        let path = tmpdir("batch").join("store.wal");
        let ds = Arc::new(WalDatastore::open_with_sync(&path, true).unwrap());
        let s = ds.create_study(study("gc")).unwrap();
        let threads = 8;
        let per_thread = 50u64;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let ds = Arc::clone(&ds);
                let name = s.name.clone();
                std::thread::spawn(move || {
                    for _ in 0..per_thread {
                        ds.create_trial(&name, TrialProto::default()).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total = threads as u64 * per_thread;
        assert_eq!(ds.trial_count(&s.name).unwrap() as u64, total);
        // +1 record for the create_study.
        assert_eq!(ds.records_flushed(), total + 1);
        assert!(
            ds.batches_flushed() <= ds.records_flushed(),
            "batches {} must not exceed records {}",
            ds.batches_flushed(),
            ds.records_flushed()
        );
        // All ids dense 1..=total, each durable before its ack.
        drop(ds);
        let ds = WalDatastore::open(&path).unwrap();
        let ids: Vec<u64> =
            ds.list_trials("studies/1").unwrap().into_iter().map(|t| t.id).collect();
        assert_eq!(ids, (1..=total).collect::<Vec<u64>>());
    }

    #[test]
    fn torn_group_commit_tail_preserves_acknowledged_writes() {
        // Acked mutations live in flushed batches; simulate a crash that
        // tears the *next* batch mid-record and verify every acked write
        // replays while the torn record is rejected.
        let dir = tmpdir("torn-gc");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            let s = ds.create_study(study("acked")).unwrap();
            for _ in 0..10 {
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
            }
        } // clean shutdown: 11 complete records on disk
        let acked_len = std::fs::metadata(&path).unwrap().len();

        // A crash mid-batch: half a record appended after the acked tail.
        let mut torn = Vec::new();
        append_record(
            &mut torn,
            &Mutation::PutTrial("studies/1".into(), TrialProto { id: 99, ..Default::default() }),
        )
        .unwrap();
        let half = &torn[..torn.len() / 2];
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(half).unwrap();
        f.sync_all().unwrap();
        drop(f);

        let ds = WalDatastore::open(&path).unwrap();
        assert_eq!(ds.trial_count("studies/1").unwrap(), 10, "all acked trials survive");
        assert!(ds.get_trial("studies/1", 99).is_err(), "torn record rejected");
        // Recovery truncated back to the acked prefix.
        assert_eq!(ds.log_size(), acked_len);
    }

    // -- segmented layout ------------------------------------------------

    #[test]
    fn segmented_state_survives_reopen_across_rotations() {
        let dir = tmpdir("seg-reopen");
        let path = dir.join("wal");
        {
            let ds = WalDatastore::open_with_options(&path, seg_opts(2048)).unwrap();
            let s = ds.create_study(study("seg")).unwrap();
            for i in 0..200 {
                let t = ds.create_trial(&s.name, TrialProto::default()).unwrap();
                ds.mutate_trial(&s.name, t.id, &mut |t| {
                    t.created_ms = i;
                    Ok(())
                })
                .unwrap();
            }
            assert!(ds.segment_count() > 1, "rotation must have produced segments");
            assert!(ds.metrics().rotations() >= 1);
        }
        let ds = WalDatastore::open_with_options(&path, seg_opts(2048)).unwrap();
        let s = ds.lookup_study("seg").unwrap();
        assert_eq!(ds.trial_count(&s.name).unwrap(), 200);
        assert_eq!(ds.get_trial(&s.name, 200).unwrap().created_ms, 199);
        // Counters continue, no collisions.
        assert_eq!(ds.create_trial(&s.name, TrialProto::default()).unwrap().id, 201);
    }

    #[test]
    fn segmented_replay_applies_base_then_tail() {
        let dir = tmpdir("seg-base-tail");
        let path = dir.join("wal");
        {
            let ds = WalDatastore::open_with_options(&path, seg_opts(1024)).unwrap();
            let s = ds.create_study(study("bt")).unwrap();
            for _ in 0..40 {
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
            }
            ds.compact().unwrap();
            // Post-compaction commits land in the tail.
            for _ in 0..10 {
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
            }
            ds.delete_trial(&s.name, 3).unwrap();
            let files = segment_files(&path);
            assert!(
                files[0].extension().is_some_and(|e| e == "base"),
                "replay starts at the base: {files:?}"
            );
        }
        let ds = WalDatastore::open_with_options(&path, seg_opts(1024)).unwrap();
        assert_eq!(ds.trial_count("studies/1").unwrap(), 49);
        assert!(ds.get_trial("studies/1", 3).is_err());
        assert!(ds.get_trial("studies/1", 50).is_ok());
        assert_eq!(ds.create_trial("studies/1", TrialProto::default()).unwrap().id, 51);
    }

    #[test]
    fn segmented_compaction_runs_off_the_commit_path() {
        let dir = tmpdir("seg-compact");
        let path = dir.join("wal");
        let committed;
        {
            let ds = Arc::new(WalDatastore::open_with_options(&path, seg_opts(4096)).unwrap());
            let s = ds.create_study(study("c")).unwrap();
            let t = ds.create_trial(&s.name, TrialProto::default()).unwrap();
            for i in 0..10_000 {
                ds.mutate_trial(&s.name, t.id, &mut |t| {
                    t.created_ms = i;
                    Ok(())
                })
                .unwrap();
            }
            let before = ds.log_size();
            // A writer keeps committing while the background compactor
            // runs; none of its commits may be lost or blocked on error.
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let writer = {
                let ds = Arc::clone(&ds);
                let name = s.name.clone();
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut n = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        ds.create_trial(&name, TrialProto::default()).unwrap();
                        n += 1;
                    }
                    n
                })
            };
            ds.compact().unwrap();
            stop.store(true, Ordering::Relaxed);
            committed = writer.join().unwrap();
            assert!(ds.metrics().compactions() >= 1);
            assert!(ds.log_size() < before, "superseded segments deleted");
        }
        // Every acknowledged commit — before, during, and after the
        // compaction — survives replay of base + tail.
        let ds = WalDatastore::open_with_options(&path, seg_opts(4096)).unwrap();
        assert_eq!(ds.trial_count("studies/1").unwrap() as u64, 1 + committed);
        assert_eq!(ds.get_trial("studies/1", 1).unwrap().created_ms, 9999);
    }

    #[test]
    fn per_shard_lanes_preserve_per_study_replay_order() {
        let dir = tmpdir("lanes");
        let path = dir.join("wal");
        let threads = 8usize;
        let per_thread = 100u64;
        {
            let ds =
                Arc::new(WalDatastore::open_with_options(&path, seg_opts(16 * 1024)).unwrap());
            let studies: Vec<String> = (0..threads)
                .map(|i| ds.create_study(study(&format!("lane{i}"))).unwrap().name)
                .collect();
            let handles: Vec<_> = studies
                .iter()
                .map(|name| {
                    let ds = Arc::clone(&ds);
                    let name = name.clone();
                    std::thread::spawn(move || {
                        for i in 0..per_thread {
                            let t = ds.create_trial(&name, TrialProto::default()).unwrap();
                            ds.mutate_trial(&name, t.id, &mut |t| {
                                t.created_ms = i;
                                Ok(())
                            })
                            .unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        }
        let ds = WalDatastore::open_with_options(&path, seg_opts(16 * 1024)).unwrap();
        for i in 0..threads {
            let s = ds.lookup_study(&format!("lane{i}")).unwrap();
            let trials = ds.list_trials(&s.name).unwrap();
            let ids: Vec<u64> = trials.iter().map(|t| t.id).collect();
            assert_eq!(ids, (1..=per_thread).collect::<Vec<u64>>(), "study {i} ids dense");
            for t in trials {
                assert_eq!(t.created_ms, t.id - 1, "per-study replay order held");
            }
        }
    }

    #[test]
    fn serial_apply_baseline_matches_lanes() {
        let run = |serial_apply: bool, tag: &str| -> Vec<(u64, u64)> {
            let path = tmpdir(tag).join("wal");
            let opts = WalOptions {
                serial_apply,
                segment_bytes: Some(1024),
                ..WalOptions::default()
            };
            {
                let ds = WalDatastore::open_with_options(&path, opts).unwrap();
                let s = ds.create_study(study("sa")).unwrap();
                for i in 0..30 {
                    let t = ds.create_trial(&s.name, TrialProto::default()).unwrap();
                    ds.mutate_trial(&s.name, t.id, &mut |t| {
                        t.created_ms = i;
                        Ok(())
                    })
                    .unwrap();
                }
                ds.delete_trial(&s.name, 7).unwrap();
            }
            let ds = WalDatastore::open_with_options(&path, opts).unwrap();
            ds.list_trials("studies/1")
                .unwrap()
                .into_iter()
                .map(|t| (t.id, t.created_ms))
                .collect()
        };
        let lanes = run(false, "sa-lanes");
        let serial = run(true, "sa-serial");
        assert_eq!(lanes, serial);
        assert_eq!(lanes.len(), 29);
    }

    #[test]
    fn auto_compaction_triggers_in_background() {
        let dir = tmpdir("seg-auto");
        let path = dir.join("wal");
        let opts = WalOptions {
            segment_bytes: Some(512),
            auto_compact_segments: 2,
            ..WalOptions::default()
        };
        let ds = WalDatastore::open_with_options(&path, opts).unwrap();
        let s = ds.create_study(study("auto")).unwrap();
        for _ in 0..200 {
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while ds.metrics().compactions() == 0 {
            assert!(std::time::Instant::now() < deadline, "auto-compaction never ran");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(ds.trial_count(&s.name).unwrap(), 200);
    }

    /// The bytes-amplification trigger: a small hot state rewritten over
    /// and over grows the log without growing the file count fast enough
    /// for the segment trigger — the amplification trigger compacts on
    /// the live-log / base-size ratio instead.
    #[test]
    fn amplification_auto_compaction_triggers_in_background() {
        let dir = tmpdir("seg-amp");
        let path = dir.join("wal");
        let opts = WalOptions {
            segment_bytes: Some(2048),
            compact_amplification: 3,
            ..WalOptions::default()
        };
        let ds = WalDatastore::open_with_options(&path, opts).unwrap();
        let s = ds.create_study(study("amp")).unwrap();
        let t = ds.create_trial(&s.name, TrialProto::default()).unwrap();
        // Live state stays two records' worth; the log grows by one
        // record per update. Keep updating until the background
        // compactor has folded the overwrite churn into a base.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while ds.metrics().compactions() == 0 {
            assert!(
                std::time::Instant::now() < deadline,
                "amplification trigger never compacted (log {} bytes in {} segments)",
                ds.log_size(),
                ds.segment_count(),
            );
            ds.update_trial(&s.name, TrialProto { id: t.id, ..Default::default() }).unwrap();
        }
        assert_eq!(ds.trial_count(&s.name).unwrap(), 1);
        // After compaction, the base carries the live state and the log
        // tail restarts near-empty: amplification is actually bounded,
        // not just requested.
        let (log_bytes, base_bytes) = super::live_log_and_base_bytes(&path);
        assert!(base_bytes > 0, "compaction must have produced a base");
        let _ = log_bytes; // racing writers may already regrow the tail
    }

    #[test]
    fn torn_tail_only_allowed_in_final_segment() {
        let dir = tmpdir("seg-torn");
        let path = dir.join("wal");
        {
            let ds = WalDatastore::open_with_options(&path, seg_opts(512)).unwrap();
            let s = ds.create_study(study("t")).unwrap();
            for _ in 0..100 {
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
            }
            assert!(ds.segment_count() >= 3, "need several segments");
        }
        // Drop header-only trailing segments (a legal crash state on
        // their own), then tear the final record-bearing one: recovery
        // truncates.
        let mut logs = segment_files(&path);
        while let Some(last) = logs.last() {
            if std::fs::metadata(last).unwrap().len() <= WAL_HEADER_LEN {
                std::fs::remove_file(last).unwrap();
                logs.pop();
            } else {
                break;
            }
        }
        let tail = logs.last().unwrap().clone();
        let len = std::fs::metadata(&tail).unwrap().len();
        OpenOptions::new().write(true).open(&tail).unwrap().set_len(len - 3).unwrap();
        {
            let ds = WalDatastore::open_with_options(&path, seg_opts(512)).unwrap();
            let n = ds.trial_count("studies/1").unwrap();
            assert!(n < 100 && n > 0, "torn record dropped, acked prefix kept ({n})");
        }
        // A torn record in a sealed (non-final) segment is corruption.
        let first = segment_files(&path)
            .into_iter()
            .find(|p| p.extension().is_some_and(|e| e == "log"))
            .unwrap();
        let len = std::fs::metadata(&first).unwrap().len();
        OpenOptions::new().write(true).open(&first).unwrap().set_len(len - 3).unwrap();
        assert!(WalDatastore::open_with_options(&path, seg_opts(512)).is_err());
    }

    #[test]
    fn segmented_layout_rejects_a_legacy_single_file() {
        let dir = tmpdir("seg-mismatch");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            ds.create_study(study("legacy")).unwrap();
        }
        let err = WalDatastore::open_with_options(&path, seg_opts(1024)).unwrap_err();
        assert!(matches!(err, DsError::Storage(_)));
        // The other direction (opening a segment directory as a
        // single-file log) also fails rather than corrupting anything.
        let seg_path = dir.join("segdir");
        drop(WalDatastore::open_with_options(&seg_path, seg_opts(1024)).unwrap());
        assert!(WalDatastore::open(&seg_path).is_err());
    }

    #[test]
    fn header_mismatch_fails_fast_on_reopen() {
        let dir = tmpdir("hdr");
        let path = dir.join("store.wal");
        {
            let ds = WalDatastore::open(&path).unwrap();
            ds.create_study(study("h")).unwrap();
        }
        let orig = std::fs::read(&path).unwrap();
        assert_eq!(&orig[..8], &WAL_MAGIC);

        // Cross-version open: bump the stamped format version.
        let mut bad = orig.clone();
        bad[8..12].copy_from_slice(&(WAL_FORMAT_VERSION + 1).to_le_bytes());
        std::fs::write(&path, &bad).unwrap();
        let msg = WalDatastore::open(&path).unwrap_err().to_string();
        assert!(msg.contains("format version"), "{msg}");

        // Cross-shard-count open: a stamp this store was not opened with.
        let mut bad = orig.clone();
        bad[12..16].copy_from_slice(&999u32.to_le_bytes());
        std::fs::write(&path, &bad).unwrap();
        let msg = WalDatastore::open(&path).unwrap_err().to_string();
        assert!(msg.contains("999 shards"), "{msg}");

        // Not a WAL file at all.
        let mut bad = orig.clone();
        bad[..8].copy_from_slice(b"GARBAGE!");
        std::fs::write(&path, &bad).unwrap();
        let msg = WalDatastore::open(&path).unwrap_err().to_string();
        assert!(msg.contains("bad magic"), "{msg}");

        // Restored intact, the store reopens with its state.
        std::fs::write(&path, &orig).unwrap();
        let ds = WalDatastore::open(&path).unwrap();
        assert!(ds.lookup_study("h").is_ok());
    }

    #[test]
    fn every_segment_carries_a_header_and_reopens_clean() {
        let dir = tmpdir("hdr-seg");
        let path = dir.join("wal");
        {
            let ds = WalDatastore::open_with_options(&path, seg_opts(512)).unwrap();
            let s = ds.create_study(study("hs")).unwrap();
            for _ in 0..60 {
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
            }
            ds.compact().unwrap();
            for _ in 0..5 {
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
            }
        }
        // Base and every log segment are stamped.
        for f in segment_files(&path) {
            let bytes = std::fs::read(&f).unwrap();
            assert!(bytes.len() >= WAL_HEADER_LEN as usize, "{}", f.display());
            assert_eq!(&bytes[..8], &WAL_MAGIC, "{}", f.display());
        }
        // Reopen replays base + tail through the header checks.
        {
            let ds = WalDatastore::open_with_options(&path, seg_opts(512)).unwrap();
            assert_eq!(ds.trial_count("studies/1").unwrap(), 65);
        }
        // A sealed segment stamped with a different shard count fails the
        // whole open — cross-shard-count replay would scramble lane order.
        let seg = segment_files(&path)
            .into_iter()
            .find(|p| p.extension().is_some_and(|e| e == "log"))
            .unwrap();
        let mut bytes = std::fs::read(&seg).unwrap();
        bytes[12..16].copy_from_slice(&3u32.to_le_bytes());
        std::fs::write(&seg, &bytes).unwrap();
        let msg = WalDatastore::open_with_options(&path, seg_opts(512))
            .unwrap_err()
            .to_string();
        assert!(msg.contains("3 shards"), "{msg}");
    }

    #[test]
    fn segment_file_helpers() {
        let dir = tmpdir("seg-helpers");
        let path = dir.join("wal");
        {
            let ds = WalDatastore::open_with_options(&path, seg_opts(512)).unwrap();
            let s = ds.create_study(study("h")).unwrap();
            for _ in 0..60 {
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
            }
            ds.compact().unwrap();
            for _ in 0..5 {
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
            }
            assert_eq!(ds.segment_count() as usize, segment_files(&path).len());
        }
        let files = segment_files(&path);
        assert!(files[0].extension().is_some_and(|e| e == "base"));
        assert!(files[1..].iter().all(|p| p.extension().is_some_and(|e| e == "log")));
        assert_eq!(&tail_segment(&path).unwrap(), files.last().unwrap());
        assert_eq!(
            total_log_bytes(&path),
            files.iter().map(|p| std::fs::metadata(p).unwrap().len()).sum::<u64>()
        );
    }
}

