//! In-memory datastore: the default backing store, also embedded inside
//! [`super::wal::WalDatastore`] as the materialized state.

use super::{Datastore, DsError};
use crate::wire::messages::{OperationProto, StudyProto, TrialProto, UnitMetadataUpdate};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

#[derive(Debug, Default)]
struct StudyEntry {
    study: StudyProto,
    trials: BTreeMap<u64, TrialProto>,
    next_trial_id: u64,
}

#[derive(Debug, Default)]
struct State {
    studies: HashMap<String, StudyEntry>,
    operations: HashMap<String, OperationProto>,
}

/// Thread-safe in-memory store.
#[derive(Debug, Default)]
pub struct InMemoryDatastore {
    state: RwLock<State>,
    next_study: AtomicU64,
    next_op: AtomicU64,
}

impl InMemoryDatastore {
    pub fn new() -> Self {
        Self {
            state: RwLock::new(State::default()),
            next_study: AtomicU64::new(1),
            next_op: AtomicU64::new(1),
        }
    }

    /// Apply a study proto without assigning a fresh name (used by WAL
    /// replay). Overwrites silently and keeps id counters monotone.
    pub(crate) fn apply_put_study(&self, study: StudyProto) {
        let mut st = self.state.write().unwrap();
        if let Some(n) = study.name.strip_prefix("studies/").and_then(|s| s.parse::<u64>().ok()) {
            self.next_study.fetch_max(n + 1, Ordering::SeqCst);
        }
        let entry = st.studies.entry(study.name.clone()).or_default();
        entry.study = study;
    }

    pub(crate) fn apply_put_trial(&self, study: &str, trial: TrialProto) -> Result<(), DsError> {
        let mut st = self.state.write().unwrap();
        let entry = st
            .studies
            .get_mut(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        entry.next_trial_id = entry.next_trial_id.max(trial.id + 1);
        entry.trials.insert(trial.id, trial);
        Ok(())
    }

    pub(crate) fn apply_put_operation(&self, op: OperationProto) {
        let mut st = self.state.write().unwrap();
        if let Some(n) = op.name.strip_prefix("operations/").and_then(|s| s.parse::<u64>().ok()) {
            self.next_op.fetch_max(n + 1, Ordering::SeqCst);
        }
        st.operations.insert(op.name.clone(), op);
    }

    pub(crate) fn apply_delete_study(&self, name: &str) {
        self.state.write().unwrap().studies.remove(name);
    }

    pub(crate) fn apply_delete_trial(&self, study: &str, id: u64) {
        if let Some(e) = self.state.write().unwrap().studies.get_mut(study) {
            e.trials.remove(&id);
        }
    }
}

impl Datastore for InMemoryDatastore {
    fn create_study(&self, mut study: StudyProto) -> Result<StudyProto, DsError> {
        let mut st = self.state.write().unwrap();
        if study.name.is_empty() {
            let id = self.next_study.fetch_add(1, Ordering::SeqCst);
            study.name = format!("studies/{id}");
        }
        if st.studies.contains_key(&study.name) {
            return Err(DsError::StudyExists(study.name));
        }
        if !study.display_name.is_empty()
            && st.studies.values().any(|e| e.study.display_name == study.display_name)
        {
            return Err(DsError::StudyExists(study.display_name));
        }
        st.studies.insert(
            study.name.clone(),
            StudyEntry {
                study: study.clone(),
                trials: BTreeMap::new(),
                next_trial_id: 1,
            },
        );
        Ok(study)
    }

    fn get_study(&self, name: &str) -> Result<StudyProto, DsError> {
        self.state
            .read()
            .unwrap()
            .studies
            .get(name)
            .map(|e| e.study.clone())
            .ok_or_else(|| DsError::StudyNotFound(name.to_string()))
    }

    fn lookup_study(&self, display_name: &str) -> Result<StudyProto, DsError> {
        self.state
            .read()
            .unwrap()
            .studies
            .values()
            .find(|e| e.study.display_name == display_name)
            .map(|e| e.study.clone())
            .ok_or_else(|| DsError::StudyNotFound(display_name.to_string()))
    }

    fn list_studies(&self) -> Result<Vec<StudyProto>, DsError> {
        let st = self.state.read().unwrap();
        let mut studies: Vec<StudyProto> = st.studies.values().map(|e| e.study.clone()).collect();
        studies.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(studies)
    }

    fn update_study(&self, study: StudyProto) -> Result<(), DsError> {
        let mut st = self.state.write().unwrap();
        let entry = st
            .studies
            .get_mut(&study.name)
            .ok_or_else(|| DsError::StudyNotFound(study.name.clone()))?;
        entry.study = study;
        Ok(())
    }

    fn delete_study(&self, name: &str) -> Result<(), DsError> {
        let mut st = self.state.write().unwrap();
        st.studies
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| DsError::StudyNotFound(name.to_string()))
    }

    fn create_trial(&self, study: &str, mut trial: TrialProto) -> Result<TrialProto, DsError> {
        let mut st = self.state.write().unwrap();
        let entry = st
            .studies
            .get_mut(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        trial.id = entry.next_trial_id;
        entry.next_trial_id += 1;
        entry.trials.insert(trial.id, trial.clone());
        Ok(trial)
    }

    fn get_trial(&self, study: &str, id: u64) -> Result<TrialProto, DsError> {
        let st = self.state.read().unwrap();
        st.studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?
            .trials
            .get(&id)
            .cloned()
            .ok_or_else(|| DsError::TrialNotFound(study.to_string(), id))
    }

    fn list_trials(&self, study: &str) -> Result<Vec<TrialProto>, DsError> {
        let st = self.state.read().unwrap();
        Ok(st
            .studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?
            .trials
            .values()
            .cloned()
            .collect())
    }

    fn query_trials(
        &self,
        study: &str,
        filter: &super::query::TrialFilter,
    ) -> Result<Vec<TrialProto>, DsError> {
        let st = self.state.read().unwrap();
        let entry = st
            .studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        // Range-scan from min_id so incremental reads touch only new rows,
        // and clone only matching trials (the §6.3 database-work saving).
        let lo = filter.min_id.unwrap_or(0);
        let hi = filter.max_id.unwrap_or(u64::MAX);
        let mut kept: Vec<TrialProto> = entry
            .trials
            .range(lo..=hi)
            .map(|(_, t)| t)
            .filter(|t| filter.matches(t))
            .cloned()
            .collect();
        if let Some(limit) = filter.limit {
            if kept.len() > limit {
                kept = kept.split_off(kept.len() - limit);
            }
        }
        Ok(kept)
    }

    fn update_trial(&self, study: &str, trial: TrialProto) -> Result<(), DsError> {
        let mut st = self.state.write().unwrap();
        let entry = st
            .studies
            .get_mut(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        if !entry.trials.contains_key(&trial.id) {
            return Err(DsError::TrialNotFound(study.to_string(), trial.id));
        }
        entry.trials.insert(trial.id, trial);
        Ok(())
    }

    fn delete_trial(&self, study: &str, id: u64) -> Result<(), DsError> {
        let mut st = self.state.write().unwrap();
        let entry = st
            .studies
            .get_mut(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        entry
            .trials
            .remove(&id)
            .map(|_| ())
            .ok_or_else(|| DsError::TrialNotFound(study.to_string(), id))
    }

    fn mutate_trial(
        &self,
        study: &str,
        id: u64,
        f: &mut dyn FnMut(&mut TrialProto) -> Result<(), DsError>,
    ) -> Result<TrialProto, DsError> {
        let mut st = self.state.write().unwrap();
        let entry = st
            .studies
            .get_mut(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        let trial = entry
            .trials
            .get_mut(&id)
            .ok_or_else(|| DsError::TrialNotFound(study.to_string(), id))?;
        f(trial)?;
        Ok(trial.clone())
    }

    fn create_operation(&self, mut op: OperationProto) -> Result<OperationProto, DsError> {
        let mut st = self.state.write().unwrap();
        if op.name.is_empty() {
            let id = self.next_op.fetch_add(1, Ordering::SeqCst);
            op.name = format!("operations/{id}");
        }
        st.operations.insert(op.name.clone(), op.clone());
        Ok(op)
    }

    fn get_operation(&self, name: &str) -> Result<OperationProto, DsError> {
        self.state
            .read()
            .unwrap()
            .operations
            .get(name)
            .cloned()
            .ok_or_else(|| DsError::OperationNotFound(name.to_string()))
    }

    fn update_operation(&self, op: OperationProto) -> Result<(), DsError> {
        let mut st = self.state.write().unwrap();
        if !st.operations.contains_key(&op.name) {
            return Err(DsError::OperationNotFound(op.name.clone()));
        }
        st.operations.insert(op.name.clone(), op);
        Ok(())
    }

    fn pending_operations(&self) -> Result<Vec<OperationProto>, DsError> {
        let st = self.state.read().unwrap();
        let mut ops: Vec<OperationProto> =
            st.operations.values().filter(|o| !o.done).cloned().collect();
        ops.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(ops)
    }

    fn update_metadata(
        &self,
        study: &str,
        updates: &[UnitMetadataUpdate],
    ) -> Result<(), DsError> {
        let mut st = self.state.write().unwrap();
        let entry = st
            .studies
            .get_mut(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        for u in updates {
            let Some(item) = &u.item else { continue };
            if u.trial_id == 0 {
                // Study-level metadata table.
                let md = &mut entry.study.spec.metadata;
                md.retain(|m| !(m.namespace == item.namespace && m.key == item.key));
                md.push(item.clone());
            } else {
                let trial = entry
                    .trials
                    .get_mut(&u.trial_id)
                    .ok_or_else(|| DsError::TrialNotFound(study.to_string(), u.trial_id))?;
                trial
                    .metadata
                    .retain(|m| !(m.namespace == item.namespace && m.key == item.key));
                trial.metadata.push(item.clone());
            }
        }
        Ok(())
    }

    fn trial_count(&self, study: &str) -> Result<usize, DsError> {
        let st = self.state.read().unwrap();
        Ok(st
            .studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?
            .trials
            .len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::messages::MetadataItem;
    use std::sync::Arc;

    fn study(display: &str) -> StudyProto {
        StudyProto {
            display_name: display.to_string(),
            ..Default::default()
        }
    }

    #[test]
    fn study_crud() {
        let ds = InMemoryDatastore::new();
        let s = ds.create_study(study("a")).unwrap();
        assert_eq!(s.name, "studies/1");
        assert_eq!(ds.get_study("studies/1").unwrap().display_name, "a");
        assert_eq!(ds.lookup_study("a").unwrap().name, "studies/1");
        let s2 = ds.create_study(study("b")).unwrap();
        assert_eq!(s2.name, "studies/2");
        assert_eq!(ds.list_studies().unwrap().len(), 2);
        ds.delete_study("studies/1").unwrap();
        assert_eq!(ds.get_study("studies/1"), Err(DsError::StudyNotFound("studies/1".into())));
        assert!(ds.delete_study("studies/1").is_err());
    }

    #[test]
    fn duplicate_display_name_rejected() {
        let ds = InMemoryDatastore::new();
        ds.create_study(study("same")).unwrap();
        assert!(matches!(ds.create_study(study("same")), Err(DsError::StudyExists(_))));
    }

    #[test]
    fn trial_ids_are_sequential_per_study() {
        let ds = InMemoryDatastore::new();
        let s1 = ds.create_study(study("a")).unwrap();
        let s2 = ds.create_study(study("b")).unwrap();
        for expect in 1..=3 {
            let t = ds.create_trial(&s1.name, TrialProto::default()).unwrap();
            assert_eq!(t.id, expect);
        }
        let t = ds.create_trial(&s2.name, TrialProto::default()).unwrap();
        assert_eq!(t.id, 1, "ids are per-study");
        assert_eq!(ds.trial_count(&s1.name).unwrap(), 3);
    }

    #[test]
    fn mutate_trial_atomicity() {
        let ds = Arc::new(InMemoryDatastore::new());
        let s = ds.create_study(study("a")).unwrap();
        ds.create_trial(&s.name, TrialProto::default()).unwrap();
        // 8 threads increment created_ms 100 times each via mutate_trial.
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let ds = Arc::clone(&ds);
                let name = s.name.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        ds.mutate_trial(&name, 1, &mut |t| {
                            t.created_ms += 1;
                            Ok(())
                        })
                        .unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(ds.get_trial(&s.name, 1).unwrap().created_ms, 800);
    }

    #[test]
    fn operations() {
        let ds = InMemoryDatastore::new();
        let op = ds.create_operation(OperationProto::default()).unwrap();
        assert_eq!(op.name, "operations/1");
        assert_eq!(ds.pending_operations().unwrap().len(), 1);
        let mut done = op.clone();
        done.done = true;
        ds.update_operation(done).unwrap();
        assert!(ds.pending_operations().unwrap().is_empty());
        assert!(ds.get_operation("operations/1").unwrap().done);
        assert!(ds.get_operation("operations/99").is_err());
    }

    #[test]
    fn metadata_updates_upsert() {
        let ds = InMemoryDatastore::new();
        let s = ds.create_study(study("a")).unwrap();
        ds.create_trial(&s.name, TrialProto::default()).unwrap();
        let item = |v: &[u8]| MetadataItem {
            namespace: "evo".into(),
            key: "pop".into(),
            value: v.to_vec(),
        };
        // Study-level write then overwrite.
        ds.update_metadata(
            &s.name,
            &[UnitMetadataUpdate { trial_id: 0, item: Some(item(b"v1")) }],
        )
        .unwrap();
        ds.update_metadata(
            &s.name,
            &[UnitMetadataUpdate { trial_id: 0, item: Some(item(b"v2")) }],
        )
        .unwrap();
        let study = ds.get_study(&s.name).unwrap();
        assert_eq!(study.spec.metadata.len(), 1);
        assert_eq!(study.spec.metadata[0].value, b"v2");
        // Trial-level write.
        ds.update_metadata(
            &s.name,
            &[UnitMetadataUpdate { trial_id: 1, item: Some(item(b"t")) }],
        )
        .unwrap();
        assert_eq!(ds.get_trial(&s.name, 1).unwrap().metadata[0].value, b"t");
        // Unknown trial errors.
        assert!(ds
            .update_metadata(
                &s.name,
                &[UnitMetadataUpdate { trial_id: 99, item: Some(item(b"x")) }],
            )
            .is_err());
    }

    #[test]
    fn errors_for_missing_entities() {
        let ds = InMemoryDatastore::new();
        assert!(ds.get_trial("studies/1", 1).is_err());
        assert!(ds.list_trials("nope").is_err());
        assert!(ds.create_trial("nope", TrialProto::default()).is_err());
        assert!(ds.update_trial("nope", TrialProto::default()).is_err());
        let s = ds.create_study(study("a")).unwrap();
        assert!(ds.update_trial(&s.name, TrialProto { id: 5, ..Default::default() }).is_err());
    }
}
