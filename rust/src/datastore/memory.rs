//! In-memory datastore: the default backing store, also embedded inside
//! [`super::wal::WalDatastore`] as the materialized state.
//!
//! # Sharding
//!
//! State is partitioned into [`DEFAULT_SHARD_COUNT`] independent shards
//! (configurable via [`InMemoryDatastore::with_shards`]), each behind its
//! own `RwLock`. A study is routed to a shard by a stable FNV-1a hash of
//! its resource name, so all trial operations for one study serialize on
//! one shard lock while different studies proceed in parallel — the
//! paper's "multiple parallel evaluations" load pattern (§3.1) no longer
//! funnels through a single global lock. Operations are routed the same
//! way by operation name.
//!
//! # Copy-on-write snapshot reads (the default)
//!
//! Each shard's state is an immutable `ShardImage` behind an
//! atomically-swappable pointer (`ImageCell`). Writers mutate under the
//! shard *write* lock by cloning only the touched layers
//! (`Arc::make_mut` on the image, the study, and one trial chunk), then
//! publish the new image with a single atomic pointer swap. Readers do
//! one atomic load and scan the immutable image with **zero locks
//! held** — a burst of `ListTrials`/`QueryTrials`/suggest reads never
//! stalls behind a writer, and the WAL compactor's base snapshot is one
//! pointer load per shard instead of paged lock holds.
//!
//! Reclamation uses a pin counter per cell: readers increment `pins`
//! around the load+upgrade window, and a publisher parks the previous
//! image in a small graveyard (lock class `datastore.image_retire`),
//! clearing it only when it observes zero pinned readers. All three
//! accesses are `SeqCst`, so a publisher that sees `pins == 0` knows
//! every reader either upgraded its raw pointer to a real reference
//! already or will load the *new* pointer.
//!
//! Trials inside a study are stored in fixed-capacity chunks
//! (`CHUNK_CAP` rows per `Arc` chunk, keyed by their minimum trial id),
//! so a single-trial write clones O(studies-in-shard + chunks-per-study
//! + `CHUNK_CAP`) `Arc`s — not the whole trial table.
//!
//! The pre-snapshot behavior (readers take the shard read lock, writers
//! mutate in place) is kept as a recorded baseline behind
//! `--datastore-cow=off` / `OSSVIZIER_DATASTORE_COW=off`, mirroring the
//! `--poller` and `serial_apply` baselines. The same write path serves
//! both modes: with no published image holding a second reference,
//! `Arc::make_mut` mutates in place and clones nothing.
//!
//! Cross-shard concerns:
//! * `list_studies` / `pending_operations` read shards one at a time
//!   (snapshots in CoW mode; one read lock at a time in baseline mode —
//!   never two at once) and merge.
//! * display-name lookup and uniqueness go through a small `directory`
//!   mutex (display name → study name). Lock order is always
//!   directory → shard, and the directory lock is never held while
//!   another directory-taking call runs, so the pair cannot deadlock.
//!
//! All locks are registered with the crate lock hierarchy
//! ([`crate::util::sync::classes`]: `datastore.directory` before
//! `datastore.shard` before `datastore.image_retire`), so the order
//! above is machine-checked under lockdep (debug builds /
//! `OSSVIZIER_LOCKDEP=1`) — see `rust/docs/INVARIANTS.md`. Which read
//! path served a workload is observable through
//! [`crate::service::metrics::DatastoreMetrics`]
//! (`snapshot_loads` vs `locked_reads`).

use super::{Datastore, DsError, StudyPage, TrialPage};
use crate::service::metrics::DatastoreMetrics;
use crate::util::sync::{classes, Mutex, RwLock, RwLockReadGuard};
use crate::wire::messages::{OperationProto, StudyProto, TrialProto, UnitMetadataUpdate};
use std::collections::{BTreeMap, HashMap};
use std::ops::Bound;
use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::Arc;

/// Default number of shards (a power of two comfortably above typical
/// worker-thread counts, so independent studies rarely collide).
pub const DEFAULT_SHARD_COUNT: usize = 16;

/// Trials per storage chunk. Large enough that chunk bookkeeping is
/// negligible next to the trial payloads, small enough that a
/// copy-on-write of one chunk (one trial insert) stays O(64) `Arc`
/// clones instead of O(trials-in-study).
const CHUNK_CAP: usize = 64;

/// Stable (process-independent) FNV-1a hash used for shard routing, so
/// tests and tooling can predict placement.
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// `OSSVIZIER_DATASTORE_COW` environment default: copy-on-write snapshot
/// reads are ON unless the variable is set to `off`/`0`/`false`.
pub fn cow_default_from_env() -> bool {
    match std::env::var("OSSVIZIER_DATASTORE_COW") {
        Ok(v) => !matches!(v.trim().to_ascii_lowercase().as_str(), "off" | "0" | "false"),
        Err(_) => true,
    }
}

// ---------------------------------------------------------------------------
// Immutable image types
// ---------------------------------------------------------------------------

/// One fixed-capacity run of trials, keyed in the parent map by its
/// minimum trial id. Invariants: never empty once stored, key == min id,
/// chunk key ranges are disjoint and ordered.
#[derive(Debug, Clone, Default)]
struct Chunk {
    trials: BTreeMap<u64, Arc<TrialProto>>,
}

/// One study's immutable image: the spec plus chunked trials. Writers
/// clone-on-write only the layers they touch (`Arc::make_mut`).
#[derive(Debug, Clone)]
pub(crate) struct StudyImage {
    study: Arc<StudyProto>,
    /// Chunk key = minimum trial id stored in that chunk.
    chunks: BTreeMap<u64, Arc<Chunk>>,
    next_trial_id: u64,
    trial_count: usize,
}

impl StudyImage {
    fn new(study: StudyProto) -> Self {
        Self {
            study: Arc::new(study),
            chunks: BTreeMap::new(),
            next_trial_id: 1,
            trial_count: 0,
        }
    }

    /// The study row (spec only, no trials).
    pub(crate) fn study(&self) -> &StudyProto {
        &self.study
    }

    /// All trials in id order, borrowed from the image (the WAL
    /// compactor serializes from this without cloning the table).
    pub(crate) fn trials(&self) -> impl Iterator<Item = &TrialProto> + '_ {
        self.chunks
            .values()
            .flat_map(|c| c.trials.values())
            .map(|t| t.as_ref())
    }

    fn get_trial(&self, id: u64) -> Option<&TrialProto> {
        let (_, c) = self.chunks.range(..=id).next_back()?;
        c.trials.get(&id).map(|t| t.as_ref())
    }

    /// Visit trials with ids in `[lo, hi]` in order; `f` returns `false`
    /// to stop early (pagination fills).
    fn for_each_in_range(&self, lo: u64, hi: u64, f: &mut dyn FnMut(&TrialProto) -> bool) {
        if lo > hi {
            return;
        }
        // The chunk covering `lo` may be keyed below it; start there.
        let begin = self
            .chunks
            .range(..=lo)
            .next_back()
            .map(|(k, _)| *k)
            .unwrap_or(lo);
        for (_, c) in self.chunks.range(begin..=hi) {
            for (_, t) in c.trials.range(lo..=hi) {
                if !f(t) {
                    return;
                }
            }
        }
    }

    /// Upsert one trial, keeping the chunk invariants: splits an
    /// over-cap chunk at its median, re-keys on a new minimum, and
    /// starts a fresh tail chunk when appending past a full one (the
    /// monotonically-growing-id fast path — append-heavy studies never
    /// split).
    fn put_trial(&mut self, trial: TrialProto) {
        let id = trial.id;
        let candidate = self
            .chunks
            .range(..=id)
            .next_back()
            .map(|(k, _)| *k)
            .or_else(|| self.chunks.keys().next().copied());
        let Some(key) = candidate else {
            let mut c = Chunk::default();
            c.trials.insert(id, Arc::new(trial));
            self.chunks.insert(id, Arc::new(c));
            self.trial_count += 1;
            return;
        };
        if key <= id {
            let is_tail = self
                .chunks
                .range((Bound::Excluded(key), Bound::Unbounded))
                .next()
                .is_none();
            if is_tail {
                if let Some(tail) = self.chunks.get(&key) {
                    let past_end = tail.trials.keys().next_back().is_some_and(|m| *m < id);
                    if past_end && tail.trials.len() >= CHUNK_CAP {
                        let mut c = Chunk::default();
                        c.trials.insert(id, Arc::new(trial));
                        self.chunks.insert(id, Arc::new(c));
                        self.trial_count += 1;
                        return;
                    }
                }
            }
        }
        // General path: detach the candidate chunk, mutate, split if
        // over cap, and re-insert keyed by its (possibly new) minimum.
        let mut chunk = match self.chunks.remove(&key) {
            Some(c) => c,
            None => Arc::new(Chunk::default()), // unreachable: `key` was read from the map
        };
        let c = Arc::make_mut(&mut chunk);
        if c.trials.insert(id, Arc::new(trial)).is_none() {
            self.trial_count += 1;
        }
        if c.trials.len() > CHUNK_CAP {
            let mid_key = c.trials.keys().nth(c.trials.len() / 2).copied();
            if let Some(mid) = mid_key {
                let upper = c.trials.split_off(&mid);
                self.chunks.insert(mid, Arc::new(Chunk { trials: upper }));
            }
        }
        if let Some(min) = chunk.trials.keys().next().copied() {
            self.chunks.insert(min, chunk);
        }
    }

    /// Remove one trial; empty chunks are dropped, a removed minimum
    /// re-keys the chunk. Returns whether the id was present.
    fn delete_trial(&mut self, id: u64) -> bool {
        let Some(key) = self.chunks.range(..=id).next_back().map(|(k, _)| *k) else {
            return false;
        };
        let Some(mut chunk) = self.chunks.remove(&key) else {
            return false;
        };
        let removed = Arc::make_mut(&mut chunk).trials.remove(&id).is_some();
        if removed {
            self.trial_count = self.trial_count.saturating_sub(1);
        }
        if let Some(min) = chunk.trials.keys().next().copied() {
            self.chunks.insert(min, chunk);
        }
        removed
    }

    fn get_trial_mut(&mut self, id: u64) -> Option<&mut TrialProto> {
        let key = self.chunks.range(..=id).next_back().map(|(k, _)| *k)?;
        let chunk = self.chunks.get_mut(&key)?;
        if !chunk.trials.contains_key(&id) {
            return None;
        }
        Arc::make_mut(chunk).trials.get_mut(&id).map(Arc::make_mut)
    }
}

/// One shard's immutable image: every read path scans exactly one of
/// these, either freshly loaded from the shard's `ImageCell` (CoW mode,
/// no locks) or borrowed under the shard read lock (baseline mode).
#[derive(Debug, Clone, Default)]
pub(crate) struct ShardImage {
    studies: HashMap<String, Arc<StudyImage>>,
    operations: HashMap<String, Arc<OperationProto>>,
}

impl ShardImage {
    /// The shard's study images (the WAL compactor's iteration surface).
    pub(crate) fn studies(&self) -> impl Iterator<Item = &StudyImage> + '_ {
        self.studies.values().map(|e| e.as_ref())
    }

    /// Operations with `done == false` resident in this shard
    /// (compaction is where the log sheds completed ones).
    pub(crate) fn pending_ops(&self) -> impl Iterator<Item = &OperationProto> + '_ {
        self.operations.values().map(|o| o.as_ref()).filter(|o| !o.done)
    }
}

// ---------------------------------------------------------------------------
// Publish / reclaim cell
// ---------------------------------------------------------------------------

/// Atomically-swappable pointer to the shard's current image, plus the
/// pin-counter reclamation protocol described in the module docs.
///
/// The cell owns one strong count for the image its pointer names; a
/// publish transfers that ownership to the graveyard until no reader can
/// still hold the retired image's raw pointer un-upgraded.
#[derive(Debug)]
struct ImageCell {
    ptr: AtomicPtr<ShardImage>,
    /// Readers inside the load→upgrade window right now.
    pins: AtomicU64,
    /// Retired images awaiting reclamation; cleared by the next publish
    /// that observes zero pins.
    retired: Mutex<Vec<Arc<ShardImage>>>,
}

impl ImageCell {
    fn new(image: Arc<ShardImage>) -> Self {
        Self {
            ptr: AtomicPtr::new(Arc::into_raw(image) as *mut ShardImage),
            pins: AtomicU64::new(0),
            retired: Mutex::new(&classes::DS_IMAGE, Vec::new()),
        }
    }

    /// Lock-free snapshot load: one pin bump, one pointer load, one
    /// refcount bump.
    fn load(&self, metrics: &DatastoreMetrics) -> Arc<ShardImage> {
        self.pins.fetch_add(1, Ordering::SeqCst);
        metrics.pinned_inc();
        let p = self.ptr.load(Ordering::SeqCst);
        // SAFETY: `p` came from `Arc::into_raw` (in `new` or `publish`)
        // and a strong count for it is held by the cell or — if a
        // publisher already swapped it out — by that publisher's
        // graveyard entry. The graveyard cannot be cleared while this
        // pin is visible: the publisher reads `pins` with SeqCst *after*
        // parking the old image, and our `fetch_add` precedes our
        // pointer load in the SeqCst total order, so a publisher that
        // observes zero pins knows we either already upgraded the raw
        // pointer below or will load its new pointer instead.
        // `increment_strong_count` before `from_raw` leaves the
        // cell's/graveyard's own reference intact.
        let image = unsafe {
            Arc::increment_strong_count(p);
            Arc::from_raw(p)
        };
        self.pins.fetch_sub(1, Ordering::SeqCst);
        metrics.pinned_dec();
        image
    }

    /// Publish a new image (caller holds the shard write lock, so
    /// publishes are serialized per shard) and retire the old one.
    fn publish(&self, image: Arc<ShardImage>, metrics: &DatastoreMetrics) {
        let new_raw = Arc::into_raw(image) as *mut ShardImage;
        let old_raw = self.ptr.swap(new_raw, Ordering::SeqCst);
        // SAFETY: `old_raw` was produced by `Arc::into_raw` in `new` or
        // a previous `publish`, and the cell held its strong count until
        // this swap transferred that ownership to us.
        let old = unsafe { Arc::from_raw(old_raw) };
        let mut retired = self.retired.lock();
        retired.push(old);
        metrics.retired_images.fetch_add(1, Ordering::Relaxed);
        // Zero visible pins ⇒ every retired image's raw pointer has been
        // upgraded to a real reference (or was never loaded), so the
        // graveyard's strong counts are the last thing keeping
        // unreferenced images alive. See `load` for the ordering
        // argument.
        if self.pins.load(Ordering::SeqCst) == 0 {
            let n = retired.len() as u64;
            retired.clear();
            metrics.retired_images.fetch_sub(n, Ordering::Relaxed);
        }
    }
}

impl Drop for ImageCell {
    fn drop(&mut self) {
        // SAFETY: the pointer was produced by `Arc::into_raw` and the
        // cell owns exactly one strong count for it; `&mut self`
        // guarantees no concurrent `load`/`publish`.
        let p = *self.ptr.get_mut();
        unsafe { drop(Arc::from_raw(p)) };
    }
}

// ---------------------------------------------------------------------------
// The store
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
struct ShardState {
    image: Arc<ShardImage>,
}

/// One shard's top-level contents as captured by
/// [`InMemoryDatastore::snapshot_shard`]. Baseline-mode compaction path:
/// trials are deliberately NOT cloned here — they are streamed per study
/// in keyed pages ([`Datastore::list_trials_page`]) so no single lock
/// acquisition holds a shard's writers for longer than one page clone.
/// In CoW mode the compactor bypasses this entirely and iterates one
/// atomically-loaded shard image (`InMemoryDatastore::shard_image`),
/// holding no shard locks at all.
#[derive(Debug, Default)]
pub(crate) struct ShardSnapshot {
    /// The shard's study rows (specs only, no trials).
    pub studies: Vec<StudyProto>,
    /// Operations with `done == false` resident in this shard.
    pub pending_ops: Vec<OperationProto>,
}

/// A borrowed-or-owned view of one shard's image: `Snapshot` is the
/// lock-free CoW path, `Locked` the baseline read-lock path. Both deref
/// to the same immutable `ShardImage`, so every read method is written
/// once.
enum ImageRef<'a> {
    Snapshot(Arc<ShardImage>),
    Locked(RwLockReadGuard<'a, ShardState>),
}

impl std::ops::Deref for ImageRef<'_> {
    type Target = ShardImage;
    fn deref(&self) -> &ShardImage {
        match self {
            ImageRef::Snapshot(img) => img,
            ImageRef::Locked(guard) => guard.image.as_ref(),
        }
    }
}

/// Thread-safe sharded in-memory store.
#[derive(Debug)]
pub struct InMemoryDatastore {
    shards: Vec<RwLock<ShardState>>,
    /// `Some` iff copy-on-write snapshot reads are enabled (one cell per
    /// shard); `None` is the lock-per-read baseline.
    images: Option<Vec<ImageCell>>,
    /// display name -> study name (fast `lookup_study`, uniqueness check).
    directory: Mutex<HashMap<String, String>>,
    next_study: AtomicU64,
    next_op: AtomicU64,
    metrics: Arc<DatastoreMetrics>,
}

impl Default for InMemoryDatastore {
    fn default() -> Self {
        Self::new()
    }
}

impl InMemoryDatastore {
    pub fn new() -> Self {
        Self::with_shards(DEFAULT_SHARD_COUNT)
    }

    /// Store with an explicit shard count (>= 1) and the environment's
    /// read-path mode (see [`cow_default_from_env`]). `with_shards(1)`
    /// is the single-lock layout, kept as a benchmark baseline.
    pub fn with_shards(n: usize) -> Self {
        Self::with_shards_cow(n, cow_default_from_env())
    }

    /// Store with an explicit shard count and read-path mode: `cow =
    /// true` publishes immutable shard images for lock-free reads,
    /// `false` is the lock-per-read baseline (`--datastore-cow=off`).
    pub fn with_shards_cow(n: usize, cow: bool) -> Self {
        let n = n.max(1);
        let mut shards = Vec::with_capacity(n);
        let mut cells = Vec::with_capacity(n);
        for _ in 0..n {
            let image = Arc::new(ShardImage::default());
            if cow {
                cells.push(ImageCell::new(Arc::clone(&image)));
            }
            shards.push(RwLock::new(&classes::DS_SHARD, ShardState { image }));
        }
        Self {
            shards,
            images: cow.then_some(cells),
            directory: Mutex::new(&classes::DS_DIRECTORY, HashMap::new()),
            next_study: AtomicU64::new(1),
            next_op: AtomicU64::new(1),
            metrics: Arc::new(DatastoreMetrics::default()),
        }
    }

    /// Whether reads go through published copy-on-write snapshots.
    pub fn cow_enabled(&self) -> bool {
        self.images.is_some()
    }

    /// Snapshot/contention counters, for linking into
    /// [`crate::service::metrics::ServiceMetrics`].
    pub fn metrics(&self) -> Arc<DatastoreMetrics> {
        Arc::clone(&self.metrics)
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard a study (or operation) name routes to. Deterministic:
    /// the same name always maps to the same shard for a given count.
    pub fn shard_index(&self, name: &str) -> usize {
        (fnv1a(name) % self.shards.len() as u64) as usize
    }

    /// Names of the studies currently resident in shard `idx` (unsorted).
    /// Introspection for tests and tooling.
    pub fn studies_in_shard(&self, idx: usize) -> Vec<String> {
        let image = self.read_shard(idx);
        image.studies.keys().cloned().collect()
    }

    /// One shard's current image, read the mode-appropriate way: a
    /// lock-free cell load in CoW mode, a read-lock borrow in baseline
    /// mode. Every read path goes through here (and is counted).
    fn read_shard(&self, idx: usize) -> ImageRef<'_> {
        match &self.images {
            Some(cells) => {
                self.metrics.record_snapshot_load();
                ImageRef::Snapshot(cells[idx].load(&self.metrics))
            }
            None => {
                self.metrics.record_locked_read();
                ImageRef::Locked(self.shards[idx].read())
            }
        }
    }

    /// Run `f` against the shard's image under the write lock and, in
    /// CoW mode, publish the resulting image if `f` produced a new one
    /// (`Arc::make_mut` leaves the pointer untouched when nothing
    /// shared was mutated — including every pure-validation error path).
    /// A changed pointer is published even when `f` errors: partial
    /// mutations (`mutate_trial`'s closure failing midway, metadata
    /// batches erroring on a late row) stay visible exactly as they do
    /// in baseline mode, so the published image never diverges from the
    /// authoritative state.
    fn with_shard_mut<R>(
        &self,
        idx: usize,
        f: impl FnOnce(&mut Arc<ShardImage>) -> Result<R, DsError>,
    ) -> Result<R, DsError> {
        let mut state = self.shards[idx].write();
        let before = Arc::as_ptr(&state.image);
        let out = f(&mut state.image);
        if let Some(cells) = &self.images {
            if !std::ptr::eq(Arc::as_ptr(&state.image), before) {
                cells[idx].publish(Arc::clone(&state.image), &self.metrics);
                self.metrics.record_snapshot_publish();
            }
        }
        if out.is_ok() {
            self.metrics.record_shard_write();
        }
        out
    }

    /// Clone-on-write down to one study's mutable image. Callers
    /// validate existence (and anything else read-only) *before* this,
    /// on the shared image, so error paths never clone.
    fn study_mut<'a>(
        image: &'a mut Arc<ShardImage>,
        study: &str,
    ) -> Result<&'a mut StudyImage, DsError> {
        if !image.studies.contains_key(study) {
            return Err(DsError::StudyNotFound(study.to_string()));
        }
        match Arc::make_mut(image).studies.get_mut(study) {
            Some(e) => Ok(Arc::make_mut(e)),
            None => Err(DsError::StudyNotFound(study.to_string())), // unreachable: checked above
        }
    }

    /// `true` if any shard holds a study with this display name. The
    /// authoritative alias scan behind create-time uniqueness (the
    /// directory only tracks current owners; `update_study` renames can
    /// leave aliases it no longer maps).
    fn any_study_with_display(&self, display: &str) -> bool {
        for idx in 0..self.shards.len() {
            let image = self.read_shard(idx);
            if image.studies.values().any(|e| e.study.display_name == display) {
                return true;
            }
        }
        false
    }

    /// Apply a study proto without assigning a fresh name (used by WAL
    /// replay). Overwrites silently and keeps id counters monotone.
    pub(crate) fn apply_put_study(&self, study: StudyProto) {
        if let Some(n) = study.name.strip_prefix("studies/").and_then(|s| s.parse::<u64>().ok()) {
            self.next_study.fetch_max(n + 1, Ordering::SeqCst);
        }
        let mut dir = self.directory.lock();
        let idx = self.shard_index(&study.name);
        let _ = self.with_shard_mut(idx, |image| {
            let old_display = image.studies.get(&study.name).map(|e| e.study.display_name.clone());
            let img = Arc::make_mut(image);
            match img.studies.get_mut(&study.name) {
                Some(e) => Arc::make_mut(e).study = Arc::new(study.clone()),
                None => {
                    img.studies
                        .insert(study.name.clone(), Arc::new(StudyImage::new(study.clone())));
                }
            }
            match old_display {
                Some(old) if old != study.display_name => {
                    Self::remap_display(&mut dir, &old, &study.display_name, &study.name);
                }
                _ => {
                    if !study.display_name.is_empty() {
                        dir.entry(study.display_name.clone())
                            .or_insert_with(|| study.name.clone());
                    }
                }
            }
            Ok(())
        });
    }

    /// Reserve the next `studies/{n}` resource name without inserting
    /// anything. [`super::wal::WalDatastore`] assigns names *before*
    /// committing so every record of a study — including its create —
    /// routes to the same commit lane (lane order is what makes per-study
    /// replay order hold; see the WAL module docs).
    pub(crate) fn reserve_study_name(&self) -> String {
        format!("studies/{}", self.next_study.fetch_add(1, Ordering::SeqCst))
    }

    /// Reserve the next `operations/{n}` resource name (see
    /// [`Self::reserve_study_name`]).
    pub(crate) fn reserve_operation_name(&self) -> String {
        format!("operations/{}", self.next_op.fetch_add(1, Ordering::SeqCst))
    }

    /// One shard's current immutable image, or `None` in baseline mode.
    /// This is the CoW compactor's entire snapshot step: one atomic
    /// load, zero shard locks, and the returned image is a consistent
    /// point-in-time capture of the whole shard (studies, trials, and
    /// pending operations together).
    pub(crate) fn shard_image(&self, idx: usize) -> Option<Arc<ShardImage>> {
        self.images.as_ref().map(|cells| {
            self.metrics.record_snapshot_load();
            cells[idx].load(&self.metrics)
        })
    }

    /// Clone one shard's study rows and pending operations (baseline
    /// compaction path; in CoW mode this reads the published image, but
    /// the compactor prefers [`Self::shard_image`] and skips the clone).
    /// Trial tables are streamed separately in keyed pages — see
    /// [`ShardSnapshot`].
    pub(crate) fn snapshot_shard(&self, idx: usize) -> ShardSnapshot {
        let image = self.read_shard(idx);
        ShardSnapshot {
            studies: image.studies.values().map(|e| (*e.study).clone()).collect(),
            pending_ops: image
                .operations
                .values()
                .filter(|o| !o.done)
                .map(|o| (**o).clone())
                .collect(),
        }
    }

    /// Move a directory mapping from `old` to `new` for study `name`.
    fn remap_display(dir: &mut HashMap<String, String>, old: &str, new: &str, name: &str) {
        if !old.is_empty() {
            if let Some(owner) = dir.get(old) {
                if owner == name {
                    dir.remove(old);
                }
            }
        }
        if !new.is_empty() {
            dir.insert(new.to_string(), name.to_string());
        }
    }

    pub(crate) fn apply_put_trial(&self, study: &str, trial: TrialProto) -> Result<(), DsError> {
        self.with_shard_mut(self.shard_index(study), |image| {
            let si = Self::study_mut(image, study)?;
            si.next_trial_id = si.next_trial_id.max(trial.id + 1);
            si.put_trial(trial);
            Ok(())
        })
    }

    pub(crate) fn apply_put_operation(&self, op: OperationProto) {
        if let Some(n) = op.name.strip_prefix("operations/").and_then(|s| s.parse::<u64>().ok()) {
            self.next_op.fetch_max(n + 1, Ordering::SeqCst);
        }
        let _ = self.with_shard_mut(self.shard_index(&op.name), |image| {
            Arc::make_mut(image).operations.insert(op.name.clone(), Arc::new(op));
            Ok(())
        });
    }

    pub(crate) fn apply_delete_study(&self, name: &str) {
        let mut dir = self.directory.lock();
        let _ = self.with_shard_mut(self.shard_index(name), |image| {
            let Some(entry) = image.studies.get(name) else {
                return Ok(()); // replay tolerates deletes of absent rows
            };
            let display = entry.study.display_name.clone();
            Arc::make_mut(image).studies.remove(name);
            Self::remap_display(&mut dir, &display, "", name);
            Ok(())
        });
    }

    pub(crate) fn apply_delete_trial(&self, study: &str, id: u64) {
        let _ = self.with_shard_mut(self.shard_index(study), |image| {
            let present = image.studies.get(study).is_some_and(|e| e.get_trial(id).is_some());
            if present {
                Self::study_mut(image, study)?.delete_trial(id);
            }
            Ok(())
        });
    }
}

impl Datastore for InMemoryDatastore {
    fn create_study(&self, mut study: StudyProto) -> Result<StudyProto, DsError> {
        if study.name.is_empty() {
            let id = self.next_study.fetch_add(1, Ordering::SeqCst);
            study.name = format!("studies/{id}");
        }
        // Directory is held across the shard insert so a concurrent
        // create with the same display name cannot slip between the
        // uniqueness check and the reservation. The directory hit is the
        // fast path; the cross-shard scan is authoritative because
        // update_study display renames can leave aliases the unique-key
        // directory no longer tracks. Creates are rare — the scan reads
        // shards one at a time (dir -> shard order; snapshot loads in
        // CoW mode) and never touches the trial hot path. A racing
        // create publishes its image before releasing the directory, so
        // the snapshot scan here cannot miss it.
        let mut dir = self.directory.lock();
        if !study.display_name.is_empty() {
            if dir.contains_key(&study.display_name) {
                return Err(DsError::StudyExists(study.display_name));
            }
            if self.any_study_with_display(&study.display_name) {
                return Err(DsError::StudyExists(study.display_name));
            }
        }
        self.with_shard_mut(self.shard_index(&study.name), |image| {
            if image.studies.contains_key(&study.name) {
                return Err(DsError::StudyExists(study.name.clone()));
            }
            Arc::make_mut(image)
                .studies
                .insert(study.name.clone(), Arc::new(StudyImage::new(study.clone())));
            Ok(())
        })?;
        if !study.display_name.is_empty() {
            dir.insert(study.display_name.clone(), study.name.clone());
        }
        Ok(study)
    }

    fn get_study(&self, name: &str) -> Result<StudyProto, DsError> {
        let image = self.read_shard(self.shard_index(name));
        image
            .studies
            .get(name)
            .map(|e| (*e.study).clone())
            .ok_or_else(|| DsError::StudyNotFound(name.to_string()))
    }

    fn lookup_study(&self, display_name: &str) -> Result<StudyProto, DsError> {
        let hit = self.directory.lock().get(display_name).cloned();
        if let Some(name) = hit {
            if let Ok(study) = self.get_study(&name) {
                return Ok(study);
            }
        }
        // Fallback scan (directory misses can only come from duplicate
        // display names introduced via update_study).
        for idx in 0..self.shards.len() {
            let image = self.read_shard(idx);
            if let Some(e) = image
                .studies
                .values()
                .find(|e| e.study.display_name == display_name)
            {
                return Ok((*e.study).clone());
            }
        }
        Err(DsError::StudyNotFound(display_name.to_string()))
    }

    fn list_studies(&self) -> Result<Vec<StudyProto>, DsError> {
        let mut studies: Vec<StudyProto> = Vec::new();
        for idx in 0..self.shards.len() {
            let image = self.read_shard(idx);
            studies.extend(image.studies.values().map(|e| (*e.study).clone()));
        }
        studies.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(studies)
    }

    /// Shard-aware pagination. The token is `"{shard}:{last_study_name}"`
    /// — resume in `shard` after `last_study_name` (names sorted within a
    /// shard, shards visited in index order). Unlike `list_studies`, only
    /// the page's studies are cloned and shards past the fill point are
    /// never read, so a page over a large store costs O(page + one
    /// shard's keys) instead of O(all studies). The keyed cursor is what
    /// makes pagination churn-stable: rows present when the walk started
    /// are each seen exactly once even as new rows land between pages.
    fn list_studies_page(&self, page_size: usize, page_token: &str) -> Result<StudyPage, DsError> {
        let bad = || DsError::Invalid(format!("malformed page token {page_token:?}"));
        let (mut shard, mut after): (usize, Option<String>) = if page_token.is_empty() {
            (0, None)
        } else {
            let (s, name) = page_token.split_once(':').ok_or_else(bad)?;
            let idx: usize = s.parse().map_err(|_| bad())?;
            if idx >= self.shards.len() {
                return Err(bad());
            }
            (idx, Some(name.to_string()))
        };
        let cap = if page_size == 0 { usize::MAX } else { page_size };
        let mut out: Vec<StudyProto> = Vec::new();
        // Position of the last emitted study; becomes the next token when
        // the page fills with studies still left to visit.
        let mut last: Option<(usize, String)> = None;
        while shard < self.shards.len() {
            let image = self.read_shard(shard);
            let mut names: Vec<&String> = image.studies.keys().collect();
            names.sort();
            for name in names {
                if let Some(a) = &after {
                    if name.as_str() <= a.as_str() {
                        continue;
                    }
                }
                if out.len() == cap {
                    // lint: allow(no-unwrap) — cap >= 1, so something was emitted
                    let (s, n) = last.expect("cap >= 1, so something was emitted");
                    return Ok(StudyPage {
                        studies: out,
                        next_page_token: format!("{s}:{n}"),
                    });
                }
                out.push((*image.studies[name].study).clone());
                last = Some((shard, name.clone()));
            }
            after = None;
            shard += 1;
        }
        Ok(StudyPage {
            studies: out,
            next_page_token: String::new(),
        })
    }

    fn update_study(&self, study: StudyProto) -> Result<(), DsError> {
        let mut dir = self.directory.lock();
        self.with_shard_mut(self.shard_index(&study.name), |image| {
            let Some(entry) = image.studies.get(&study.name) else {
                return Err(DsError::StudyNotFound(study.name.clone()));
            };
            let old_display = entry.study.display_name.clone();
            if old_display != study.display_name {
                Self::remap_display(&mut dir, &old_display, &study.display_name, &study.name);
            }
            let si = Self::study_mut(image, &study.name)?;
            si.study = Arc::new(study);
            Ok(())
        })
    }

    fn delete_study(&self, name: &str) -> Result<(), DsError> {
        let mut dir = self.directory.lock();
        self.with_shard_mut(self.shard_index(name), |image| {
            let Some(entry) = image.studies.get(name) else {
                return Err(DsError::StudyNotFound(name.to_string()));
            };
            let display = entry.study.display_name.clone();
            Arc::make_mut(image).studies.remove(name);
            Self::remap_display(&mut dir, &display, "", name);
            Ok(())
        })
    }

    fn create_trial(&self, study: &str, mut trial: TrialProto) -> Result<TrialProto, DsError> {
        self.with_shard_mut(self.shard_index(study), |image| {
            let si = Self::study_mut(image, study)?;
            trial.id = si.next_trial_id;
            si.next_trial_id += 1;
            si.put_trial(trial.clone());
            Ok(trial)
        })
    }

    fn get_trial(&self, study: &str, id: u64) -> Result<TrialProto, DsError> {
        let image = self.read_shard(self.shard_index(study));
        image
            .studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?
            .get_trial(id)
            .cloned()
            .ok_or_else(|| DsError::TrialNotFound(study.to_string(), id))
    }

    /// Keyed pagination over the study's chunked trial storage: a range
    /// scan from the token's id clones only the requested page, not the
    /// whole study.
    fn list_trials_page(
        &self,
        study: &str,
        page_size: usize,
        page_token: &str,
    ) -> Result<TrialPage, DsError> {
        let after = crate::datastore::parse_trial_token(page_token)?;
        let cap = if page_size == 0 { usize::MAX } else { page_size };
        let image = self.read_shard(self.shard_index(study));
        let entry = image
            .studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        let mut trials: Vec<TrialProto> = Vec::with_capacity(cap.min(entry.trial_count));
        let mut more = false;
        if after < u64::MAX {
            entry.for_each_in_range(after + 1, u64::MAX, &mut |t| {
                if trials.len() == cap {
                    more = true;
                    return false;
                }
                trials.push(t.clone());
                true
            });
        }
        let next_page_token = if more {
            trials.last().map(|t| t.id.to_string()).unwrap_or_default()
        } else {
            String::new()
        };
        Ok(TrialPage {
            trials,
            next_page_token,
        })
    }

    fn list_trials(&self, study: &str) -> Result<Vec<TrialProto>, DsError> {
        let image = self.read_shard(self.shard_index(study));
        let entry = image
            .studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        let mut out: Vec<TrialProto> = Vec::with_capacity(entry.trial_count);
        out.extend(entry.trials().cloned());
        Ok(out)
    }

    fn query_trials(
        &self,
        study: &str,
        filter: &super::query::TrialFilter,
    ) -> Result<Vec<TrialProto>, DsError> {
        let image = self.read_shard(self.shard_index(study));
        let entry = image
            .studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        // Range-scan from min_id so incremental reads touch only new rows,
        // and clone only matching trials (the §6.3 database-work saving).
        let (lo, hi) = filter.id_bounds();
        let mut kept: Vec<TrialProto> = Vec::new();
        entry.for_each_in_range(lo, hi, &mut |t| {
            if filter.matches(t) {
                kept.push(t.clone());
            }
            true
        });
        if let Some(limit) = filter.limit {
            if kept.len() > limit {
                kept = kept.split_off(kept.len() - limit);
            }
        }
        Ok(kept)
    }

    fn update_trial(&self, study: &str, trial: TrialProto) -> Result<(), DsError> {
        self.with_shard_mut(self.shard_index(study), |image| {
            {
                let entry = image
                    .studies
                    .get(study)
                    .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
                if entry.get_trial(trial.id).is_none() {
                    return Err(DsError::TrialNotFound(study.to_string(), trial.id));
                }
            }
            Self::study_mut(image, study)?.put_trial(trial);
            Ok(())
        })
    }

    fn delete_trial(&self, study: &str, id: u64) -> Result<(), DsError> {
        self.with_shard_mut(self.shard_index(study), |image| {
            {
                let entry = image
                    .studies
                    .get(study)
                    .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
                if entry.get_trial(id).is_none() {
                    return Err(DsError::TrialNotFound(study.to_string(), id));
                }
            }
            Self::study_mut(image, study)?.delete_trial(id);
            Ok(())
        })
    }

    fn mutate_trial(
        &self,
        study: &str,
        id: u64,
        f: &mut dyn FnMut(&mut TrialProto) -> Result<(), DsError>,
    ) -> Result<TrialProto, DsError> {
        self.with_shard_mut(self.shard_index(study), |image| {
            {
                let entry = image
                    .studies
                    .get(study)
                    .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
                if entry.get_trial(id).is_none() {
                    return Err(DsError::TrialNotFound(study.to_string(), id));
                }
            }
            let si = Self::study_mut(image, study)?;
            match si.get_trial_mut(id) {
                Some(trial) => {
                    f(trial)?;
                    Ok(trial.clone())
                }
                None => Err(DsError::TrialNotFound(study.to_string(), id)), // unreachable: checked above
            }
        })
    }

    fn create_operation(&self, mut op: OperationProto) -> Result<OperationProto, DsError> {
        if op.name.is_empty() {
            let id = self.next_op.fetch_add(1, Ordering::SeqCst);
            op.name = format!("operations/{id}");
        }
        self.with_shard_mut(self.shard_index(&op.name), |image| {
            Arc::make_mut(image)
                .operations
                .insert(op.name.clone(), Arc::new(op.clone()));
            Ok(op)
        })
    }

    fn get_operation(&self, name: &str) -> Result<OperationProto, DsError> {
        let image = self.read_shard(self.shard_index(name));
        image
            .operations
            .get(name)
            .map(|o| (**o).clone())
            .ok_or_else(|| DsError::OperationNotFound(name.to_string()))
    }

    fn update_operation(&self, op: OperationProto) -> Result<(), DsError> {
        self.with_shard_mut(self.shard_index(&op.name), |image| {
            if !image.operations.contains_key(&op.name) {
                return Err(DsError::OperationNotFound(op.name.clone()));
            }
            Arc::make_mut(image).operations.insert(op.name.clone(), Arc::new(op));
            Ok(())
        })
    }

    fn pending_operations(&self) -> Result<Vec<OperationProto>, DsError> {
        let mut ops: Vec<OperationProto> = Vec::new();
        for idx in 0..self.shards.len() {
            let image = self.read_shard(idx);
            ops.extend(image.operations.values().filter(|o| !o.done).map(|o| (**o).clone()));
        }
        ops.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(ops)
    }

    fn update_metadata(&self, study: &str, updates: &[UnitMetadataUpdate]) -> Result<(), DsError> {
        self.with_shard_mut(self.shard_index(study), |image| {
            if !image.studies.contains_key(study) {
                return Err(DsError::StudyNotFound(study.to_string()));
            }
            let si = Self::study_mut(image, study)?;
            for u in updates {
                let Some(item) = &u.item else { continue };
                if u.trial_id == 0 {
                    // Study-level metadata table.
                    let md = &mut Arc::make_mut(&mut si.study).spec.metadata;
                    md.retain(|m| !(m.namespace == item.namespace && m.key == item.key));
                    md.push(item.clone());
                } else {
                    let Some(trial) = si.get_trial_mut(u.trial_id) else {
                        return Err(DsError::TrialNotFound(study.to_string(), u.trial_id));
                    };
                    trial
                        .metadata
                        .retain(|m| !(m.namespace == item.namespace && m.key == item.key));
                    trial.metadata.push(item.clone());
                }
            }
            Ok(())
        })
    }

    fn trial_count(&self, study: &str) -> Result<usize, DsError> {
        let image = self.read_shard(self.shard_index(study));
        image
            .studies
            .get(study)
            .map(|e| e.trial_count)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::messages::MetadataItem;
    use std::sync::Arc;

    fn study(display: &str) -> StudyProto {
        StudyProto {
            display_name: display.to_string(),
            ..Default::default()
        }
    }

    #[test]
    fn study_crud() {
        let ds = InMemoryDatastore::new();
        let s = ds.create_study(study("a")).unwrap();
        assert_eq!(s.name, "studies/1");
        assert_eq!(ds.get_study("studies/1").unwrap().display_name, "a");
        assert_eq!(ds.lookup_study("a").unwrap().name, "studies/1");
        let s2 = ds.create_study(study("b")).unwrap();
        assert_eq!(s2.name, "studies/2");
        assert_eq!(ds.list_studies().unwrap().len(), 2);
        ds.delete_study("studies/1").unwrap();
        assert_eq!(ds.get_study("studies/1"), Err(DsError::StudyNotFound("studies/1".into())));
        assert!(ds.delete_study("studies/1").is_err());
    }

    #[test]
    fn duplicate_display_name_rejected() {
        let ds = InMemoryDatastore::new();
        ds.create_study(study("same")).unwrap();
        assert!(matches!(ds.create_study(study("same")), Err(DsError::StudyExists(_))));
    }

    #[test]
    fn display_rename_aliases_cannot_bypass_uniqueness() {
        let ds = InMemoryDatastore::new();
        let a = ds.create_study(study("d")).unwrap();
        let b = ds.create_study(study("b")).unwrap();
        // Rename B onto A's display name, then away again — this strands
        // the alias in a naive unique-key index.
        let mut b2 = ds.get_study(&b.name).unwrap();
        b2.display_name = "d".into();
        ds.update_study(b2.clone()).unwrap();
        b2.display_name = "x".into();
        ds.update_study(b2).unwrap();
        // A still owns "d": another create must be rejected and lookup
        // must still resolve to A.
        assert!(matches!(ds.create_study(study("d")), Err(DsError::StudyExists(_))));
        assert_eq!(ds.lookup_study("d").unwrap().name, a.name);
    }

    #[test]
    fn deleted_display_name_can_be_reused() {
        let ds = InMemoryDatastore::new();
        let s = ds.create_study(study("re")).unwrap();
        ds.delete_study(&s.name).unwrap();
        let s2 = ds.create_study(study("re")).unwrap();
        assert_ne!(s.name, s2.name);
        assert_eq!(ds.lookup_study("re").unwrap().name, s2.name);
    }

    #[test]
    fn trial_ids_are_sequential_per_study() {
        let ds = InMemoryDatastore::new();
        let s1 = ds.create_study(study("a")).unwrap();
        let s2 = ds.create_study(study("b")).unwrap();
        for expect in 1..=3 {
            let t = ds.create_trial(&s1.name, TrialProto::default()).unwrap();
            assert_eq!(t.id, expect);
        }
        let t = ds.create_trial(&s2.name, TrialProto::default()).unwrap();
        assert_eq!(t.id, 1, "ids are per-study");
        assert_eq!(ds.trial_count(&s1.name).unwrap(), 3);
    }

    #[test]
    fn mutate_trial_atomicity() {
        let ds = Arc::new(InMemoryDatastore::new());
        let s = ds.create_study(study("a")).unwrap();
        ds.create_trial(&s.name, TrialProto::default()).unwrap();
        // 8 threads increment created_ms 100 times each via mutate_trial.
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let ds = Arc::clone(&ds);
                let name = s.name.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        ds.mutate_trial(&name, 1, &mut |t| {
                            t.created_ms += 1;
                            Ok(())
                        })
                        .unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(ds.get_trial(&s.name, 1).unwrap().created_ms, 800);
    }

    #[test]
    fn trial_pagination_walks_every_trial_once() {
        let ds = InMemoryDatastore::new();
        let s = ds
            .create_study(StudyProto { display_name: "page".into(), ..Default::default() })
            .unwrap();
        for _ in 0..25 {
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
        }
        let mut seen: Vec<u64> = Vec::new();
        let mut token = String::new();
        let mut pages = 0;
        loop {
            let page = ds.list_trials_page(&s.name, 10, &token).unwrap();
            assert!(page.trials.len() <= 10);
            seen.extend(page.trials.iter().map(|t| t.id));
            pages += 1;
            if page.next_page_token.is_empty() {
                break;
            }
            token = page.next_page_token;
        }
        assert_eq!(pages, 3); // 10 + 10 + 5
        assert_eq!(seen, (1..=25).collect::<Vec<u64>>());
        // page_size 0 = everything in one page.
        let all = ds.list_trials_page(&s.name, 0, "").unwrap();
        assert_eq!(all.trials.len(), 25);
        assert!(all.next_page_token.is_empty());
        // A malformed token is an error, not a silent restart.
        assert!(ds.list_trials_page(&s.name, 10, "bogus").is_err());
        assert!(ds.list_trials_page("studies/none", 10, "").is_err());
    }

    #[test]
    fn operations() {
        let ds = InMemoryDatastore::new();
        let op = ds.create_operation(OperationProto::default()).unwrap();
        assert_eq!(op.name, "operations/1");
        assert_eq!(ds.pending_operations().unwrap().len(), 1);
        let mut done = op.clone();
        done.done = true;
        ds.update_operation(done).unwrap();
        assert!(ds.pending_operations().unwrap().is_empty());
        assert!(ds.get_operation("operations/1").unwrap().done);
        assert!(ds.get_operation("operations/99").is_err());
    }

    #[test]
    fn metadata_updates_upsert() {
        let ds = InMemoryDatastore::new();
        let s = ds.create_study(study("a")).unwrap();
        ds.create_trial(&s.name, TrialProto::default()).unwrap();
        let item = |v: &[u8]| MetadataItem {
            namespace: "evo".into(),
            key: "pop".into(),
            value: v.to_vec(),
        };
        // Study-level write then overwrite.
        ds.update_metadata(
            &s.name,
            &[UnitMetadataUpdate { trial_id: 0, item: Some(item(b"v1")), new_trial_index: 0 }],
        )
        .unwrap();
        ds.update_metadata(
            &s.name,
            &[UnitMetadataUpdate { trial_id: 0, item: Some(item(b"v2")), new_trial_index: 0 }],
        )
        .unwrap();
        let study = ds.get_study(&s.name).unwrap();
        assert_eq!(study.spec.metadata.len(), 1);
        assert_eq!(study.spec.metadata[0].value, b"v2");
        // Trial-level write.
        ds.update_metadata(
            &s.name,
            &[UnitMetadataUpdate { trial_id: 1, item: Some(item(b"t")), new_trial_index: 0 }],
        )
        .unwrap();
        assert_eq!(ds.get_trial(&s.name, 1).unwrap().metadata[0].value, b"t");
        // Unknown trial errors.
        assert!(ds
            .update_metadata(
                &s.name,
                &[UnitMetadataUpdate { trial_id: 99, item: Some(item(b"x")), new_trial_index: 0 }],
            )
            .is_err());
    }

    #[test]
    fn errors_for_missing_entities() {
        let ds = InMemoryDatastore::new();
        assert!(ds.get_trial("studies/1", 1).is_err());
        assert!(ds.list_trials("nope").is_err());
        assert!(ds.create_trial("nope", TrialProto::default()).is_err());
        assert!(ds.update_trial("nope", TrialProto::default()).is_err());
        let s = ds.create_study(study("a")).unwrap();
        assert!(ds.update_trial(&s.name, TrialProto { id: 5, ..Default::default() }).is_err());
    }

    #[test]
    fn shard_routing_is_stable_and_total() {
        let ds = InMemoryDatastore::new();
        assert_eq!(ds.shard_count(), DEFAULT_SHARD_COUNT);
        for i in 0..200 {
            let name = format!("studies/{i}");
            let a = ds.shard_index(&name);
            let b = ds.shard_index(&name);
            assert_eq!(a, b, "routing must be deterministic");
            assert!(a < ds.shard_count());
        }
    }

    #[test]
    fn studies_land_in_their_computed_shard() {
        let ds = InMemoryDatastore::new();
        let mut names = Vec::new();
        for i in 0..50 {
            names.push(ds.create_study(study(&format!("s{i}"))).unwrap().name);
        }
        for name in &names {
            let idx = ds.shard_index(name);
            assert!(
                ds.studies_in_shard(idx).contains(name),
                "{name} not in shard {idx}"
            );
        }
        // Union over shards == list_studies.
        let mut union: Vec<String> = (0..ds.shard_count())
            .flat_map(|i| ds.studies_in_shard(i))
            .collect();
        union.sort();
        let mut listed: Vec<String> =
            ds.list_studies().unwrap().into_iter().map(|s| s.name).collect();
        listed.sort();
        assert_eq!(union, listed);
    }

    #[test]
    fn single_shard_store_behaves_identically() {
        let run = |ds: InMemoryDatastore| {
            let s = ds.create_study(study("x")).unwrap();
            for _ in 0..5 {
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
            }
            ds.delete_trial(&s.name, 3).unwrap();
            let ids: Vec<u64> =
                ds.list_trials(&s.name).unwrap().into_iter().map(|t| t.id).collect();
            (s.name, ids)
        };
        assert_eq!(run(InMemoryDatastore::with_shards(1)), run(InMemoryDatastore::new()));
    }

    #[test]
    fn pagination_visits_every_study_exactly_once() {
        for shards in [1usize, 16] {
            let ds = InMemoryDatastore::with_shards(shards);
            let mut expected: Vec<String> = Vec::new();
            for i in 0..43 {
                expected.push(ds.create_study(study(&format!("p{i}"))).unwrap().name);
            }
            for page_size in [1usize, 7, 43, 100] {
                let mut seen: Vec<String> = Vec::new();
                let mut token = String::new();
                let mut rounds = 0;
                loop {
                    let page = ds.list_studies_page(page_size, &token).unwrap();
                    assert!(page.studies.len() <= page_size);
                    seen.extend(page.studies.iter().map(|s| s.name.clone()));
                    if page.next_page_token.is_empty() {
                        break;
                    }
                    token = page.next_page_token;
                    rounds += 1;
                    assert!(rounds <= 100, "pagination must terminate");
                }
                let mut seen_sorted = seen.clone();
                seen_sorted.sort();
                seen_sorted.dedup();
                assert_eq!(seen.len(), expected.len(), "page_size {page_size}");
                assert_eq!(seen_sorted.len(), expected.len(), "no duplicates");
                let mut want = expected.clone();
                want.sort();
                assert_eq!(seen_sorted, want);
            }
        }
    }

    #[test]
    fn pagination_unlimited_page_matches_list() {
        let ds = InMemoryDatastore::new();
        for i in 0..10 {
            ds.create_study(study(&format!("u{i}"))).unwrap();
        }
        let page = ds.list_studies_page(0, "").unwrap();
        assert_eq!(page.studies.len(), 10);
        assert!(page.next_page_token.is_empty());
    }

    #[test]
    fn pagination_rejects_malformed_tokens() {
        let ds = InMemoryDatastore::new();
        ds.create_study(study("t")).unwrap();
        assert!(matches!(
            ds.list_studies_page(5, "no-colon"),
            Err(DsError::Invalid(_))
        ));
        assert!(matches!(
            ds.list_studies_page(5, "abc:studies/1"),
            Err(DsError::Invalid(_))
        ));
        assert!(matches!(
            ds.list_studies_page(5, "999:studies/1"),
            Err(DsError::Invalid(_))
        ));
    }

    #[test]
    fn concurrent_study_creation_never_loses_or_duplicates() {
        let ds = Arc::new(InMemoryDatastore::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let ds = Arc::clone(&ds);
                std::thread::spawn(move || {
                    for i in 0..50 {
                        ds.create_study(study(&format!("t{t}-{i}"))).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let studies = ds.list_studies().unwrap();
        assert_eq!(studies.len(), 400);
        let names: std::collections::HashSet<_> =
            studies.iter().map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), 400, "resource names must be unique");
    }

    // --- Copy-on-write specifics ----------------------------------------

    /// Full CRUD workload run against both read-path modes must produce
    /// byte-identical results.
    #[test]
    fn cow_and_baseline_modes_behave_identically() {
        let run = |cow: bool| {
            let ds = InMemoryDatastore::with_shards_cow(4, cow);
            let s = ds.create_study(study("mode")).unwrap();
            for i in 0..150u64 {
                let t = ds.create_trial(&s.name, TrialProto::default()).unwrap();
                assert_eq!(t.id, i + 1);
            }
            ds.delete_trial(&s.name, 3).unwrap();
            ds.delete_trial(&s.name, 64).unwrap();
            ds.mutate_trial(&s.name, 10, &mut |t| {
                t.created_ms = 77;
                Ok(())
            })
            .unwrap();
            ds.update_trial(&s.name, TrialProto { id: 20, created_ms: 5, ..Default::default() })
                .unwrap();
            let ids: Vec<u64> =
                ds.list_trials(&s.name).unwrap().into_iter().map(|t| t.id).collect();
            let t10 = ds.get_trial(&s.name, 10).unwrap().created_ms;
            let t20 = ds.get_trial(&s.name, 20).unwrap().created_ms;
            (ids, t10, t20, ds.trial_count(&s.name).unwrap())
        };
        assert_eq!(run(true), run(false));
    }

    /// The chunked trial table must keep its invariants under sparse
    /// replayed ids, out-of-order inserts, and min-key deletes.
    #[test]
    fn chunked_storage_handles_sparse_ids_and_deletes() {
        let ds = InMemoryDatastore::with_shards_cow(1, true);
        let s = ds.create_study(study("sparse")).unwrap();
        // Replay-style sparse inserts, descending then interleaved:
        // exercises the re-key (new minimum) and split paths.
        let ids: Vec<u64> = (1..=200).rev().map(|i| i * 3).collect();
        for id in &ids {
            ds.apply_put_trial(&s.name, TrialProto { id: *id, ..Default::default() })
                .unwrap();
        }
        assert_eq!(ds.trial_count(&s.name).unwrap(), 200);
        let listed: Vec<u64> = ds.list_trials(&s.name).unwrap().iter().map(|t| t.id).collect();
        let mut want: Vec<u64> = ids.clone();
        want.sort_unstable();
        assert_eq!(listed, want, "in-order iteration over chunks");
        // Overwrite is an upsert, not a duplicate.
        ds.apply_put_trial(&s.name, TrialProto { id: 300, created_ms: 9, ..Default::default() })
            .unwrap();
        assert_eq!(ds.trial_count(&s.name).unwrap(), 200);
        assert_eq!(ds.get_trial(&s.name, 300).unwrap().created_ms, 9);
        // Delete minimums (re-keys chunks) and a run in the middle.
        for id in [3u64, 6, 9, 300, 303] {
            ds.delete_trial(&s.name, id).unwrap();
        }
        assert_eq!(ds.trial_count(&s.name).unwrap(), 195);
        assert!(ds.get_trial(&s.name, 3).is_err());
        assert_eq!(ds.get_trial(&s.name, 12).unwrap().id, 12);
        // Next id continues after the max replayed id.
        let t = ds.create_trial(&s.name, TrialProto::default()).unwrap();
        assert_eq!(t.id, 601);
        // Range reads line up with the full listing.
        let page = ds.list_trials_page(&s.name, 50, "100").unwrap();
        assert_eq!(page.trials.first().map(|t| t.id), Some(102));
        assert_eq!(page.trials.len(), 50);
    }

    /// A snapshot loaded before a write must keep showing the old state:
    /// published images are immutable.
    #[test]
    fn snapshot_is_isolated_from_later_writes() {
        let ds = InMemoryDatastore::with_shards_cow(1, true);
        let s = ds.create_study(study("iso")).unwrap();
        for _ in 0..10 {
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
        }
        let before = ds.shard_image(0).expect("cow mode");
        ds.create_trial(&s.name, TrialProto::default()).unwrap();
        ds.delete_trial(&s.name, 1).unwrap();
        let count_before: usize =
            before.studies().map(|e| e.trials().count()).sum();
        assert_eq!(count_before, 10, "old image unchanged");
        let after = ds.shard_image(0).expect("cow mode");
        let count_after: usize = after.studies().map(|e| e.trials().count()).sum();
        assert_eq!(count_after, 10, "11 created - 1 deleted");
        assert!(after.studies().any(|e| e.trials().all(|t| t.id != 1)));
    }

    /// With no readers pinned, every publish reclaims the graveyard:
    /// the retired-images gauge returns to zero.
    #[test]
    fn retired_images_are_reclaimed_between_writes() {
        let ds = InMemoryDatastore::with_shards_cow(1, true);
        let m = ds.metrics();
        let s = ds.create_study(study("gc")).unwrap();
        for _ in 0..10 {
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
        }
        assert!(m.snapshot_publishes() >= 11, "one publish per write");
        assert_eq!(m.retired_images(), 0, "no pinned readers -> graveyard drains");
        assert_eq!(m.pinned_readers(), 0);
    }

    /// Mode observability: CoW reads count as snapshot loads, baseline
    /// reads as locked reads — the C-DS-SNAP zero-lock verdict's signal.
    #[test]
    fn read_path_metrics_distinguish_modes() {
        let cow = InMemoryDatastore::with_shards_cow(2, true);
        let s = cow.create_study(study("m1")).unwrap();
        cow.create_trial(&s.name, TrialProto::default()).unwrap();
        cow.list_trials(&s.name).unwrap();
        cow.get_trial(&s.name, 1).unwrap();
        assert!(cow.metrics().snapshot_loads() > 0);
        assert_eq!(cow.metrics().locked_reads(), 0);
        assert!(cow.metrics().shard_writes() >= 2);

        let base = InMemoryDatastore::with_shards_cow(2, false);
        let s = base.create_study(study("m1")).unwrap();
        base.create_trial(&s.name, TrialProto::default()).unwrap();
        base.list_trials(&s.name).unwrap();
        assert!(base.metrics().locked_reads() > 0);
        assert_eq!(base.metrics().snapshot_loads(), 0);
        assert_eq!(base.metrics().snapshot_publishes(), 0);
    }

    /// Trial-cursor pagination must neither skip nor duplicate the rows
    /// that existed when the walk began, even as a writer inserts
    /// between pages — in both modes.
    #[test]
    fn trial_pagination_is_stable_under_churn() {
        for cow in [true, false] {
            let ds = InMemoryDatastore::with_shards_cow(4, cow);
            let s = ds.create_study(study("churn-t")).unwrap();
            for _ in 0..40 {
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
            }
            let mut seen: Vec<u64> = Vec::new();
            let mut token = String::new();
            loop {
                let page = ds.list_trials_page(&s.name, 7, &token).unwrap();
                seen.extend(page.trials.iter().map(|t| t.id));
                // Churn: new rows land while the cursor is parked.
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
                if page.next_page_token.is_empty() {
                    break;
                }
                token = page.next_page_token;
            }
            let mut dedup = seen.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), seen.len(), "no duplicates (cow={cow})");
            let original: Vec<u64> = (1..=40).collect();
            assert!(
                original.iter().all(|id| seen.contains(id)),
                "no skipped originals (cow={cow}): {seen:?}"
            );
        }
    }

    /// Study-cursor pagination under churn: same guarantee as above for
    /// `list_studies_page`, across shards.
    #[test]
    fn study_pagination_is_stable_under_churn() {
        for cow in [true, false] {
            let ds = InMemoryDatastore::with_shards_cow(8, cow);
            let mut original: Vec<String> = Vec::new();
            for i in 0..30 {
                original.push(ds.create_study(study(&format!("c{i}"))).unwrap().name);
            }
            let mut seen: Vec<String> = Vec::new();
            let mut token = String::new();
            let mut churn = 100;
            loop {
                let page = ds.list_studies_page(4, &token).unwrap();
                seen.extend(page.studies.iter().map(|s| s.name.clone()));
                // Churn: a new study lands between every page.
                churn += 1;
                ds.create_study(study(&format!("c{churn}"))).unwrap();
                if page.next_page_token.is_empty() {
                    break;
                }
                token = page.next_page_token;
            }
            let mut dedup = seen.clone();
            dedup.sort();
            dedup.dedup();
            assert_eq!(dedup.len(), seen.len(), "no duplicates (cow={cow})");
            assert!(
                original.iter().all(|n| seen.contains(n)),
                "no skipped originals (cow={cow})"
            );
        }
    }

    /// Memory-safety smoke for the pin/publish protocol: hammer loads
    /// and publishes from many threads (run under lockdep + sanitizer CI
    /// legs).
    #[test]
    fn concurrent_snapshot_reads_under_writes() {
        let ds = Arc::new(InMemoryDatastore::with_shards_cow(2, true));
        let s = ds.create_study(study("hammer")).unwrap();
        ds.create_trial(&s.name, TrialProto::default()).unwrap();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let ds = Arc::clone(&ds);
                let stop = Arc::clone(&stop);
                let name = s.name.clone();
                std::thread::spawn(move || {
                    let mut last = 0usize;
                    while !stop.load(Ordering::Relaxed) {
                        let n = ds.list_trials(&name).unwrap().len();
                        assert!(n >= last, "trial count is monotone under create-only churn");
                        last = n;
                        ds.get_trial(&name, 1).unwrap();
                    }
                })
            })
            .collect();
        for _ in 0..500 {
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(ds.trial_count(&s.name).unwrap(), 501);
        assert_eq!(ds.metrics().locked_reads(), 0, "no read path took a shard lock");
    }
}
