//! In-memory datastore: the default backing store, also embedded inside
//! [`super::wal::WalDatastore`] as the materialized state.
//!
//! # Sharding
//!
//! State is partitioned into [`DEFAULT_SHARD_COUNT`] independent shards
//! (configurable via [`InMemoryDatastore::with_shards`]), each behind its
//! own `RwLock`. A study is routed to a shard by a stable FNV-1a hash of
//! its resource name, so all trial operations for one study serialize on
//! one shard lock while different studies proceed in parallel — the
//! paper's "multiple parallel evaluations" load pattern (§3.1) no longer
//! funnels through a single global lock. Operations are routed the same
//! way by operation name.
//!
//! Cross-shard concerns:
//! * `list_studies` / `pending_operations` take shard locks one at a time
//!   (never two at once — no lock-order hazard) and merge.
//! * display-name lookup and uniqueness go through a small `directory`
//!   mutex (display name → study name). Lock order is always
//!   directory → shard, and the directory lock is never held while
//!   another directory-taking call runs, so the pair cannot deadlock.
//!
//! Both locks are registered with the crate lock hierarchy
//! ([`crate::util::sync::classes`]: `datastore.directory` before
//! `datastore.shard`), so the order above is machine-checked under
//! lockdep (debug builds / `OSSVIZIER_LOCKDEP=1`) — see
//! `rust/docs/INVARIANTS.md`.

use super::{Datastore, DsError, StudyPage, TrialPage};
use crate::wire::messages::{OperationProto, StudyProto, TrialProto, UnitMetadataUpdate};
use std::collections::{BTreeMap, HashMap};
use crate::util::sync::{classes, Mutex, RwLock};
use std::sync::atomic::{AtomicU64, Ordering};

/// Default number of shards (a power of two comfortably above typical
/// worker-thread counts, so independent studies rarely collide).
pub const DEFAULT_SHARD_COUNT: usize = 16;

/// Stable (process-independent) FNV-1a hash used for shard routing, so
/// tests and tooling can predict placement.
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

#[derive(Debug, Default)]
struct StudyEntry {
    study: StudyProto,
    trials: BTreeMap<u64, TrialProto>,
    next_trial_id: u64,
}

#[derive(Debug, Default)]
struct Shard {
    studies: HashMap<String, StudyEntry>,
    operations: HashMap<String, OperationProto>,
}

/// One shard's top-level contents as captured by
/// [`InMemoryDatastore::snapshot_shard`]. Trials are deliberately NOT
/// cloned here: the WAL compactor streams them per study in keyed pages
/// ([`Datastore::list_trials_page`]) so no single lock acquisition holds
/// a shard's writers for longer than one page clone.
#[derive(Debug, Default)]
pub(crate) struct ShardSnapshot {
    /// The shard's study rows (specs only, no trials).
    pub studies: Vec<StudyProto>,
    /// Operations with `done == false` resident in this shard.
    pub pending_ops: Vec<OperationProto>,
}

/// Thread-safe sharded in-memory store.
#[derive(Debug)]
pub struct InMemoryDatastore {
    shards: Vec<RwLock<Shard>>,
    /// display name -> study name (fast `lookup_study`, uniqueness check).
    directory: Mutex<HashMap<String, String>>,
    next_study: AtomicU64,
    next_op: AtomicU64,
}

impl Default for InMemoryDatastore {
    fn default() -> Self {
        Self::new()
    }
}

impl InMemoryDatastore {
    pub fn new() -> Self {
        Self::with_shards(DEFAULT_SHARD_COUNT)
    }

    /// Store with an explicit shard count (>= 1). `with_shards(1)` is the
    /// single-lock layout, kept as a benchmark baseline.
    pub fn with_shards(n: usize) -> Self {
        let n = n.max(1);
        Self {
            shards: (0..n)
                .map(|_| RwLock::new(&classes::DS_SHARD, Shard::default()))
                .collect(),
            directory: Mutex::new(&classes::DS_DIRECTORY, HashMap::new()),
            next_study: AtomicU64::new(1),
            next_op: AtomicU64::new(1),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard a study (or operation) name routes to. Deterministic:
    /// the same name always maps to the same shard for a given count.
    pub fn shard_index(&self, name: &str) -> usize {
        (fnv1a(name) % self.shards.len() as u64) as usize
    }

    /// Names of the studies currently resident in shard `idx` (unsorted).
    /// Introspection for tests and tooling.
    pub fn studies_in_shard(&self, idx: usize) -> Vec<String> {
        self.shards[idx].read().studies.keys().cloned().collect()
    }

    fn shard_of(&self, name: &str) -> &RwLock<Shard> {
        &self.shards[self.shard_index(name)]
    }

    /// Apply a study proto without assigning a fresh name (used by WAL
    /// replay). Overwrites silently and keeps id counters monotone.
    pub(crate) fn apply_put_study(&self, study: StudyProto) {
        if let Some(n) = study.name.strip_prefix("studies/").and_then(|s| s.parse::<u64>().ok()) {
            self.next_study.fetch_max(n + 1, Ordering::SeqCst);
        }
        let mut dir = self.directory.lock();
        let mut sh = self.shard_of(&study.name).write();
        let entry = sh.studies.entry(study.name.clone()).or_default();
        if entry.study.display_name != study.display_name {
            Self::remap_display(&mut dir, &entry.study.display_name, &study.display_name, &study.name);
        } else if !study.display_name.is_empty() {
            dir.entry(study.display_name.clone()).or_insert_with(|| study.name.clone());
        }
        entry.study = study;
    }

    /// Reserve the next `studies/{n}` resource name without inserting
    /// anything. [`super::wal::WalDatastore`] assigns names *before*
    /// committing so every record of a study — including its create —
    /// routes to the same commit lane (lane order is what makes per-study
    /// replay order hold; see the WAL module docs).
    pub(crate) fn reserve_study_name(&self) -> String {
        format!("studies/{}", self.next_study.fetch_add(1, Ordering::SeqCst))
    }

    /// Reserve the next `operations/{n}` resource name (see
    /// [`Self::reserve_study_name`]).
    pub(crate) fn reserve_operation_name(&self) -> String {
        format!("operations/{}", self.next_op.fetch_add(1, Ordering::SeqCst))
    }

    /// Clone one shard's study rows and pending operations under a
    /// single (short) read-lock acquisition: the WAL compactor's
    /// snapshot iteration. Trial tables are streamed separately in
    /// keyed pages — see [`ShardSnapshot`] — so the compactor never
    /// holds a shard's writers for longer than one page clone; replay
    /// correctness needs only per-record (upsert) consistency, not an
    /// atomic shard image. Done operations are excluded: compaction is
    /// where the log sheds them.
    pub(crate) fn snapshot_shard(&self, idx: usize) -> ShardSnapshot {
        let sh = self.shards[idx].read();
        ShardSnapshot {
            studies: sh.studies.values().map(|e| e.study.clone()).collect(),
            pending_ops: sh.operations.values().filter(|o| !o.done).cloned().collect(),
        }
    }

    /// Move a directory mapping from `old` to `new` for study `name`.
    fn remap_display(
        dir: &mut HashMap<String, String>,
        old: &str,
        new: &str,
        name: &str,
    ) {
        if !old.is_empty() {
            if let Some(owner) = dir.get(old) {
                if owner == name {
                    dir.remove(old);
                }
            }
        }
        if !new.is_empty() {
            dir.insert(new.to_string(), name.to_string());
        }
    }

    pub(crate) fn apply_put_trial(&self, study: &str, trial: TrialProto) -> Result<(), DsError> {
        let mut sh = self.shard_of(study).write();
        let entry = sh
            .studies
            .get_mut(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        entry.next_trial_id = entry.next_trial_id.max(trial.id + 1);
        entry.trials.insert(trial.id, trial);
        Ok(())
    }

    pub(crate) fn apply_put_operation(&self, op: OperationProto) {
        if let Some(n) = op.name.strip_prefix("operations/").and_then(|s| s.parse::<u64>().ok()) {
            self.next_op.fetch_max(n + 1, Ordering::SeqCst);
        }
        let mut sh = self.shard_of(&op.name).write();
        sh.operations.insert(op.name.clone(), op);
    }

    pub(crate) fn apply_delete_study(&self, name: &str) {
        let mut dir = self.directory.lock();
        let mut sh = self.shard_of(name).write();
        if let Some(entry) = sh.studies.remove(name) {
            Self::remap_display(&mut dir, &entry.study.display_name, "", name);
        }
    }

    pub(crate) fn apply_delete_trial(&self, study: &str, id: u64) {
        if let Some(e) = self.shard_of(study).write().studies.get_mut(study) {
            e.trials.remove(&id);
        }
    }
}

impl Datastore for InMemoryDatastore {
    fn create_study(&self, mut study: StudyProto) -> Result<StudyProto, DsError> {
        if study.name.is_empty() {
            let id = self.next_study.fetch_add(1, Ordering::SeqCst);
            study.name = format!("studies/{id}");
        }
        // Directory is held across the shard insert so a concurrent
        // create with the same display name cannot slip between the
        // uniqueness check and the reservation. The directory hit is the
        // fast path; the cross-shard scan is authoritative because
        // update_study display renames can leave aliases the unique-key
        // directory no longer tracks. Creates are rare — the scan takes
        // shard read locks one at a time (dir -> shard order) and never
        // touches the trial hot path.
        let mut dir = self.directory.lock();
        if !study.display_name.is_empty() {
            if dir.contains_key(&study.display_name) {
                return Err(DsError::StudyExists(study.display_name));
            }
            for sh in &self.shards {
                let sh = sh.read();
                if sh.studies.values().any(|e| e.study.display_name == study.display_name) {
                    return Err(DsError::StudyExists(study.display_name));
                }
            }
        }
        let mut sh = self.shard_of(&study.name).write();
        if sh.studies.contains_key(&study.name) {
            return Err(DsError::StudyExists(study.name));
        }
        if !study.display_name.is_empty() {
            dir.insert(study.display_name.clone(), study.name.clone());
        }
        sh.studies.insert(
            study.name.clone(),
            StudyEntry {
                study: study.clone(),
                trials: BTreeMap::new(),
                next_trial_id: 1,
            },
        );
        Ok(study)
    }

    fn get_study(&self, name: &str) -> Result<StudyProto, DsError> {
        self.shard_of(name)
            .read()
            .studies
            .get(name)
            .map(|e| e.study.clone())
            .ok_or_else(|| DsError::StudyNotFound(name.to_string()))
    }

    fn lookup_study(&self, display_name: &str) -> Result<StudyProto, DsError> {
        let hit = self.directory.lock().get(display_name).cloned();
        if let Some(name) = hit {
            if let Ok(study) = self.get_study(&name) {
                return Ok(study);
            }
        }
        // Fallback scan (directory misses can only come from duplicate
        // display names introduced via update_study).
        for sh in &self.shards {
            let sh = sh.read();
            if let Some(e) = sh.studies.values().find(|e| e.study.display_name == display_name) {
                return Ok(e.study.clone());
            }
        }
        Err(DsError::StudyNotFound(display_name.to_string()))
    }

    fn list_studies(&self) -> Result<Vec<StudyProto>, DsError> {
        let mut studies: Vec<StudyProto> = Vec::new();
        for sh in &self.shards {
            let sh = sh.read();
            studies.extend(sh.studies.values().map(|e| e.study.clone()));
        }
        studies.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(studies)
    }

    /// Shard-aware pagination. The token is `"{shard}:{last_study_name}"`
    /// — resume in `shard` after `last_study_name` (names sorted within a
    /// shard, shards visited in index order). Unlike `list_studies`, only
    /// the page's studies are cloned and shards past the fill point are
    /// never locked, so a page over a large store costs O(page + one
    /// shard's keys) instead of O(all studies).
    fn list_studies_page(&self, page_size: usize, page_token: &str) -> Result<StudyPage, DsError> {
        let bad = || DsError::Invalid(format!("malformed page token {page_token:?}"));
        let (mut shard, mut after): (usize, Option<String>) = if page_token.is_empty() {
            (0, None)
        } else {
            let (s, name) = page_token.split_once(':').ok_or_else(bad)?;
            let idx: usize = s.parse().map_err(|_| bad())?;
            if idx >= self.shards.len() {
                return Err(bad());
            }
            (idx, Some(name.to_string()))
        };
        let cap = if page_size == 0 { usize::MAX } else { page_size };
        let mut out: Vec<StudyProto> = Vec::new();
        // Position of the last emitted study; becomes the next token when
        // the page fills with studies still left to visit.
        let mut last: Option<(usize, String)> = None;
        while shard < self.shards.len() {
            let sh = self.shards[shard].read();
            let mut names: Vec<&String> = sh.studies.keys().collect();
            names.sort();
            for name in names {
                if let Some(a) = &after {
                    if name.as_str() <= a.as_str() {
                        continue;
                    }
                }
                if out.len() == cap {
                    // lint: allow(no-unwrap) — cap >= 1, so something was emitted
                    let (s, n) = last.expect("cap >= 1, so something was emitted");
                    return Ok(StudyPage {
                        studies: out,
                        next_page_token: format!("{s}:{n}"),
                    });
                }
                out.push(sh.studies[name].study.clone());
                last = Some((shard, name.clone()));
            }
            after = None;
            shard += 1;
        }
        Ok(StudyPage {
            studies: out,
            next_page_token: String::new(),
        })
    }

    fn update_study(&self, study: StudyProto) -> Result<(), DsError> {
        let mut dir = self.directory.lock();
        let mut sh = self.shard_of(&study.name).write();
        let entry = sh
            .studies
            .get_mut(&study.name)
            .ok_or_else(|| DsError::StudyNotFound(study.name.clone()))?;
        if entry.study.display_name != study.display_name {
            Self::remap_display(&mut dir, &entry.study.display_name, &study.display_name, &study.name);
        }
        entry.study = study;
        Ok(())
    }

    fn delete_study(&self, name: &str) -> Result<(), DsError> {
        let mut dir = self.directory.lock();
        let mut sh = self.shard_of(name).write();
        let entry = sh
            .studies
            .remove(name)
            .ok_or_else(|| DsError::StudyNotFound(name.to_string()))?;
        Self::remap_display(&mut dir, &entry.study.display_name, "", name);
        Ok(())
    }

    fn create_trial(&self, study: &str, mut trial: TrialProto) -> Result<TrialProto, DsError> {
        let mut sh = self.shard_of(study).write();
        let entry = sh
            .studies
            .get_mut(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        trial.id = entry.next_trial_id;
        entry.next_trial_id += 1;
        entry.trials.insert(trial.id, trial.clone());
        Ok(trial)
    }

    fn get_trial(&self, study: &str, id: u64) -> Result<TrialProto, DsError> {
        let sh = self.shard_of(study).read();
        sh.studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?
            .trials
            .get(&id)
            .cloned()
            .ok_or_else(|| DsError::TrialNotFound(study.to_string(), id))
    }

    /// Keyed pagination over the study's `BTreeMap` of trials: a range
    /// scan from the token's id clones only the requested page, not the
    /// whole study.
    fn list_trials_page(
        &self,
        study: &str,
        page_size: usize,
        page_token: &str,
    ) -> Result<TrialPage, DsError> {
        let after = crate::datastore::parse_trial_token(page_token)?;
        let cap = if page_size == 0 { usize::MAX } else { page_size };
        let sh = self.shard_of(study).read();
        let entry = sh
            .studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        let mut trials: Vec<TrialProto> = Vec::with_capacity(cap.min(entry.trials.len()));
        let mut more = false;
        for (_, t) in entry.trials.range((std::ops::Bound::Excluded(after), std::ops::Bound::Unbounded)) {
            if trials.len() == cap {
                more = true;
                break;
            }
            trials.push(t.clone());
        }
        let next_page_token = if more {
            trials.last().map(|t| t.id.to_string()).unwrap_or_default()
        } else {
            String::new()
        };
        Ok(TrialPage {
            trials,
            next_page_token,
        })
    }

    fn list_trials(&self, study: &str) -> Result<Vec<TrialProto>, DsError> {
        let sh = self.shard_of(study).read();
        Ok(sh
            .studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?
            .trials
            .values()
            .cloned()
            .collect())
    }

    fn query_trials(
        &self,
        study: &str,
        filter: &super::query::TrialFilter,
    ) -> Result<Vec<TrialProto>, DsError> {
        let sh = self.shard_of(study).read();
        let entry = sh
            .studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        // Range-scan from min_id so incremental reads touch only new rows,
        // and clone only matching trials (the §6.3 database-work saving).
        let lo = filter.min_id.unwrap_or(0);
        let hi = filter.max_id.unwrap_or(u64::MAX);
        let mut kept: Vec<TrialProto> = entry
            .trials
            .range(lo..=hi)
            .map(|(_, t)| t)
            .filter(|t| filter.matches(t))
            .cloned()
            .collect();
        if let Some(limit) = filter.limit {
            if kept.len() > limit {
                kept = kept.split_off(kept.len() - limit);
            }
        }
        Ok(kept)
    }

    fn update_trial(&self, study: &str, trial: TrialProto) -> Result<(), DsError> {
        let mut sh = self.shard_of(study).write();
        let entry = sh
            .studies
            .get_mut(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        if !entry.trials.contains_key(&trial.id) {
            return Err(DsError::TrialNotFound(study.to_string(), trial.id));
        }
        entry.trials.insert(trial.id, trial);
        Ok(())
    }

    fn delete_trial(&self, study: &str, id: u64) -> Result<(), DsError> {
        let mut sh = self.shard_of(study).write();
        let entry = sh
            .studies
            .get_mut(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        entry
            .trials
            .remove(&id)
            .map(|_| ())
            .ok_or_else(|| DsError::TrialNotFound(study.to_string(), id))
    }

    fn mutate_trial(
        &self,
        study: &str,
        id: u64,
        f: &mut dyn FnMut(&mut TrialProto) -> Result<(), DsError>,
    ) -> Result<TrialProto, DsError> {
        let mut sh = self.shard_of(study).write();
        let entry = sh
            .studies
            .get_mut(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        let trial = entry
            .trials
            .get_mut(&id)
            .ok_or_else(|| DsError::TrialNotFound(study.to_string(), id))?;
        f(trial)?;
        Ok(trial.clone())
    }

    fn create_operation(&self, mut op: OperationProto) -> Result<OperationProto, DsError> {
        if op.name.is_empty() {
            let id = self.next_op.fetch_add(1, Ordering::SeqCst);
            op.name = format!("operations/{id}");
        }
        let mut sh = self.shard_of(&op.name).write();
        sh.operations.insert(op.name.clone(), op.clone());
        Ok(op)
    }

    fn get_operation(&self, name: &str) -> Result<OperationProto, DsError> {
        self.shard_of(name)
            .read()
            .operations
            .get(name)
            .cloned()
            .ok_or_else(|| DsError::OperationNotFound(name.to_string()))
    }

    fn update_operation(&self, op: OperationProto) -> Result<(), DsError> {
        let mut sh = self.shard_of(&op.name).write();
        if !sh.operations.contains_key(&op.name) {
            return Err(DsError::OperationNotFound(op.name.clone()));
        }
        sh.operations.insert(op.name.clone(), op);
        Ok(())
    }

    fn pending_operations(&self) -> Result<Vec<OperationProto>, DsError> {
        let mut ops: Vec<OperationProto> = Vec::new();
        for sh in &self.shards {
            let sh = sh.read();
            ops.extend(sh.operations.values().filter(|o| !o.done).cloned());
        }
        ops.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(ops)
    }

    fn update_metadata(
        &self,
        study: &str,
        updates: &[UnitMetadataUpdate],
    ) -> Result<(), DsError> {
        let mut sh = self.shard_of(study).write();
        let entry = sh
            .studies
            .get_mut(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?;
        for u in updates {
            let Some(item) = &u.item else { continue };
            if u.trial_id == 0 {
                // Study-level metadata table.
                let md = &mut entry.study.spec.metadata;
                md.retain(|m| !(m.namespace == item.namespace && m.key == item.key));
                md.push(item.clone());
            } else {
                let trial = entry
                    .trials
                    .get_mut(&u.trial_id)
                    .ok_or_else(|| DsError::TrialNotFound(study.to_string(), u.trial_id))?;
                trial
                    .metadata
                    .retain(|m| !(m.namespace == item.namespace && m.key == item.key));
                trial.metadata.push(item.clone());
            }
        }
        Ok(())
    }

    fn trial_count(&self, study: &str) -> Result<usize, DsError> {
        let sh = self.shard_of(study).read();
        Ok(sh
            .studies
            .get(study)
            .ok_or_else(|| DsError::StudyNotFound(study.to_string()))?
            .trials
            .len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::messages::MetadataItem;
    use std::sync::Arc;

    fn study(display: &str) -> StudyProto {
        StudyProto {
            display_name: display.to_string(),
            ..Default::default()
        }
    }

    #[test]
    fn study_crud() {
        let ds = InMemoryDatastore::new();
        let s = ds.create_study(study("a")).unwrap();
        assert_eq!(s.name, "studies/1");
        assert_eq!(ds.get_study("studies/1").unwrap().display_name, "a");
        assert_eq!(ds.lookup_study("a").unwrap().name, "studies/1");
        let s2 = ds.create_study(study("b")).unwrap();
        assert_eq!(s2.name, "studies/2");
        assert_eq!(ds.list_studies().unwrap().len(), 2);
        ds.delete_study("studies/1").unwrap();
        assert_eq!(ds.get_study("studies/1"), Err(DsError::StudyNotFound("studies/1".into())));
        assert!(ds.delete_study("studies/1").is_err());
    }

    #[test]
    fn duplicate_display_name_rejected() {
        let ds = InMemoryDatastore::new();
        ds.create_study(study("same")).unwrap();
        assert!(matches!(ds.create_study(study("same")), Err(DsError::StudyExists(_))));
    }

    #[test]
    fn display_rename_aliases_cannot_bypass_uniqueness() {
        let ds = InMemoryDatastore::new();
        let a = ds.create_study(study("d")).unwrap();
        let b = ds.create_study(study("b")).unwrap();
        // Rename B onto A's display name, then away again — this strands
        // the alias in a naive unique-key index.
        let mut b2 = ds.get_study(&b.name).unwrap();
        b2.display_name = "d".into();
        ds.update_study(b2.clone()).unwrap();
        b2.display_name = "x".into();
        ds.update_study(b2).unwrap();
        // A still owns "d": another create must be rejected and lookup
        // must still resolve to A.
        assert!(matches!(ds.create_study(study("d")), Err(DsError::StudyExists(_))));
        assert_eq!(ds.lookup_study("d").unwrap().name, a.name);
    }

    #[test]
    fn deleted_display_name_can_be_reused() {
        let ds = InMemoryDatastore::new();
        let s = ds.create_study(study("re")).unwrap();
        ds.delete_study(&s.name).unwrap();
        let s2 = ds.create_study(study("re")).unwrap();
        assert_ne!(s.name, s2.name);
        assert_eq!(ds.lookup_study("re").unwrap().name, s2.name);
    }

    #[test]
    fn trial_ids_are_sequential_per_study() {
        let ds = InMemoryDatastore::new();
        let s1 = ds.create_study(study("a")).unwrap();
        let s2 = ds.create_study(study("b")).unwrap();
        for expect in 1..=3 {
            let t = ds.create_trial(&s1.name, TrialProto::default()).unwrap();
            assert_eq!(t.id, expect);
        }
        let t = ds.create_trial(&s2.name, TrialProto::default()).unwrap();
        assert_eq!(t.id, 1, "ids are per-study");
        assert_eq!(ds.trial_count(&s1.name).unwrap(), 3);
    }

    #[test]
    fn mutate_trial_atomicity() {
        let ds = Arc::new(InMemoryDatastore::new());
        let s = ds.create_study(study("a")).unwrap();
        ds.create_trial(&s.name, TrialProto::default()).unwrap();
        // 8 threads increment created_ms 100 times each via mutate_trial.
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let ds = Arc::clone(&ds);
                let name = s.name.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        ds.mutate_trial(&name, 1, &mut |t| {
                            t.created_ms += 1;
                            Ok(())
                        })
                        .unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(ds.get_trial(&s.name, 1).unwrap().created_ms, 800);
    }

    #[test]
    fn trial_pagination_walks_every_trial_once() {
        let ds = InMemoryDatastore::new();
        let s = ds
            .create_study(StudyProto { display_name: "page".into(), ..Default::default() })
            .unwrap();
        for _ in 0..25 {
            ds.create_trial(&s.name, TrialProto::default()).unwrap();
        }
        let mut seen: Vec<u64> = Vec::new();
        let mut token = String::new();
        let mut pages = 0;
        loop {
            let page = ds.list_trials_page(&s.name, 10, &token).unwrap();
            assert!(page.trials.len() <= 10);
            seen.extend(page.trials.iter().map(|t| t.id));
            pages += 1;
            if page.next_page_token.is_empty() {
                break;
            }
            token = page.next_page_token;
        }
        assert_eq!(pages, 3); // 10 + 10 + 5
        assert_eq!(seen, (1..=25).collect::<Vec<u64>>());
        // page_size 0 = everything in one page.
        let all = ds.list_trials_page(&s.name, 0, "").unwrap();
        assert_eq!(all.trials.len(), 25);
        assert!(all.next_page_token.is_empty());
        // A malformed token is an error, not a silent restart.
        assert!(ds.list_trials_page(&s.name, 10, "bogus").is_err());
        assert!(ds.list_trials_page("studies/none", 10, "").is_err());
    }

    #[test]
    fn operations() {
        let ds = InMemoryDatastore::new();
        let op = ds.create_operation(OperationProto::default()).unwrap();
        assert_eq!(op.name, "operations/1");
        assert_eq!(ds.pending_operations().unwrap().len(), 1);
        let mut done = op.clone();
        done.done = true;
        ds.update_operation(done).unwrap();
        assert!(ds.pending_operations().unwrap().is_empty());
        assert!(ds.get_operation("operations/1").unwrap().done);
        assert!(ds.get_operation("operations/99").is_err());
    }

    #[test]
    fn metadata_updates_upsert() {
        let ds = InMemoryDatastore::new();
        let s = ds.create_study(study("a")).unwrap();
        ds.create_trial(&s.name, TrialProto::default()).unwrap();
        let item = |v: &[u8]| MetadataItem {
            namespace: "evo".into(),
            key: "pop".into(),
            value: v.to_vec(),
        };
        // Study-level write then overwrite.
        ds.update_metadata(
            &s.name,
            &[UnitMetadataUpdate { trial_id: 0, item: Some(item(b"v1")), new_trial_index: 0 }],
        )
        .unwrap();
        ds.update_metadata(
            &s.name,
            &[UnitMetadataUpdate { trial_id: 0, item: Some(item(b"v2")), new_trial_index: 0 }],
        )
        .unwrap();
        let study = ds.get_study(&s.name).unwrap();
        assert_eq!(study.spec.metadata.len(), 1);
        assert_eq!(study.spec.metadata[0].value, b"v2");
        // Trial-level write.
        ds.update_metadata(
            &s.name,
            &[UnitMetadataUpdate { trial_id: 1, item: Some(item(b"t")), new_trial_index: 0 }],
        )
        .unwrap();
        assert_eq!(ds.get_trial(&s.name, 1).unwrap().metadata[0].value, b"t");
        // Unknown trial errors.
        assert!(ds
            .update_metadata(
                &s.name,
                &[UnitMetadataUpdate { trial_id: 99, item: Some(item(b"x")), new_trial_index: 0 }],
            )
            .is_err());
    }

    #[test]
    fn errors_for_missing_entities() {
        let ds = InMemoryDatastore::new();
        assert!(ds.get_trial("studies/1", 1).is_err());
        assert!(ds.list_trials("nope").is_err());
        assert!(ds.create_trial("nope", TrialProto::default()).is_err());
        assert!(ds.update_trial("nope", TrialProto::default()).is_err());
        let s = ds.create_study(study("a")).unwrap();
        assert!(ds.update_trial(&s.name, TrialProto { id: 5, ..Default::default() }).is_err());
    }

    #[test]
    fn shard_routing_is_stable_and_total() {
        let ds = InMemoryDatastore::new();
        assert_eq!(ds.shard_count(), DEFAULT_SHARD_COUNT);
        for i in 0..200 {
            let name = format!("studies/{i}");
            let a = ds.shard_index(&name);
            let b = ds.shard_index(&name);
            assert_eq!(a, b, "routing must be deterministic");
            assert!(a < ds.shard_count());
        }
    }

    #[test]
    fn studies_land_in_their_computed_shard() {
        let ds = InMemoryDatastore::new();
        let mut names = Vec::new();
        for i in 0..50 {
            names.push(ds.create_study(study(&format!("s{i}"))).unwrap().name);
        }
        for name in &names {
            let idx = ds.shard_index(name);
            assert!(
                ds.studies_in_shard(idx).contains(name),
                "{name} not in shard {idx}"
            );
        }
        // Union over shards == list_studies.
        let mut union: Vec<String> = (0..ds.shard_count())
            .flat_map(|i| ds.studies_in_shard(i))
            .collect();
        union.sort();
        let mut listed: Vec<String> =
            ds.list_studies().unwrap().into_iter().map(|s| s.name).collect();
        listed.sort();
        assert_eq!(union, listed);
    }

    #[test]
    fn single_shard_store_behaves_identically() {
        let run = |ds: InMemoryDatastore| {
            let s = ds.create_study(study("x")).unwrap();
            for _ in 0..5 {
                ds.create_trial(&s.name, TrialProto::default()).unwrap();
            }
            ds.delete_trial(&s.name, 3).unwrap();
            let ids: Vec<u64> =
                ds.list_trials(&s.name).unwrap().into_iter().map(|t| t.id).collect();
            (s.name, ids)
        };
        assert_eq!(run(InMemoryDatastore::with_shards(1)), run(InMemoryDatastore::new()));
    }

    #[test]
    fn pagination_visits_every_study_exactly_once() {
        for shards in [1usize, 16] {
            let ds = InMemoryDatastore::with_shards(shards);
            let mut expected: Vec<String> = Vec::new();
            for i in 0..43 {
                expected.push(ds.create_study(study(&format!("p{i}"))).unwrap().name);
            }
            for page_size in [1usize, 7, 43, 100] {
                let mut seen: Vec<String> = Vec::new();
                let mut token = String::new();
                let mut rounds = 0;
                loop {
                    let page = ds.list_studies_page(page_size, &token).unwrap();
                    assert!(page.studies.len() <= page_size);
                    seen.extend(page.studies.iter().map(|s| s.name.clone()));
                    if page.next_page_token.is_empty() {
                        break;
                    }
                    token = page.next_page_token;
                    rounds += 1;
                    assert!(rounds <= 100, "pagination must terminate");
                }
                let mut seen_sorted = seen.clone();
                seen_sorted.sort();
                seen_sorted.dedup();
                assert_eq!(seen.len(), expected.len(), "page_size {page_size}");
                assert_eq!(seen_sorted.len(), expected.len(), "no duplicates");
                let mut want = expected.clone();
                want.sort();
                assert_eq!(seen_sorted, want);
            }
        }
    }

    #[test]
    fn pagination_unlimited_page_matches_list() {
        let ds = InMemoryDatastore::new();
        for i in 0..10 {
            ds.create_study(study(&format!("u{i}"))).unwrap();
        }
        let page = ds.list_studies_page(0, "").unwrap();
        assert_eq!(page.studies.len(), 10);
        assert!(page.next_page_token.is_empty());
    }

    #[test]
    fn pagination_rejects_malformed_tokens() {
        let ds = InMemoryDatastore::new();
        ds.create_study(study("t")).unwrap();
        assert!(matches!(
            ds.list_studies_page(5, "no-colon"),
            Err(DsError::Invalid(_))
        ));
        assert!(matches!(
            ds.list_studies_page(5, "abc:studies/1"),
            Err(DsError::Invalid(_))
        ));
        assert!(matches!(
            ds.list_studies_page(5, "999:studies/1"),
            Err(DsError::Invalid(_))
        ));
    }

    #[test]
    fn concurrent_study_creation_never_loses_or_duplicates() {
        let ds = Arc::new(InMemoryDatastore::new());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let ds = Arc::clone(&ds);
                std::thread::spawn(move || {
                    for i in 0..50 {
                        ds.create_study(study(&format!("t{t}-{i}"))).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let studies = ds.list_studies().unwrap();
        assert_eq!(studies.len(), 400);
        let names: std::collections::HashSet<_> =
            studies.iter().map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), 400, "resource names must be unique");
    }
}
