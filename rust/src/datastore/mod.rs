//! Persistent datastore (paper §3.1 "Persistent Datastore", §3.2).
//!
//! The datastore owns all studies, trials, and long-running operations.
//! It is pluggable ("The database in OSS Vizier can be changed based on the
//! user's needs"): [`memory::InMemoryDatastore`] for benchmarking and local
//! studies, [`wal::WalDatastore`] for durability — an append-only
//! write-ahead log of wire-encoded mutations with snapshot + replay
//! recovery, which is what makes the server-side fault-tolerance claim of
//! §3.2 hold across process crashes.
//!
//! # Scaling under parallel clients
//!
//! The paper's reliability story (§3.1–§3.2) assumes the datastore keeps
//! serving while many workers evaluate trials in parallel. Two mechanisms
//! keep the hot paths off global locks:
//!
//! * **Sharding** ([`memory::InMemoryDatastore`]): studies are partitioned
//!   into `N` independent shards by a stable FNV-1a hash of the study name,
//!   each shard behind its own `RwLock`. Trial CRUD for different studies
//!   proceeds in parallel; per-study trial-id assignment stays sequential
//!   because a study never leaves its shard. Cross-shard reads
//!   (`list_studies`) iterate shards; `lookup_study` and display-name
//!   uniqueness go through a small directory lock that is never held
//!   across shard work.
//!
//! * **Copy-on-write snapshot reads** ([`memory::InMemoryDatastore`],
//!   the default mode): each shard's state is an immutable
//!   `Arc<ShardImage>` republished atomically after every write
//!   (clone-on-write of only the touched study/chunk), so *readers take
//!   no lock at all* — one atomic pointer load yields a self-consistent
//!   image that a burst of `ListTrials`/`QueryTrials`/suggest scans can
//!   walk while writers keep committing. `OSSVIZIER_DATASTORE_COW=off`
//!   (or `--datastore-cow off`) restores the lock-per-read baseline.
//!   The publish/pin/reclaim protocol, its lock-rank class
//!   (`datastore.image_retire`), and the chunked trial layout are
//!   documented in [`memory`].
//!
//! * **Group commit with per-shard lanes** ([`wal::WalDatastore`]):
//!   mutations from concurrent connections are appended to per-shard
//!   commit lanes and one dedicated committer thread writes + fsyncs all
//!   lanes in batches. A writer is acknowledged only once the batch
//!   containing its record is durable, so K concurrent writers pay ~1
//!   fsync instead of K while keeping the §3.2 guarantee: every
//!   acknowledged mutation survives a crash, and a torn batch tail is
//!   detected and truncated at replay. Because the in-memory apply runs
//!   under the *lane's* lock (not a global commit lock), the sharded
//!   store's N-way parallelism survives durability.
//!
//! # Durable-log invariants (see `wal.rs` for the full lifecycle)
//!
//! The WAL's correctness argument rests on three invariants, each of
//! which a test suite pins:
//!
//! 1. **Per-shard replay order.** All records of one study (or
//!    operation) route to one commit lane — creates reserve their
//!    resource name first — and a lane is FIFO: appends happen in apply
//!    order under the lane lock, the committer drains lanes completely,
//!    and earlier batches hit the disk first. Replay therefore applies
//!    each shard's records in its apply order; cross-shard interleaving
//!    is unconstrained and irrelevant (`prop_invariants.rs`:
//!    `segment_prefix_plus_torn_tail_replays_to_acked_prefix_per_study`).
//! 2. **Prefix recovery.** Any crash leaves, per shard, a prefix of the
//!    applied mutation order that covers every *acknowledged* mutation:
//!    acks happen only after the flush, torn tails are exactly the
//!    never-acked suffix, and only the final segment may be torn
//!    (sealed segments are fsynced at rotation).
//! 3. **Compaction transparency.** A base snapshot is cut from live
//!    state without perturbing the commit path: in copy-on-write mode
//!    (the default) each shard is a single atomic image load and the
//!    compactor streams the pinned image holding **zero** shard locks;
//!    in the `OSSVIZIER_DATASTORE_COW=off` baseline it falls back to
//!    short paged reads (study rows per shard, trials in keyed pages),
//!    never holding any lock longer than one page clone. Either way the
//!    base may overlap the tail; replay applies are blind per-key
//!    upserts/deletes, so base-then-tail replay converges to the same
//!    state as replaying the full original log
//!    (`tests/fault_tolerance.rs`:
//!    `crash_at_every_compaction_stage_recovers_cleanly`).
//!
//! The datastore's locks sit in the crate-wide hierarchy declared in
//! [`crate::util::sync::classes`] (directory before shard; the WAL
//! commit locks above both) and are checked under lockdep. The full
//! table, with the code paths that pin each edge, is in
//! `rust/docs/INVARIANTS.md`.

pub mod memory;
pub mod query;
pub mod wal;

use crate::wire::messages::{OperationProto, StudyProto, TrialProto, UnitMetadataUpdate};

/// Datastore errors (mapped to RPC statuses by the service layer).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DsError {
    StudyNotFound(String),
    TrialNotFound(String, u64),
    OperationNotFound(String),
    StudyExists(String),
    Invalid(String),
    Storage(String),
}

impl std::fmt::Display for DsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DsError::StudyNotFound(s) => write!(f, "study {s:?} not found"),
            DsError::TrialNotFound(s, id) => write!(f, "trial {id} not found in study {s:?}"),
            DsError::OperationNotFound(op) => write!(f, "operation {op:?} not found"),
            DsError::StudyExists(s) => write!(f, "study {s:?} already exists"),
            DsError::Invalid(msg) => write!(f, "invalid argument: {msg}"),
            DsError::Storage(msg) => write!(f, "storage failure: {msg}"),
        }
    }
}

impl std::error::Error for DsError {}

/// One page of a paginated study listing.
#[derive(Debug, Clone, Default)]
pub struct StudyPage {
    pub studies: Vec<StudyProto>,
    /// Opaque cursor for the next page; empty = listing exhausted.
    pub next_page_token: String,
}

/// One page of a paginated trial listing.
#[derive(Debug, Clone, Default)]
pub struct TrialPage {
    pub trials: Vec<TrialProto>,
    /// Opaque cursor for the next page; empty = listing exhausted.
    pub next_page_token: String,
}

/// Storage abstraction used by the Vizier service.
///
/// All methods are atomic with respect to each other. `mutate_*` methods
/// provide read-modify-write under the owning shard's write lock (there
/// is no store-wide lock — see the sharding notes above), which the
/// service uses for trial assignment and operation completion. Reads may
/// be served lock-free from a published copy-on-write snapshot; they are
/// still atomic — a reader observes some prefix of the shard's applied
/// writes, never a torn one.
pub trait Datastore: Send + Sync {
    // -- studies --
    /// Store a new study; assigns `name` = `studies/{n}` if empty.
    fn create_study(&self, study: StudyProto) -> Result<StudyProto, DsError>;
    fn get_study(&self, name: &str) -> Result<StudyProto, DsError>;
    /// Find by user-facing display name (paper: `load_or_create_study`).
    fn lookup_study(&self, display_name: &str) -> Result<StudyProto, DsError>;
    fn list_studies(&self) -> Result<Vec<StudyProto>, DsError>;
    /// Paginated listing: at most `page_size` studies (0 = no cap) after
    /// the position encoded by `page_token` ("" starts from the top).
    /// Full iteration visits every study exactly once, but the order is
    /// implementation-defined — sharded stores may return shard-grouped
    /// pages instead of a globally sorted sequence. The default
    /// implementation falls back to sorting the full listing; stores with
    /// internal cursors should override it.
    fn list_studies_page(&self, page_size: usize, page_token: &str) -> Result<StudyPage, DsError> {
        let all = self.list_studies()?; // name-sorted by contract
        let start = if page_token.is_empty() {
            0
        } else {
            all.partition_point(|s| s.name.as_str() <= page_token)
        };
        let end = if page_size == 0 {
            all.len()
        } else {
            (start + page_size).min(all.len())
        };
        let studies = all[start..end].to_vec();
        let next_page_token = if end < all.len() {
            studies.last().map(|s| s.name.clone()).unwrap_or_default()
        } else {
            String::new()
        };
        Ok(StudyPage {
            studies,
            next_page_token,
        })
    }
    fn update_study(&self, study: StudyProto) -> Result<(), DsError>;
    fn delete_study(&self, name: &str) -> Result<(), DsError>;

    // -- trials --
    /// Store a new trial; assigns the next trial id in the study.
    fn create_trial(&self, study: &str, trial: TrialProto) -> Result<TrialProto, DsError>;
    fn get_trial(&self, study: &str, id: u64) -> Result<TrialProto, DsError>;
    fn list_trials(&self, study: &str) -> Result<Vec<TrialProto>, DsError>;
    /// Server-side filtered read (paper §6.2: "the Policy can request only
    /// the Trials it needs; ... reduce the database work by orders of
    /// magnitude relative to loading all the Trials"). Implementations
    /// should avoid cloning non-matching trials; the default falls back to
    /// `list_trials` + filter.
    fn query_trials(
        &self,
        study: &str,
        filter: &query::TrialFilter,
    ) -> Result<Vec<TrialProto>, DsError> {
        Ok(filter.apply(self.list_trials(study)?))
    }
    /// Paginated trial listing: at most `page_size` trials (0 = no cap)
    /// after the position encoded by `page_token` ("" starts from the
    /// top), in trial-id order. The token is the last returned trial's
    /// id; trials created mid-iteration with higher ids appear in later
    /// pages, deleted ones are skipped — the usual cursor semantics.
    /// The default falls back to `list_trials` (id-sorted by contract)
    /// and clones everything; stores with keyed trial maps should
    /// override it to clone only the page.
    fn list_trials_page(
        &self,
        study: &str,
        page_size: usize,
        page_token: &str,
    ) -> Result<TrialPage, DsError> {
        let after = parse_trial_token(page_token)?;
        let cap = if page_size == 0 { usize::MAX } else { page_size };
        let mut trials: Vec<TrialProto> = self
            .list_trials(study)?
            .into_iter()
            .filter(|t| t.id > after)
            .collect();
        let next_page_token = if trials.len() > cap {
            trials.truncate(cap);
            trials.last().map(|t| t.id.to_string()).unwrap_or_default()
        } else {
            String::new()
        };
        Ok(TrialPage {
            trials,
            next_page_token,
        })
    }
    fn update_trial(&self, study: &str, trial: TrialProto) -> Result<(), DsError>;
    fn delete_trial(&self, study: &str, id: u64) -> Result<(), DsError>;
    /// Atomic read-modify-write of one trial.
    fn mutate_trial(
        &self,
        study: &str,
        id: u64,
        f: &mut dyn FnMut(&mut TrialProto) -> Result<(), DsError>,
    ) -> Result<TrialProto, DsError>;

    // -- operations --
    /// Store a new operation; assigns `name` = `operations/{n}` if empty.
    fn create_operation(&self, op: OperationProto) -> Result<OperationProto, DsError>;
    fn get_operation(&self, name: &str) -> Result<OperationProto, DsError>;
    fn update_operation(&self, op: OperationProto) -> Result<(), DsError>;
    /// All operations with `done == false` — scanned at startup to resume
    /// interrupted computations (server-side fault tolerance).
    fn pending_operations(&self) -> Result<Vec<OperationProto>, DsError>;

    // -- metadata --
    /// Apply a batch of metadata writes (trial_id 0 = study metadata).
    fn update_metadata(&self, study: &str, updates: &[UnitMetadataUpdate])
        -> Result<(), DsError>;

    /// Number of trials in a study (cheaper than `list_trials().len()`).
    fn trial_count(&self, study: &str) -> Result<usize, DsError>;
}

/// Decode a trial-listing page token (the last-seen trial id; "" = from
/// the top).
pub(crate) fn parse_trial_token(page_token: &str) -> Result<u64, DsError> {
    if page_token.is_empty() {
        Ok(0)
    } else {
        page_token
            .parse()
            .map_err(|_| DsError::Invalid(format!("malformed page token {page_token:?}")))
    }
}
