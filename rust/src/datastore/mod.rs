//! Persistent datastore (paper §3.1 "Persistent Datastore", §3.2).
//!
//! The datastore owns all studies, trials, and long-running operations.
//! It is pluggable ("The database in OSS Vizier can be changed based on the
//! user's needs"): [`memory::InMemoryDatastore`] for benchmarking and local
//! studies, [`wal::WalDatastore`] for durability — an append-only
//! write-ahead log of wire-encoded mutations with snapshot + replay
//! recovery, which is what makes the server-side fault-tolerance claim of
//! §3.2 hold across process crashes.

pub mod memory;
pub mod query;
pub mod wal;

use crate::wire::messages::{OperationProto, StudyProto, TrialProto, UnitMetadataUpdate};

/// Datastore errors (mapped to RPC statuses by the service layer).
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum DsError {
    #[error("study {0:?} not found")]
    StudyNotFound(String),
    #[error("trial {1} not found in study {0:?}")]
    TrialNotFound(String, u64),
    #[error("operation {0:?} not found")]
    OperationNotFound(String),
    #[error("study {0:?} already exists")]
    StudyExists(String),
    #[error("invalid argument: {0}")]
    Invalid(String),
    #[error("storage failure: {0}")]
    Storage(String),
}

/// Storage abstraction used by the Vizier service.
///
/// All methods are atomic with respect to each other. `mutate_*` methods
/// provide read-modify-write under the store's lock, which the service uses
/// for trial assignment and operation completion.
pub trait Datastore: Send + Sync {
    // -- studies --
    /// Store a new study; assigns `name` = `studies/{n}` if empty.
    fn create_study(&self, study: StudyProto) -> Result<StudyProto, DsError>;
    fn get_study(&self, name: &str) -> Result<StudyProto, DsError>;
    /// Find by user-facing display name (paper: `load_or_create_study`).
    fn lookup_study(&self, display_name: &str) -> Result<StudyProto, DsError>;
    fn list_studies(&self) -> Result<Vec<StudyProto>, DsError>;
    fn update_study(&self, study: StudyProto) -> Result<(), DsError>;
    fn delete_study(&self, name: &str) -> Result<(), DsError>;

    // -- trials --
    /// Store a new trial; assigns the next trial id in the study.
    fn create_trial(&self, study: &str, trial: TrialProto) -> Result<TrialProto, DsError>;
    fn get_trial(&self, study: &str, id: u64) -> Result<TrialProto, DsError>;
    fn list_trials(&self, study: &str) -> Result<Vec<TrialProto>, DsError>;
    /// Server-side filtered read (paper §6.2: "the Policy can request only
    /// the Trials it needs; ... reduce the database work by orders of
    /// magnitude relative to loading all the Trials"). Implementations
    /// should avoid cloning non-matching trials; the default falls back to
    /// `list_trials` + filter.
    fn query_trials(
        &self,
        study: &str,
        filter: &query::TrialFilter,
    ) -> Result<Vec<TrialProto>, DsError> {
        Ok(filter.apply(self.list_trials(study)?))
    }
    fn update_trial(&self, study: &str, trial: TrialProto) -> Result<(), DsError>;
    fn delete_trial(&self, study: &str, id: u64) -> Result<(), DsError>;
    /// Atomic read-modify-write of one trial.
    fn mutate_trial(
        &self,
        study: &str,
        id: u64,
        f: &mut dyn FnMut(&mut TrialProto) -> Result<(), DsError>,
    ) -> Result<TrialProto, DsError>;

    // -- operations --
    /// Store a new operation; assigns `name` = `operations/{n}` if empty.
    fn create_operation(&self, op: OperationProto) -> Result<OperationProto, DsError>;
    fn get_operation(&self, name: &str) -> Result<OperationProto, DsError>;
    fn update_operation(&self, op: OperationProto) -> Result<(), DsError>;
    /// All operations with `done == false` — scanned at startup to resume
    /// interrupted computations (server-side fault tolerance).
    fn pending_operations(&self) -> Result<Vec<OperationProto>, DsError>;

    // -- metadata --
    /// Apply a batch of metadata writes (trial_id 0 = study metadata).
    fn update_metadata(&self, study: &str, updates: &[UnitMetadataUpdate])
        -> Result<(), DsError>;

    /// Number of trials in a study (cheaper than `list_trials().len()`).
    fn trial_count(&self, study: &str) -> Result<usize, DsError>;
}
