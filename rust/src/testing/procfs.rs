//! Linux `/proc` introspection helpers shared by the front-end leak /
//! thread-budget assertions in `tests/frontend.rs` and the `C-FRONTEND`
//! bench (`benches/bench_frontend.rs`). Keeping one copy means a fix to
//! the parsing (e.g. comm-name truncation handling) reaches every
//! enforcement point.

/// Count this process's threads whose name starts with `prefix`, via
/// `/proc/self/task/*/comm`. Returns `None` when `/proc` is unavailable
/// (non-Linux), so callers can skip the assertion rather than fail.
///
/// Note Linux truncates thread names to 15 bytes; keep prefixes shorter
/// than that (the front-end uses `vizier-fe` / `pythia-fe` /
/// `vizier-conn`).
pub fn threads_with_prefix(prefix: &str) -> Option<usize> {
    let dir = std::fs::read_dir("/proc/self/task").ok()?;
    let mut n = 0;
    for entry in dir.flatten() {
        if let Ok(comm) = std::fs::read_to_string(entry.path().join("comm")) {
            if comm.trim_end().starts_with(prefix) {
                n += 1;
            }
        }
    }
    Some(n)
}

/// The process's soft open-file limit from `/proc/self/limits`, or
/// `None` off Linux.
pub fn soft_fd_limit() -> Option<u64> {
    let limits = std::fs::read_to_string("/proc/self/limits").ok()?;
    for line in limits.lines() {
        if line.starts_with("Max open files") {
            return line.split_whitespace().nth(3).and_then(|v| v.parse().ok());
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_a_named_thread() {
        let Some(zero) = threads_with_prefix("ossv-probe") else {
            return; // no /proc: nothing to verify on this platform
        };
        assert_eq!(zero, 0);
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let handle = std::thread::Builder::new()
            .name("ossv-probe-1".into())
            .spawn(move || {
                let _ = rx.recv(); // park until the test is done counting
            })
            .unwrap();
        assert_eq!(threads_with_prefix("ossv-probe"), Some(1));
        tx.send(()).unwrap();
        handle.join().unwrap();
        assert_eq!(threads_with_prefix("ossv-probe"), Some(0));
    }

    #[test]
    fn fd_limit_is_sane_when_present() {
        if let Some(soft) = soft_fd_limit() {
            assert!(soft >= 64, "soft fd limit {soft} unreasonably low");
        }
    }
}
