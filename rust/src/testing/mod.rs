//! Test-support utilities, including the property-testing mini-framework
//! (`proptest` is not in the offline vendored registry — DESIGN.md §3).

pub mod procfs;
pub mod prop;

use crate::datastore::wal::WalOptions;

/// [`WalOptions`] selected by the crash-matrix environment, so one test
/// binary covers `{group-commit, serial} × {segmented, single-file}`
/// (see `.github/workflows/crash-matrix.yml`):
///
/// * `OSSVIZIER_WAL_COMMIT` — `group` (default) or `serial`
/// * `OSSVIZIER_WAL_LAYOUT` — `single-file` (default) or `segmented`
///   (small 64 KiB segments so integration workloads actually rotate)
///
/// Unset variables give the seed defaults, so plain `cargo test` runs
/// exactly what it always ran.
pub fn wal_opts_from_env() -> WalOptions {
    let mut opts = WalOptions::default();
    match std::env::var("OSSVIZIER_WAL_COMMIT").as_deref() {
        Ok("serial") => opts.group_commit = false,
        Ok("serial-apply") => opts.serial_apply = true,
        _ => {}
    }
    if let Ok("segmented") = std::env::var("OSSVIZIER_WAL_LAYOUT").as_deref() {
        opts.segment_bytes = Some(64 * 1024);
    }
    opts.datastore_cow = Some(datastore_cow_from_env());
    opts
}

/// Datastore read-path mode selected by the CI matrix environment, so
/// one test binary covers both copy-on-write snapshot reads (the
/// default) and the lock-per-read baseline (mirrors
/// [`wal_opts_from_env`]; see the CoW legs in
/// `.github/workflows/ci.yml` and `crash-matrix.yml`):
///
/// * `OSSVIZIER_DATASTORE_COW` — `on` (default) or `off`
///
/// Unset gives copy-on-write, the production default, so plain
/// `cargo test` exercises what production runs.
pub fn datastore_cow_from_env() -> bool {
    crate::datastore::memory::cow_default_from_env()
}

/// Front-end poller selected by the CI matrix environment, so one test
/// binary covers both readiness backends (mirrors [`wal_opts_from_env`];
/// see the poller matrix in `.github/workflows/ci.yml`):
///
/// * `OSSVIZIER_POLLER` — `epoll` (default) or `poll` (the
///   rebuilt-each-wakeup baseline)
///
/// Unset gives epoll, the production default, so plain `cargo test`
/// exercises what production runs.
pub fn poller_from_env() -> crate::util::netpoll::PollerKind {
    crate::util::netpoll::PollerKind::from_env()
}
