//! Test-support utilities, including the property-testing mini-framework
//! (`proptest` is not in the offline vendored registry — DESIGN.md §3).

pub mod procfs;
pub mod prop;
