//! Test-support utilities, including the property-testing mini-framework
//! (`proptest` is not in the offline vendored registry — DESIGN.md §3).

pub mod procfs;
pub mod prop;

use crate::datastore::wal::WalOptions;

/// [`WalOptions`] selected by the crash-matrix environment, so one test
/// binary covers `{group-commit, serial} × {segmented, single-file}`
/// (see `.github/workflows/crash-matrix.yml`):
///
/// * `OSSVIZIER_WAL_COMMIT` — `group` (default) or `serial`
/// * `OSSVIZIER_WAL_LAYOUT` — `single-file` (default) or `segmented`
///   (small 64 KiB segments so integration workloads actually rotate)
///
/// Unset variables give the seed defaults, so plain `cargo test` runs
/// exactly what it always ran.
pub fn wal_opts_from_env() -> WalOptions {
    let mut opts = WalOptions::default();
    match std::env::var("OSSVIZIER_WAL_COMMIT").as_deref() {
        Ok("serial") => opts.group_commit = false,
        Ok("serial-apply") => opts.serial_apply = true,
        _ => {}
    }
    if let Ok("segmented") = std::env::var("OSSVIZIER_WAL_LAYOUT").as_deref() {
        opts.segment_bytes = Some(64 * 1024);
    }
    opts
}

/// Front-end poller selected by the CI matrix environment, so one test
/// binary covers both readiness backends (mirrors [`wal_opts_from_env`];
/// see the poller matrix in `.github/workflows/ci.yml`):
///
/// * `OSSVIZIER_POLLER` — `epoll` (default) or `poll` (the
///   rebuilt-each-wakeup baseline)
///
/// Unset gives epoll, the production default, so plain `cargo test`
/// exercises what production runs.
pub fn poller_from_env() -> crate::util::netpoll::PollerKind {
    crate::util::netpoll::PollerKind::from_env()
}
