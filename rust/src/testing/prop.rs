//! A small property-based testing framework (proptest substitute).
//!
//! Usage (no_run: doctest binaries bypass the xla rpath in this image):
//! ```no_run
//! use ossvizier::testing::prop::{check, Gen};
//! check("addition commutes", 200, |g| {
//!     let a = g.i64_range(-1000, 1000);
//!     let b = g.i64_range(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case runs with a deterministic per-case seed; on failure the seed is
//! reported so the case can be replayed with [`check_seed`]. Generators are
//! methods on [`Gen`], which wraps a PRNG and records a human-readable trace
//! of the values drawn (printed on failure in lieu of shrinking).

use crate::util::rng::Pcg32;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Value generator handed to property bodies.
pub struct Gen {
    rng: Pcg32,
    pub trace: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg32::seeded(seed),
            trace: Vec::new(),
        }
    }

    /// Access the raw RNG (values drawn this way are not traced).
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }

    fn record<T: std::fmt::Debug>(&mut self, label: &str, v: T) -> T {
        self.trace.push(format!("{label} = {v:?}"));
        v
    }

    pub fn u64_below(&mut self, bound: u64) -> u64 {
        let v = self.rng.next_below(bound);
        self.record("u64", v)
    }

    pub fn i64_range(&mut self, lo: i64, hi: i64) -> i64 {
        let v = self.rng.int_range(lo, hi);
        self.record("i64", v)
    }

    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.int_range(lo as i64, hi as i64) as usize;
        self.record("usize", v)
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.f64_range(lo, hi);
        self.record("f64", v)
    }

    /// f64 from a mix of interesting values and uniform draws.
    pub fn f64_any(&mut self) -> f64 {
        let v = match self.rng.next_below(8) {
            0 => 0.0,
            1 => -0.0,
            2 => 1.0,
            3 => -1.0,
            4 => f64::MIN_POSITIVE,
            5 => 1e300,
            _ => {
                let m = self.rng.f64_range(-1e6, 1e6);
                let e = self.rng.int_range(-30, 30);
                m * 10f64.powi(e as i32)
            }
        };
        self.record("f64_any", v)
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.bool_with(0.5);
        self.record("bool", v)
    }

    /// ASCII-ish string with occasional unicode/escape-relevant chars.
    pub fn string(&mut self, max_len: usize) -> String {
        let len = self.rng.next_below(max_len as u64 + 1) as usize;
        let s: String = (0..len)
            .map(|_| match self.rng.next_below(12) {
                0 => '"',
                1 => '\\',
                2 => '\n',
                3 => '\u{1F600}',
                4 => 'é',
                _ => (b'a' + self.rng.next_below(26) as u8) as char,
            })
            .collect();
        self.record("string", s)
    }

    /// Identifier-safe string (non-empty).
    pub fn ident(&mut self, max_len: usize) -> String {
        let len = 1 + self.rng.next_below(max_len.max(1) as u64) as usize;
        let s: String = (0..len)
            .map(|_| {
                let c = self.rng.next_below(27) as u8;
                if c == 26 {
                    '_'
                } else {
                    (b'a' + c) as char
                }
            })
            .collect();
        self.record("ident", s)
    }

    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.rng.next_below(max_len as u64 + 1) as usize;
        (0..len).map(|_| f(self)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.next_below(xs.len() as u64) as usize;
        &xs[i]
    }
}

/// Run `cases` iterations of the property `body`. Panics (failing the test)
/// with the seed and value trace of the first failing case.
pub fn check(name: &str, cases: u64, mut body: impl FnMut(&mut Gen)) {
    // Base seed is derived from the property name so distinct properties
    // explore different streams but each run is reproducible.
    let base = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
    });
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        let result = catch_unwind(AssertUnwindSafe(|| (body)(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (replay: check_seed(\"{name}\", \
                 0x{seed:016x}, ...))\n  values: [{}]\n  panic: {msg}",
                g.trace.join(", ")
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seed(name: &str, seed: u64, body: impl Fn(&mut Gen)) {
    let mut g = Gen::new(seed);
    body(&mut g);
    let _ = name;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", 100, |g| {
            let xs = g.vec(20, |g| g.i64_range(-5, 5));
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            assert_eq!(xs, ys);
        });
    }

    #[test]
    fn failing_property_reports_seed_and_trace() {
        let r = std::panic::catch_unwind(|| {
            check("always fails", 10, |g| {
                let v = g.i64_range(0, 100);
                assert!(v > 1000, "v too small");
            });
        });
        let msg = match r {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("replay"), "msg: {msg}");
        assert!(msg.contains("i64"), "msg: {msg}");
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = || {
            let mut out = Vec::new();
            check("det", 5, |g| {
                out.push(g.i64_range(0, 1_000_000));
            });
            out
        };
        assert_eq!(collect(), collect());
    }
}
